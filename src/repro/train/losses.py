"""Cross-entropy training losses with a selectable log-softmax datapath.

This is the train-path payoff of the generalized CORDIC engine: the loss's
log-softmax can run through the same shift-add exp/log cores that serve the
forward nonlinearities, selected per model config:

    cfg.loss_impl = "exact"         — jax.nn.log_softmax (XLA transcendental)
    cfg.loss_impl = "cordic"        — cordic_engine.functions.log_softmax
                                      (jnp fixed Q2.14: CORDIC exp for the
                                      sum + hyperbolic-vectoring log leg)
    cfg.loss_impl = "cordic_pallas" — kernels.ops.log_softmax (the fused
                                      Pallas kernel, one VMEM pass per row)

``token_nll`` is a ``jax.custom_vjp``: whatever datapath produced the
primal log-probs, the backward pass is the analytic softmax-minus-onehot
form (d logits = g * (exp(logp) - onehot(labels))), computed from the saved
primal output. Training through the quantized forward therefore stays
exactly as stable as the float loss — the same contract the activation
wrappers make with their output-derived custom_jvp rules.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

LOSS_IMPLS = ("exact", "cordic", "cordic_pallas")


def log_softmax_fn(impl: str) -> Callable:
    """The log-softmax forward for a loss impl (differentiable wrappers)."""
    if impl == "exact":
        return jax.nn.log_softmax
    if impl == "cordic":
        from repro.cordic_engine import functions as F

        return F.log_softmax
    if impl == "cordic_pallas":
        from repro.kernels import ops as kops

        return kops.log_softmax
    raise ValueError(f"loss impl {impl!r} not in {LOSS_IMPLS}")


def _take_label(logp: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def _make_token_nll(logp_fn: Callable) -> Callable:
    """Per-token -log p(label) with the analytic softmax-onehot backward."""

    @jax.custom_vjp
    def nll(logits, labels):
        return -_take_label(logp_fn(logits), labels)

    def fwd(logits, labels):
        logp = logp_fn(logits)
        return -_take_label(logp, labels), (logp, labels)

    def bwd(res, g):
        logp, labels = res
        p = jnp.exp(logp)  # softmax from the primal log-probs (exact, stable)
        onehot = jax.nn.one_hot(labels, p.shape[-1], dtype=p.dtype)
        dlogits = g[..., None] * (p - onehot)
        return dlogits, np.zeros(labels.shape, dtype=jax.dtypes.float0)

    nll.defvjp(fwd, bwd)
    return nll


_TOKEN_NLL: Dict[str, Callable] = {}


def token_nll(logits: jax.Array, labels: jax.Array,
              impl: str = "exact") -> jax.Array:
    """-log softmax(logits)[labels] per position; backward = softmax-onehot.

    logits: (..., V) float; labels: (...) int. Returns (...) float32.
    """
    fn = _TOKEN_NLL.get(impl)
    if fn is None:
        fn = _TOKEN_NLL[impl] = _make_token_nll(log_softmax_fn(impl))
    return fn(logits, labels)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None,
                  impl: str = "exact") -> jax.Array:
    """Masked-mean token cross entropy (the loss_fn reduction)."""
    nll = token_nll(logits, labels, impl)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
