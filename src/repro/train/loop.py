"""Fault-tolerant training loop.

Wires together: deterministic data pipeline, pjit train step with
NamedShardings, async checkpointing with auto-resume, straggler detection,
failure injection (tests), and elastic restart (restore onto the current
mesh whatever mesh the checkpoint was taken on).

`run()` survives any number of injected/real step failures: each failure
triggers restore-from-latest-checkpoint and replay of the deterministic
data stream from the restored step — convergence is bitwise-reproducible
(asserted in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, DataIterator, SyntheticLMDataset
from repro.distributed.fault_tolerance import FailureInjector, StragglerDetector
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train import step as step_lib


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    accum: int = 1
    compress: bool = False
    max_restarts: int = 10
    seed: int = 0


def run(cfg, loop: LoopConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
        injector: Optional[FailureInjector] = None,
        log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Train `cfg` on the synthetic pipeline; returns final metrics/history."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, seed=loop.seed)
    dataset = SyntheticLMDataset(data_cfg)

    train_step = jax.jit(step_lib.make_train_step(
        cfg, opt_cfg, accum=loop.accum, compress=loop.compress,
        warmup_steps=max(loop.total_steps // 10, 1),
        total_steps=loop.total_steps), donate_argnums=(0,))

    detector = StragglerDetector()
    saver = ckpt.AsyncCheckpointer(loop.ckpt_dir)
    history: list = []
    restarts = 0

    def fresh_state():
        return step_lib.init_state(cfg, jax.random.PRNGKey(loop.seed), opt_cfg,
                                   compress=loop.compress)

    # --- resume if a committed checkpoint exists ---------------------------
    state = fresh_state()
    start = ckpt.latest_step(loop.ckpt_dir)
    if start is not None:
        state, extra = ckpt.restore(loop.ckpt_dir, start, state)
        log(f"[loop] resumed from step {start}")
        it = DataIterator(dataset, start_step=int(extra.get("data_step", start)))
        step_i = start
    else:
        it = DataIterator(dataset)
        step_i = 0

    while step_i < loop.total_steps:
        try:
            batch_np = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            if injector is not None:
                injector.maybe_fail(step_i)
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if detector.observe(step_i, dt):
                log(f"[ft] straggler flagged at step {step_i}: {dt:.3f}s "
                    f"(would trigger slice reassignment on a real mesh)")
            history.append({"step": step_i, "loss": loss, "dt": dt})
            if step_i % loop.log_every == 0:
                log(f"[loop] step {step_i} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
            step_i += 1
            if step_i % loop.ckpt_every == 0 or step_i == loop.total_steps:
                saver.save(step_i, state, extra={"data_step": it.state()["step"]})
        except FailureInjector.InjectedFailure as e:
            restarts += 1
            log(f"[ft] {e}; restart {restarts}")
            if restarts > loop.max_restarts:
                raise
            saver.wait()
            last = ckpt.latest_step(loop.ckpt_dir)
            state = fresh_state()
            if last is not None:
                state, extra = ckpt.restore(loop.ckpt_dir, last, state)
                it.restore({"step": int(extra["data_step"])})
                step_i = last
                log(f"[ft] restored step {last}, data stream realigned")
            else:
                it.restore({"step": 0})
                step_i = 0

    saver.wait()
    return {"history": history, "final_loss": history[-1]["loss"] if history else None,
            "restarts": restarts, "straggler_events": detector.events}
