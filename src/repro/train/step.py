"""The pjit train step: loss -> grads -> (optional compression) -> AdamW.

`make_train_step(cfg, opt_cfg, ...)` builds a pure function
    (state, batch) -> (state, metrics)
suitable for jax.jit with NamedShardings (see launch/dryrun.py and
train/loop.py). Microbatch gradient accumulation is a lax.scan over batch
slices — on a real mesh this *overlaps* the per-microbatch backward
collectives with the next microbatch's compute (the standard accumulation
overlap trick); donated state keeps HBM flat.

The loss itself is ``cfg.loss_impl``-selectable (train/losses.py): "cordic"
/ "cordic_pallas" run the cross-entropy log-softmax through the engine's
CORDIC exp + hyperbolic-vectoring log legs, with a custom_vjp whose
backward is the analytic softmax-minus-onehot form — gradients through the
quantized loss are as stable as the jax.nn baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression as comp
from repro.models import transformer as tf
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    err: Any            # error-feedback buffers (zeros-like or None)


def init_state(cfg, key, opt_cfg: adamw.AdamWConfig, *, compress: bool = False,
               dtype=jnp.float32) -> TrainState:
    params = tf.init(cfg, key, dtype)
    return TrainState(params=params, opt=adamw.init(params),
                      err=comp.init_error_state(params) if compress else None)


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *, accum: int = 1,
                    compress: bool = False, warmup_steps: int = 100,
                    total_steps: int = 10000):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        # cfg.loss_impl selects the cross-entropy log-softmax datapath
        # (exact | cordic | cordic_pallas) inside tf.loss_fn.
        return tf.loss_fn(params, batch, cfg)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        B = batch["labels"].shape[0]
        assert B % accum == 0, (B, accum)
        mb = B // accum
        sliced = jax.tree.map(
            lambda a: a.reshape((accum, mb) + a.shape[1:]), batch)
        # keep the microbatch dim sharded over the data axes — without the
        # constraint GSPMD can replicate the reshaped batch (measured 4x
        # memory regression in EXPERIMENTS.md section Perf iteration 3)
        from jax.sharding import PartitionSpec as PS
        from repro.models.common import maybe_shard

        sliced = jax.tree.map(
            lambda a: maybe_shard(a, PS(None, ("pod", "data")),
                                  PS(None, "data")), sliced)

        def body(carry, micro):
            g_acc, l_acc = carry
            (loss, _), g = grad_fn(params, micro)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(body, (zeros, 0.0), sliced)
        grads = jax.tree.map(lambda g: g / accum, g_sum)
        loss = l_sum / accum
        return loss, {"loss": loss, "aux": jnp.zeros(())}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        err = state.err
        if compress:
            grads, err = comp.compress_grads(grads, err)
        lr_scale = warmup_cosine(state.opt.step, warmup_steps=warmup_steps,
                                 total_steps=total_steps)
        new_params, new_opt, opt_m = adamw.apply_updates(
            state.params, state.opt, grads, opt_cfg, lr_scale)
        out = {"loss": loss, "grad_norm": opt_m["grad_norm"],
               "lr_scale": lr_scale, **{k: v for k, v in metrics.items()
                                        if k != "loss"}}
        return TrainState(new_params, new_opt, err), out

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = tf.loss_fn(params, batch, cfg)
        return metrics
    return eval_step
