"""Model substrate: generic decoder + block library."""
from repro.models import transformer  # noqa: F401
