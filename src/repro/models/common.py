"""Shared model components: parameter specs with logical sharding axes,
norms, embeddings, RoPE.

Parameters are plain pytrees (nested dicts of jnp arrays). Every leaf is
declared through a `P` spec that carries its *logical* axis names; the
distributed layer (repro.distributed.sharding) maps logical axes onto mesh
axes. Initialization is lazy-friendly: `init_params` builds real arrays,
`jax.eval_shape(init_params, ...)` builds ShapeDtypeStructs for the dry-run
without allocating a single byte (how 104B configs compile on one CPU).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (mapped to mesh axes in distributed/sharding.py):
#   "vocab"    — embedding/output vocab dim        -> model
#   "mlp"      — FFN hidden dim                    -> model
#   "heads"    — attention head dim (q)            -> model
#   "kv_heads" — attention kv-head dim             -> model if divisible
#   "experts"  — MoE expert dim                    -> model (expert parallel)
#   "embed"    — d_model dims                      -> replicated
#   "layers"   — scan-stacked layer dim            -> replicated
#   None       — replicated


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: P, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    std = spec.scale
    if std is None:
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(specs: Dict[str, Any], key: jax.Array, dtype=jnp.float32):
    """Materialize a spec tree into a param tree (same structure)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def param_axes(specs: Dict[str, Any]):
    """Extract the logical-axes tree (same structure as params)."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, P))


def stack_specs(spec: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Prepend a scan 'layers' dim of size n to every leaf of a spec tree."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        spec, is_leaf=lambda x: isinstance(x, P))


def maybe_shard(x: jax.Array, *candidates) -> jax.Array:
    """Apply the first sharding-constraint candidate the ambient mesh accepts.

    Model code stays mesh-agnostic: under the production mesh the constraint
    pins GSPMD's layout choice (e.g. KV cache seq->model for flash-decode
    SP); in meshless tests every candidate raises and x passes through.
    """
    from jax.sharding import PartitionSpec as PS

    for spec in candidates:
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except (RuntimeError, ValueError, TypeError):
            continue
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * params["scale"].astype(x.dtype)


def layernorm_spec(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="ones"),
            "bias": P((d,), ("embed",), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * params["scale"].astype(x.dtype)
            + params["bias"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embed_spec(vocab: int, d: int) -> Dict[str, P]:
    return {"table": P((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Logits projection (tied or untied table of shape (vocab, d))."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 1e4) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)  # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs      # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,d/2)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
