"""Mamba2 (SSD) block — chunked state-space scan in pure JAX.

Within a chunk the SSD quadratic ("attention-like") form is used; across
chunks a lax.scan carries the (B,H,P,N) state, so memory stays
O(B*H*L^2 + B*H*P*N) per step instead of O(S * state). Decode is a
single-token state update. The in-projection gate and the gated RMSNorm
use silu/sigmoid from the CORDIC activation registry.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import get_activation
from repro.models import common as cm
from repro.models.common import P


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, H, conv_dim


def mamba2_spec(cfg) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "in_proj": P((d, 2 * d_inner + 2 * s.n_groups * s.d_state + H),
                     ("embed", "mlp")),
        "conv_w": P((s.d_conv, conv_dim), (None, "mlp"), scale=0.5),
        "conv_b": P((conv_dim,), ("mlp",), init="zeros"),
        "dt_bias": P((H,), (None,), init="zeros"),
        "A_log": P((H,), (None,), init="ones"),
        "D": P((H,), (None,), init="ones"),
        "norm": cm.rmsnorm_spec(d_inner),
        "out_proj": P((d_inner, d), ("mlp", "embed")),
    }


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def _split_proj(params, x, cfg):
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt


def _causal_conv(params, xBC, cfg, conv_state=None):
    """Depthwise causal conv1d (width d_conv). Returns (y, new_state)."""
    s = cfg.ssm
    w = params["conv_w"].astype(xBC.dtype)        # (W, C)
    Wd = w.shape[0]
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    else:
        ctx = jnp.pad(xBC, ((0, 0), (Wd - 1, 0), (0, 0)))
    y = sum(ctx[:, i: i + xBC.shape[1], :] * w[i] for i in range(Wd))
    y = y + params["conv_b"].astype(xBC.dtype)
    new_state = ctx[:, -(Wd - 1):, :] if conv_state is not None else None
    return y, new_state


def _ssd_chunked(xh, dt, a_log, Bm, Cm, D, chunk: int):
    """Chunked SSD scan.

    xh: (B,S,H,P)  dt: (B,S,H)  a_log = dt*A (negative): (B,S,H)
    Bm/Cm: (B,S,G,N). Returns y: (B,S,H,P), final state (B,H,P,N).
    """
    B, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    L = min(chunk, S)
    S_orig = S
    if S % L:
        # pad to a chunk multiple with inert steps: x=0, dt=0 (no input
        # contribution), a_log=0 (decay 1 -> state preserved through pad)
        pad = L - S % L
        z3 = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, a_log, Bm, Cm = map(z3, (xh, dt, a_log, Bm, Cm))
        S = S + pad
    nc = S // L

    def cr(t, shape):  # chunk reshape
        return t.reshape(shape)

    xc = cr(xh, (B, nc, L, H, Pd))
    dtc = cr(dt, (B, nc, L, H))
    lac = cr(a_log, (B, nc, L, H))                    # log-decay per step
    Bc = cr(Bm, (B, nc, L, G, N))
    Cc = cr(Cm, (B, nc, L, G, N))
    cums = jnp.cumsum(lac, axis=2)                    # (B,nc,L,H)
    total = cums[:, :, -1]                            # (B,nc,H)

    # intra-chunk quadratic form, computed per chunk inside the scan
    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]             # (L,L)

    def step(h, inputs):
        xcb, dtb, cumsb, totalb, Bb, Cb = inputs      # per-chunk slices
        # seg_{i,j} = exp(cums_i - cums_j) for i>=j
        seg = jnp.exp(jnp.where(causal[None, :, :, None],
                                cumsb[:, :, None, :] - cumsb[:, None, :, :],
                                -jnp.inf))            # (B,L,L,H) [i,j]
        CB = jnp.einsum("blgn,bmgn->blmg", Cb, Bb)    # (B,L,L,G)
        CBh = jnp.repeat(CB, hpg, axis=-1)            # (B,L,L,H)
        scores = CBh * seg * dtb[:, None, :, :]       # weight dt_j
        y_intra = jnp.einsum("blmh,bmhp->blhp", scores, xcb)
        # inter: contribution of carried state
        decay_in = jnp.exp(cumsb)                     # (B,L,H)
        Ch = jnp.repeat(Cb, hpg, axis=2).reshape(Bb.shape[0], L, H, N)
        y_inter = jnp.einsum("blhn,bhpn,blh->blhp", Ch, h, decay_in)
        # state update
        decay_out = jnp.exp(totalb[:, None, :] - cumsb)  # (B,L,H)
        Bh = jnp.repeat(Bb, hpg, axis=2).reshape(Bb.shape[0], L, H, N)
        s_new = jnp.einsum("blh,blhn,blhp->bhpn", decay_out * dtb, Bh, xcb)
        h_next = jnp.exp(totalb)[:, :, None, None] * h + s_new
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((B, H, Pd, N), xh.dtype)
    swap = lambda t: jnp.moveaxis(t, 1, 0)            # scan over chunks
    hN, yc = jax.lax.scan(step, h0, (swap(xc), swap(dtc), swap(cums),
                                     swap(total), swap(Bc), swap(Cc)))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, Pd)
    y = y + xh * D[None, None, :, None]
    return y[:, :S_orig], hN


def mamba2_apply(params, x, cfg, *, cache: Optional[dict] = None
                 ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B,S,d). Train/prefill: chunked scan. Decode (S==1): state update."""
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    B, S, d = x.shape
    G, N, Pd = s.n_groups, s.d_state, s.head_dim
    silu = get_activation("silu", cfg.act_impl, range_mode="reduce")

    z, xBC, dt = _split_proj(params, x, cfg)
    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(params, xBC, cfg, conv_state)
    xBC = silu(xBC)

    xh = xBC[..., :d_inner].reshape(B, S, H, Pd)
    Bm = xBC[..., d_inner: d_inner + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(x.dtype))   # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,) < 0
    a_log = dt * A[None, None, :]                                  # log decay

    if cache is not None and S == 1:
        h = cache["ssm"].astype(jnp.float32)
        decay = jnp.exp(a_log[:, 0])                               # (B,H)
        Bh = jnp.repeat(Bm[:, 0], H // G, axis=1).reshape(B, H, N)
        Ch = jnp.repeat(Cm[:, 0], H // G, axis=1).reshape(B, H, N)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, 0], Bh, xh[:, 0])
        h_new = decay[:, :, None, None] * h + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new)
        y = y + xh[:, 0] * params["D"].astype(x.dtype)[None, :, None]
        y = y[:, None].astype(x.dtype)                             # (B,1,H,P)
        new_cache = {"ssm": h_new.astype(cache["ssm"].dtype), "conv": new_conv}
    else:
        y, hN = _ssd_chunked(xh.astype(jnp.float32), dt.astype(jnp.float32),
                             a_log.astype(jnp.float32), Bm.astype(jnp.float32),
                             Cm.astype(jnp.float32), params["D"].astype(jnp.float32),
                             cfg.ssm.chunk)
        y = y.astype(x.dtype)
        if cache is not None:
            new_cache = {"ssm": hN.astype(cache["ssm"].dtype), "conv": new_conv}
        else:
            new_cache = None

    yg = y.reshape(B, S, d_inner)
    yg = cm.rmsnorm(params["norm"], yg * silu(z))
    return jnp.einsum("bse,ed->bsd", yg, params["out_proj"].astype(x.dtype)), new_cache
