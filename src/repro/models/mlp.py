"""MLP blocks (SwiGLU / GELU) wired to the CORDIC activation registry.

`act_impl` in the model config selects how sigmoid/tanh-family
nonlinearities are evaluated: "exact", "cordic_float", "cordic_fixed"
(paper-faithful Q2.14), or "cordic_pallas" (the TPU kernel, which also
enables the fused silu_mul epilogue for SwiGLU).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.activations import get_activation
from repro.models.common import P


def swiglu_spec(d: int, d_ff: int) -> Dict[str, Any]:
    return {
        "w_gate": P((d, d_ff), ("embed", "mlp")),
        "w_up": P((d, d_ff), ("embed", "mlp")),
        "w_down": P((d_ff, d), ("mlp", "embed")),
    }


def swiglu_apply(params, x, cfg):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    if cfg.act_impl == "cordic_pallas":
        from repro.kernels import ops as kops

        h = kops.silu_mul(g, u)
    else:
        silu = get_activation("silu", cfg.act_impl, range_mode="reduce")
        h = silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))


def gelu_mlp_spec(d: int, d_ff: int) -> Dict[str, Any]:
    return {
        "w_in": P((d, d_ff), ("embed", "mlp")),
        "b_in": P((d_ff,), ("mlp",), init="zeros"),
        "w_out": P((d_ff, d), ("mlp", "embed")),
        "b_out": P((d,), ("embed",), init="zeros"),
    }


def gelu_mlp_apply(params, x, cfg):
    """GELU MLP (musicgen-style). With a CORDIC impl the tanh-approx GELU
    routes its tanh through the MR-HRC pipeline."""
    act = get_activation("gelu_tanh" if cfg.act_impl != "exact" else "gelu",
                         cfg.act_impl, range_mode="reduce")
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
    h = act(h + params["b_in"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype)) \
        + params["b_out"].astype(x.dtype)
