"""Stub modality frontends (per assignment spec: audio/vision frontends are
STUBS — ``input_specs()`` provides precomputed frame/patch embeddings; the
transformer backbone is the real model).

These helpers only define the *shape contract* of the precomputed
embeddings so input_specs() and the smoke tests agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def audio_frame_embeddings(batch: int, seq: int, d_model: int, *, seed: int = 0):
    """MusicGen stub: EnCodec frame embeddings (B,S,d).

    In the real system these come from the (frozen) EnCodec encoder +
    codebook embedding sum; here they are precomputed inputs.
    """
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.02, (batch, seq, d_model)), jnp.float32)


def vision_patch_embeddings(batch: int, seq: int, d_model: int, *, seed: int = 0):
    """InternVL2 stub: InternViT patch embeddings projected to the LM width,
    concatenated with text embeddings upstream — delivered precomputed."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.02, (batch, seq, d_model)), jnp.float32)
