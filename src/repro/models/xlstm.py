"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, recurrent lax.scan) — Beck et al. 2024 (arXiv:2405.04517).

This is the richest integration point for the paper's technique: every
forget/output gate sigmoid and every input-gate companion routes through the
CORDIC activation registry ("gating mechanisms in recurrent neural
networks" is the paper's own motivating use case).

mLSTM uses exp input gates with log-domain max-stabilization; the chunkwise
form mirrors models/ssm.py: quadratic within a chunk, lax.scan across
chunks carrying (C, n, m) per head.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import get_activation
from repro.models import common as cm
from repro.models.common import P


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mdims(cfg):
    x = cfg.xlstm
    d_inner = int(cfg.d_model * x.proj_factor)
    H = cfg.num_heads
    dk = d_inner // H
    return d_inner, H, dk


def mlstm_spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    d_inner, H, dk = _mdims(cfg)
    x = cfg.xlstm
    return {
        "up_proj": P((d, 2 * d_inner), ("embed", "mlp")),
        "conv_w": P((x.d_conv, d_inner), (None, "mlp"), scale=0.5),
        "conv_b": P((d_inner,), ("mlp",), init="zeros"),
        "wq": P((d_inner, d_inner), ("mlp", None)),
        "wk": P((d_inner, d_inner), ("mlp", None)),
        "wv": P((d_inner, d_inner), ("mlp", None)),
        "w_if": P((d_inner, 2 * H), ("mlp", None), scale=0.02),
        "b_if": P((2 * H,), (None,), init="zeros"),
        "norm": cm.rmsnorm_spec(d_inner),
        "down_proj": P((d_inner, d), ("mlp", "embed")),
    }


def mlstm_init_cache(cfg, batch: int, dtype=jnp.float32):
    d_inner, H, dk = _mdims(cfg)
    x = cfg.xlstm
    return {
        "C": jnp.zeros((batch, H, dk, dk), dtype),
        "n": jnp.zeros((batch, H, dk), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
        "conv": jnp.zeros((batch, x.d_conv - 1, d_inner), dtype),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int, state=None):
    """Chunkwise stabilized mLSTM.

    q/k/v: (B,S,H,D); li: input gate preact (B,S,H); lf: log forget gate.
    Returns y (B,S,H,D) and final (C,n,m).
    """
    B, S, H, D = q.shape
    L = min(chunk, S)
    S_orig = S
    if S % L:
        # inert padding: k/v/q = 0, input gate li = -inf (no write),
        # log forget lf = 0 (state preserved through the pad tail)
        pad = L - S % L
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = map(zp, (q, k, v))
        lf = zp(lf)
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        S = S + pad
    nc = S // L
    scale = 1.0 / np.sqrt(D)

    cr = lambda t: t.reshape((B, nc, L) + t.shape[2:])
    qc, kc, vc = cr(q), cr(k), cr(v)
    lic, lfc = cr(li), cr(lf)
    Fc = jnp.cumsum(lfc, axis=2)                       # (B,nc,L,H)
    totF = Fc[:, :, -1]                                # (B,nc,H)
    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qb, kb, vb, lib, Fb, totb = inp
        # D_ij = F_i - F_j + li_j  (i >= j)
        Dm = jnp.where(causal[None, :, :, None],
                       Fb[:, :, None, :] - Fb[:, None, :, :] + lib[:, None, :, :],
                       -jnp.inf)                        # (B,L,L,H)
        m_intra = jnp.max(Dm, axis=2)                   # (B,L,H)
        m_inter = Fb + m[:, None, :]                    # (B,L,H)
        mi = jnp.maximum(m_intra, m_inter)
        mi = jnp.maximum(mi, -1e30)
        Sij = jnp.exp(Dm - mi[:, :, None, :])           # (B,L,L,H)
        att = jnp.einsum("blhd,bmhd->blmh", qb, kb) * scale
        num_intra = jnp.einsum("blmh,bmhd->blhd", Sij * att, vb)
        den_intra = jnp.einsum("blmh,bmhd,blhd->blh", Sij, kb, qb) * scale
        w_in = jnp.exp(m_inter - mi)                    # (B,L,H)
        num_inter = jnp.einsum("blh,blhd,bhde->blhe", w_in, qb, C) * scale
        den_inter = jnp.einsum("blh,blhd,bhd->blh", w_in, qb, n) * scale
        num = num_intra + num_inter
        den = den_intra + den_inter
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-mi))[..., None]
        # chunk-end state update
        m_out = jnp.maximum(totb + m, jnp.max(totb[:, None, :] - Fb + lib, axis=1))
        wC = jnp.exp(totb + m - m_out)                  # (B,H)
        wK = jnp.exp(totb[:, None, :] - Fb + lib - m_out[:, None, :])  # (B,L,H)
        C_new = wC[:, :, None, None] * C + jnp.einsum("blh,blhd,blhe->bhde",
                                                      wK, kb, vb)
        n_new = wC[:, :, None] * n + jnp.einsum("blh,blhd->bhd", wK, kb)
        return (C_new, n_new, m_out), y

    swap = lambda t: jnp.moveaxis(t, 1, 0)
    (CN, nN, mN), yc = jax.lax.scan(
        step, (C0, n0, m0), (swap(qc), swap(kc), swap(vc), swap(lic),
                             swap(Fc), swap(totF)))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, D)
    return y[:, :S_orig], (CN, nN, mN)


def _mlstm_decode_step(q, k, v, li, lf, state):
    """Single-token stabilized update. q/k/v: (B,H,D); li/lf: (B,H)."""
    C, n, m = state
    D = q.shape[-1]
    scale = 1.0 / np.sqrt(D)
    m_new = jnp.maximum(lf + m, li)
    wC = jnp.exp(lf + m - m_new)
    wK = jnp.exp(li - m_new)
    C_new = wC[..., None, None] * C + wK[..., None, None] * (k[..., :, None]
                                                             * v[..., None, :])
    n_new = wC[..., None] * n + wK[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new) * scale
    den = jnp.einsum("bhd,bhd->bh", q, n_new) * scale
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return y, (C_new, n_new, m_new)


def mlstm_apply(params, x, cfg, *, cache: Optional[dict] = None):
    B, S, d = x.shape
    d_inner, H, dk = _mdims(cfg)
    silu = get_activation("silu", cfg.act_impl, range_mode="reduce")
    sig = get_activation("sigmoid", cfg.act_impl, range_mode="reduce")

    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(x.dtype))
    xm, z = up[..., :d_inner], up[..., d_inner:]

    # causal conv + silu on the q/k path
    w = params["conv_w"].astype(x.dtype)
    Wd = w.shape[0]
    conv_state = cache["conv"] if cache is not None else None
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state.astype(x.dtype), xm], axis=1)
        new_conv = ctx[:, -(Wd - 1):, :]
    else:
        ctx = jnp.pad(xm, ((0, 0), (Wd - 1, 0), (0, 0)))
        new_conv = None
    xc = sum(ctx[:, i: i + S, :] * w[i] for i in range(Wd)) \
        + params["conv_b"].astype(x.dtype)
    xc = silu(xc)

    q = jnp.einsum("bse,ef->bsf", xc, params["wq"].astype(x.dtype)).reshape(B, S, H, dk)
    k = jnp.einsum("bse,ef->bsf", xc, params["wk"].astype(x.dtype)).reshape(B, S, H, dk)
    v = jnp.einsum("bse,ef->bsf", xm, params["wv"].astype(x.dtype)).reshape(B, S, H, dk)
    gif = jnp.einsum("bse,eg->bsg", xm, params["w_if"].astype(x.dtype)) \
        + params["b_if"].astype(x.dtype)
    li = gif[..., :H].astype(jnp.float32)                     # input gate preact
    lf = jax.nn.log_sigmoid(gif[..., H:].astype(jnp.float32))  # log forget gate

    if cache is not None and S == 1:
        state = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        y, (C2, n2, m2) = _mlstm_decode_step(
            q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), li[:, 0], lf[:, 0], state)
        y = y[:, None]
        new_cache = {"C": C2.astype(cache["C"].dtype), "n": n2.astype(cache["n"].dtype),
                     "m": m2.astype(cache["m"].dtype), "conv": new_conv}
    else:
        state = None
        if cache is not None:
            state = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                     cache["m"].astype(jnp.float32))
        y, (C2, n2, m2) = _mlstm_chunked(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            li, lf, cfg.xlstm.chunk, state)
        new_cache = None
        if cache is not None:
            new_cache = {"C": C2.astype(cache["C"].dtype),
                         "n": n2.astype(cache["n"].dtype),
                         "m": m2.astype(cache["m"].dtype), "conv": new_conv}

    y = y.astype(x.dtype).reshape(B, S, d_inner)
    y = cm.rmsnorm(params["norm"], y) * silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(x.dtype)), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    return {
        "w": P((d, 4 * d), ("embed", "mlp")),          # i,f,z,o preacts
        "b": P((4 * d,), ("mlp",), init="zeros"),
        "r": P((4, H, dh, dh), (None, None, None, None), scale=0.02),
        "norm": cm.rmsnorm_spec(d),
        "ffn": {
            "w_gate": P((d, int(d * cfg.xlstm.ffn_factor)), ("embed", "mlp")),
            "w_up": P((d, int(d * cfg.xlstm.ffn_factor)), ("embed", "mlp")),
            "w_down": P((int(d * cfg.xlstm.ffn_factor), d), ("mlp", "embed")),
        },
    }


def slstm_init_cache(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), -1e30, dtype),
        "h": jnp.zeros((batch, d), dtype),
    }


def _slstm_cell(params, wx_t, state, cfg, acts):
    """One sLSTM step. wx_t: (B,4d) precomputed input preacts."""
    sig, tanh = acts
    H = cfg.num_heads
    d = cfg.d_model
    dh = d // H
    c, n, m, h = state
    hh = h.reshape(-1, H, dh)
    r = params["r"].astype(h.dtype)                    # (4,H,dh,dh)
    rh = jnp.einsum("bhe,ghef->bghf", hh, r).reshape(-1, 4 * d)
    pre = wx_t + rh
    pi, pf, pz, po = jnp.split(pre, 4, axis=-1)
    pi = pi.astype(jnp.float32)
    pf = pf.astype(jnp.float32)
    m_new = jnp.maximum(pf + m, pi)                    # exp forget gate (log dom)
    i = jnp.exp(pi - m_new)
    f = jnp.exp(pf + m - m_new)
    c_new = f * c + i * tanh(pz.astype(jnp.float32))
    n_new = f * n + i
    h_new = sig(po.astype(jnp.float32)) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def slstm_apply(params, x, cfg, *, cache: Optional[dict] = None):
    """x: (B,S,d). Recurrent scan over time (the sLSTM has no parallel form);
    input preactivations are hoisted out of the scan."""
    B, S, d = x.shape
    sig = get_activation("sigmoid", cfg.act_impl, range_mode="reduce")
    tanh = get_activation("tanh", cfg.act_impl, range_mode="reduce")
    acts = (sig, tanh)

    wx = jnp.einsum("bsd,de->bse", x, params["w"].astype(x.dtype)) \
        + params["b"].astype(x.dtype)                  # (B,S,4d)
    if cfg.slstm_state == "replicated":
        # Pin the scan inputs (and hence the carried state) to batch-only
        # sharding: the recurrence then runs replicated across the model
        # axis — tiny redundant compute instead of one cross-chip permute
        # per TIMESTEP (4096 of them at train_4k; see EXPERIMENTS §Perf).
        from jax.sharding import PartitionSpec as PS

        wx = cm.maybe_shard(wx, PS(("pod", "data"), None, None),
                            PS("data", None, None))

    if cache is not None:
        st = (cache["c"].astype(jnp.float32), cache["n"].astype(jnp.float32),
              cache["m"].astype(jnp.float32), cache["h"].astype(jnp.float32))
    else:
        z = jnp.zeros((B, d), jnp.float32)
        st = (z, z, jnp.full((B, d), -1e30, jnp.float32), z)
    if cfg.slstm_state == "replicated":
        from jax.sharding import PartitionSpec as PS

        st = tuple(cm.maybe_shard(s, PS(("pod", "data"), None),
                                  PS("data", None)) for s in st)

    def step(s, wx_t):
        s2 = _slstm_cell(params, wx_t, s, cfg, acts)
        if cfg.slstm_state == "replicated":
            from jax.sharding import PartitionSpec as PS

            s2 = tuple(cm.maybe_shard(t, PS(("pod", "data"), None),
                                      PS("data", None)) for t in s2)
        return s2, s2[3]

    st2, hs = jax.lax.scan(step, st, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # (B,S,d)

    new_cache = None
    if cache is not None:
        new_cache = {"c": st2[0].astype(cache["c"].dtype),
                     "n": st2[1].astype(cache["n"].dtype),
                     "m": st2[2].astype(cache["m"].dtype),
                     "h": st2[3].astype(cache["h"].dtype)}

    # post-norm + gated FFN (block structure)
    y = cm.rmsnorm(params["norm"], y)
    silu = get_activation("silu", cfg.act_impl, range_mode="reduce")
    f = params["ffn"]
    g = jnp.einsum("bsd,df->bsf", y, f["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", y, f["w_up"].astype(x.dtype))
    y = jnp.einsum("bsf,fd->bsd", silu(g) * u, f["w_down"].astype(x.dtype))
    return y, new_cache
