"""Generic decoder: composes attention/MoE/SSM/xLSTM blocks according to
``cfg.block_pattern``.

Runs of identical block types are parameter-stacked and executed with
``jax.lax.scan`` so a 64-layer model lowers to O(1) HLO (essential for the
512-device dry-runs). Zamba2-style shared blocks (one weight set applied at
several depths, each application with its own cache) break runs and are
applied inline.

Public API:
    model_spec(cfg)                      -> param spec tree
    init(cfg, key, dtype)                -> params (jax.eval_shape-able)
    init_cache(cfg, batch, max_len)      -> decode cache tree
    stack_caches(caches)                 -> (slots, ...) stacked cache tree
    insert_slot(stacked, cache, slot)    -> stacked tree with slot replaced
    take_slot(stacked, slot)             -> one slot's cache tree
    apply(params, batch, cfg, cache)     -> (logits, aux, new_cache)
    loss_fn(params, batch, cfg)          -> (loss, metrics)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import ssm as ssmm
from repro.models import xlstm as xlm
from repro.models.common import P


# ---------------------------------------------------------------------------
# Block registry
# ---------------------------------------------------------------------------
def _dense_spec(cfg):
    mlp_spec = (mlpm.swiglu_spec(cfg.d_model, cfg.d_ff) if cfg.mlp_kind == "swiglu"
                else mlpm.gelu_mlp_spec(cfg.d_model, cfg.d_ff))
    return {"ln1": cm.rmsnorm_spec(cfg.d_model), "attn": attn.gqa_spec(cfg),
            "ln2": cm.rmsnorm_spec(cfg.d_model), "mlp": mlp_spec}


def _dense_apply(params, x, cfg, cache, positions):
    h, nc = attn.gqa_apply(params["attn"], cm.rmsnorm(params["ln1"], x, cfg.norm_eps),
                           cfg, cache=cache, positions=positions)
    x = x + h
    h_in = cm.rmsnorm(params["ln2"], x, cfg.norm_eps)
    h = (mlpm.swiglu_apply(params["mlp"], h_in, cfg) if cfg.mlp_kind == "swiglu"
         else mlpm.gelu_mlp_apply(params["mlp"], h_in, cfg))
    return x + h, jnp.zeros((), jnp.float32), nc


def _mla_spec_factory(ffn: str):
    def spec(cfg):
        out = {"ln1": cm.rmsnorm_spec(cfg.d_model), "attn": attn.mla_spec(cfg),
               "ln2": cm.rmsnorm_spec(cfg.d_model)}
        out["ffn"] = (moem.moe_spec(cfg) if ffn == "moe"
                      else mlpm.swiglu_spec(cfg.d_model, cfg.d_ff_dense))
        return out
    return spec


def _mla_apply_factory(ffn: str):
    def apply(params, x, cfg, cache, positions):
        h, nc = attn.mla_apply(params["attn"],
                               cm.rmsnorm(params["ln1"], x, cfg.norm_eps),
                               cfg, cache=cache, positions=positions)
        x = x + h
        h_in = cm.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            h, aux = moem.moe_apply(params["ffn"], h_in, cfg)
        else:
            h, aux = mlpm.swiglu_apply(params["ffn"], h_in, cfg), jnp.zeros((), jnp.float32)
        return x + h, aux, nc
    return apply


def _gqa_moe_spec(cfg):
    return {"ln1": cm.rmsnorm_spec(cfg.d_model), "attn": attn.gqa_spec(cfg),
            "ln2": cm.rmsnorm_spec(cfg.d_model), "ffn": moem.moe_spec(cfg)}


def _gqa_moe_apply(params, x, cfg, cache, positions):
    h, nc = attn.gqa_apply(params["attn"], cm.rmsnorm(params["ln1"], x, cfg.norm_eps),
                           cfg, cache=cache, positions=positions)
    x = x + h
    h, aux = moem.moe_apply(params["ffn"], cm.rmsnorm(params["ln2"], x, cfg.norm_eps), cfg)
    return x + h, aux, nc


def _mamba_spec(cfg):
    return {"ln": cm.rmsnorm_spec(cfg.d_model), "mixer": ssmm.mamba2_spec(cfg)}


def _mamba_apply(params, x, cfg, cache, positions):
    h, nc = ssmm.mamba2_apply(params["mixer"], cm.rmsnorm(params["ln"], x, cfg.norm_eps),
                              cfg, cache=cache)
    return x + h, jnp.zeros((), jnp.float32), nc


def _mlstm_spec(cfg):
    return {"ln": cm.rmsnorm_spec(cfg.d_model), "mixer": xlm.mlstm_spec(cfg)}


def _mlstm_apply(params, x, cfg, cache, positions):
    h, nc = xlm.mlstm_apply(params["mixer"], cm.rmsnorm(params["ln"], x, cfg.norm_eps),
                            cfg, cache=cache)
    return x + h, jnp.zeros((), jnp.float32), nc


def _slstm_spec(cfg):
    return {"ln": cm.rmsnorm_spec(cfg.d_model), "mixer": xlm.slstm_spec(cfg)}


def _slstm_apply(params, x, cfg, cache, positions):
    h, nc = xlm.slstm_apply(params["mixer"], cm.rmsnorm(params["ln"], x, cfg.norm_eps),
                            cfg, cache=cache)
    return x + h, jnp.zeros((), jnp.float32), nc


def _gqa_cache(cfg, batch, max_len, dtype):
    return attn.gqa_init_cache(cfg, batch, max_len, dtype)


def _mla_cache(cfg, batch, max_len, dtype):
    return attn.mla_init_cache(cfg, batch, max_len, dtype)


def _mamba_cache(cfg, batch, max_len, dtype):
    return ssmm.mamba2_init_cache(cfg, batch)


def _mlstm_cache(cfg, batch, max_len, dtype):
    return xlm.mlstm_init_cache(cfg, batch)


def _slstm_cache(cfg, batch, max_len, dtype):
    return xlm.slstm_init_cache(cfg, batch)


BLOCKS = {
    "dense": (_dense_spec, _dense_apply, _gqa_cache),
    "mla_dense": (_mla_spec_factory("dense"), _mla_apply_factory("dense"), _mla_cache),
    "mla_moe": (_mla_spec_factory("moe"), _mla_apply_factory("moe"), _mla_cache),
    "gqa_moe": (_gqa_moe_spec, _gqa_moe_apply, _gqa_cache),
    "mamba2": (_mamba_spec, _mamba_apply, _mamba_cache),
    "mlstm": (_mlstm_spec, _mlstm_apply, _mlstm_cache),
    "slstm": (_slstm_spec, _slstm_apply, _slstm_cache),
}


# ---------------------------------------------------------------------------
# Execution plan: segment runs + shared-block applications
# ---------------------------------------------------------------------------
def execution_plan(cfg) -> List[Tuple[str, Any]]:
    """Returns [("seg", seg_idx, block_type, count) | ("shared", app_idx)]."""
    events = []
    for i, blk in enumerate(cfg.block_pattern):
        events.append(("blk", blk))
        if cfg.shared_block is not None and (i + 1) % cfg.shared_period == 0:
            events.append(("shared", None))
    plan, seg_idx, app_idx = [], 0, 0
    i = 0
    while i < len(events):
        kind, blk = events[i]
        if kind == "shared":
            plan.append(("shared", app_idx))
            app_idx += 1
            i += 1
            continue
        j = i
        while j < len(events) and events[j] == ("blk", blk):
            j += 1
        plan.append(("seg", (seg_idx, blk, j - i)))
        seg_idx += 1
        i = j
    return plan


def num_shared_apps(cfg) -> int:
    if cfg.shared_block is None:
        return 0
    return sum(1 for i in range(cfg.num_layers) if (i + 1) % cfg.shared_period == 0)


# ---------------------------------------------------------------------------
# Model spec / init / apply
# ---------------------------------------------------------------------------
def model_spec(cfg) -> Dict[str, Any]:
    spec: Dict[str, Any] = {"embed": cm.embed_spec(cfg.vocab_size, cfg.d_model),
                            "final_norm": cm.rmsnorm_spec(cfg.d_model)}
    if not cfg.tie_embeddings:
        spec["lm_head"] = {"table": P((cfg.vocab_size, cfg.d_model),
                                      ("vocab", "embed"), scale=0.02)}
    for item, payload in execution_plan(cfg):
        if item == "seg":
            seg_idx, blk, count = payload
            sfn = BLOCKS[blk][0]
            one = sfn(cfg)
            spec[f"seg{seg_idx}"] = cm.stack_specs(one, count) if count > 1 else one
    if cfg.shared_block is not None:
        spec["shared"] = BLOCKS[cfg.shared_block][0](cfg)
    return spec


def init(cfg, key, dtype=jnp.float32):
    return cm.init_params(model_spec(cfg), key, dtype)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    cache: Dict[str, Any] = {}
    for item, payload in execution_plan(cfg):
        if item == "seg":
            seg_idx, blk, count = payload
            cfn = BLOCKS[blk][2]
            one = cfn(cfg, batch, max_len, dtype)
            if count > 1:
                cache[f"seg{seg_idx}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (count,) + a.shape), one)
            else:
                cache[f"seg{seg_idx}"] = one
    n_apps = num_shared_apps(cfg)
    if n_apps:
        one = BLOCKS[cfg.shared_block][2](cfg, batch, max_len, dtype)
        cache["shared"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape), one)
    return cache


# ---------------------------------------------------------------------------
# Paged decode caches (cfg.kv_impl == "paged")
# ---------------------------------------------------------------------------
#: Attention block types that own a paged-cache variant. Recurrent families
#: (mamba2 / mlstm / slstm) keep their O(1) per-slot state — paging a
#: constant-size state buys nothing, so they use their ordinary batch cache.
PAGED_CACHE_FNS = {
    "dense": attn.gqa_init_paged_cache,
    "gqa_moe": attn.gqa_init_paged_cache,
    "mla_dense": attn.mla_init_paged_cache,
    "mla_moe": attn.mla_init_paged_cache,
}


def _is_pool_leaf(path) -> bool:
    key = getattr(path[-1], "key", None)
    return isinstance(key, str) and key.endswith("_pool")


def init_paged_cache(cfg, slots: int, num_blocks: int, block_len: int,
                     max_blocks: int, dtype=jnp.bfloat16):
    """Engine-level decode cache for ``kv_impl="paged"``.

    Unlike the dense scheme (one per-request cache per slot, stacked by
    stack_caches), this tree is built once for all slots: attention
    segments hold a *global* block pool per layer plus per-slot block
    tables/lengths, and recurrent segments hold their usual (slots, ...)
    batch state. Decode is a single batch-``slots`` apply — no vmap, the
    pool is shared — and admission writes one slot through
    paged_slot_view / paged_slot_merge. How the decode step attends is
    selected by ``cfg.paged_attend_impl``: the full-table gather or the
    block-walking Pallas kernel (see models/attention.py and
    kernels/paged_attention.py).
    """
    assert cfg.shared_block is None, \
        "paged KV does not support shared-block (zamba2-style) configs yet"
    cache: Dict[str, Any] = {}
    for item, payload in execution_plan(cfg):
        seg_idx, blk, count = payload
        if blk in PAGED_CACHE_FNS:
            one = PAGED_CACHE_FNS[blk](cfg, slots, num_blocks, block_len,
                                       max_blocks, dtype)
        else:
            one = BLOCKS[blk][2](cfg, slots, 1, dtype)
        if count > 1:
            one = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape), one)
        cache[f"seg{seg_idx}"] = one
    return cache


def _paged_seg_iter(cfg, cache):
    """Yields (seg_key, block_type, count, per_slot_axis, seg_cache)."""
    for item, payload in execution_plan(cfg):
        seg_idx, blk, count = payload
        key = f"seg{seg_idx}"
        yield key, blk, count, (1 if count > 1 else 0), cache[key]


def paged_slot_view(cfg, cache, slot) -> Any:
    """Batch-1 view of one slot of a paged cache tree (admission prefill).

    Pool leaves are passed whole (prefill writes blocks into the global
    pool); per-slot leaves (tables, lens, recurrent state) are sliced to
    the slot's row — except recurrent state, which is rebuilt *fresh*: an
    admitted request must not see the previous occupant's state.
    """
    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        if blk in PAGED_CACHE_FNS:
            out[key] = jax.tree_util.tree_map_with_path(
                lambda p, leaf, a=axis: leaf if _is_pool_leaf(p) else
                jax.lax.dynamic_slice_in_dim(leaf, slot, 1, a), seg)
        else:
            one = BLOCKS[blk][2](cfg, 1, 1, jnp.float32)
            if count > 1:
                one = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (count,) + a.shape), one)
            out[key] = jax.tree.map(lambda f, old: f.astype(old.dtype),
                                    one, seg)
    return out


def paged_slot_merge(cfg, cache, view, slot) -> Any:
    """Write an updated batch-1 view (from paged_slot_view + apply) back:
    pools replace the global pools, per-slot rows land in row ``slot``."""
    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        vseg = view[key]
        if blk in PAGED_CACHE_FNS:
            out[key] = jax.tree_util.tree_map_with_path(
                lambda p, full, one, a=axis: one if _is_pool_leaf(p) else
                jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, a), seg, vseg)
        else:
            out[key] = jax.tree.map(
                lambda full, one, a=axis: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, a), seg, vseg)
    return out


def paged_set_slot(cfg, cache, slot, table_row, length) -> Any:
    """Set one slot's block-table row + length across every attention
    segment (admission binds freshly allocated blocks; release resets the
    row to scratch-block zeros so the vacant slot cannot scribble on
    blocks that get reallocated)."""
    def f(p, leaf, count):
        key = getattr(p[-1], "key", None)
        if key == "tables":
            return (leaf.at[:, slot, :].set(table_row) if count > 1
                    else leaf.at[slot, :].set(table_row))
        if key == "lens":
            return (leaf.at[:, slot].set(length) if count > 1
                    else leaf.at[slot].set(length))
        return leaf

    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        if blk in PAGED_CACHE_FNS:
            out[key] = jax.tree_util.tree_map_with_path(
                lambda p, leaf, c=count: f(p, leaf, c), seg)
        else:
            out[key] = seg
    return out


def paged_pool_view(cfg, cache, tables, lens) -> Any:
    """Batch-R view over the global pools with *caller-supplied* block-table
    rows (multi-row batched / chunked prefill).

    Unlike paged_slot_view — which gathers one slot's row out of the cache —
    the table rows and lengths come by value, one per prefill row: ``tables``
    is (R, max_blocks) int32 and ``lens`` is (R,) int32 (the block-aligned
    chunk start; 0 for a fresh admission). Pad rows point every entry at the
    scratch block, so their pool writes land in garbage space and nothing
    they read is ever treated as valid. Pool leaves pass through whole.
    Paged serving is attention-only (the engine enforces this), so every
    segment must be a PAGED_CACHE_FNS block.
    """
    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        if blk not in PAGED_CACHE_FNS:
            raise NotImplementedError(
                "multi-row paged prefill requires an attention-only arch; "
                f"segment {key} is {blk}")

        def f(p, leaf, c=count):
            k = getattr(p[-1], "key", None)
            if k == "tables":
                t = tables
            elif k == "lens":
                t = lens
            else:
                return leaf
            t = t.astype(leaf.dtype)
            return jnp.broadcast_to(t, (c,) + t.shape) if c > 1 else t

        out[key] = jax.tree_util.tree_map_with_path(f, seg)
    return out


def paged_pool_merge(cfg, cache, view) -> Any:
    """Write the pools of an updated batch-R view (from paged_pool_view +
    apply) back into the full cache tree. Only pool leaves carry new state —
    the view's tables/lens were passed by value and are discarded; slot
    registration happens separately through paged_set_rows."""
    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        out[key] = jax.tree_util.tree_map_with_path(
            lambda p, full, one: one if _is_pool_leaf(p) else full,
            seg, view[key])
    return out


def paged_set_rows(cfg, cache, slot_ids, rows, lengths, valid) -> Any:
    """Masked multi-row paged_set_slot: for each prefill row ``r`` with
    ``valid[r]``, set slot ``slot_ids[r]``'s block-table row to ``rows[r]``
    and its length to ``lengths[r]`` across every attention segment.

    Implemented as R one-hot masked selects (R is a static batch dim, tiny)
    rather than a scatter: pad rows (``valid[r] == False``) may alias a live
    slot id without clobbering it, and duplicate ids resolve in row order
    deterministically. slot_ids (R,), rows (R, max_blocks), lengths (R,),
    valid (R,) — all traced.
    """
    R = rows.shape[0]

    def f(p, leaf, count):
        k = getattr(p[-1], "key", None)
        if k not in ("tables", "lens"):
            return leaf
        slots = leaf.shape[1] if count > 1 else leaf.shape[0]
        for r in range(R):
            hit = (jnp.arange(slots) == slot_ids[r]) & valid[r]     # (slots,)
            if k == "tables":
                mask = hit[:, None]                                 # (S, 1)
                upd = rows[r][None, :].astype(leaf.dtype)           # (1, M)
            else:
                mask = hit                                          # (S,)
                upd = lengths[r].astype(leaf.dtype)
            if count > 1:
                mask, upd = mask[None], upd[None]
            leaf = jnp.where(mask, upd, leaf)
        return leaf

    out: Dict[str, Any] = {}
    for key, blk, count, axis, seg in _paged_seg_iter(cfg, cache):
        if blk in PAGED_CACHE_FNS:
            out[key] = jax.tree_util.tree_map_with_path(
                lambda p, leaf, c=count: f(p, leaf, c), seg)
        else:
            out[key] = seg
    return out


def override_cache_length(cache, length) -> Any:
    """Force every position counter ('idx' dense / 'lens' paged) to
    ``length``. Bucketed prefill pads the prompt to a bucket width, so the
    position the cache advanced to overstates the real sequence length;
    the engine pins it back before decoding."""
    def f(p, leaf):
        if getattr(p[-1], "key", None) in ("idx", "lens"):
            return jnp.full_like(leaf, length)
        return leaf
    return jax.tree_util.tree_map_with_path(f, cache)


def stack_caches(caches: List[Any]) -> Any:
    """Stack per-request decode caches into one (slots, ...) pytree.

    Every leaf (KV buffers, recurrent states, the scalar ``idx`` position
    counters) gains a leading slot axis; per-slot scalars like ``idx``
    become (slots,) arrays, which is what lets a vmapped decode advance
    each slot at its own sequence position in a single dispatch."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *caches)


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert_slot_jit(stacked, cache, slot):
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_index_in_dim(
            full, one.astype(full.dtype), slot, 0),
        stacked, cache)


def insert_slot(stacked: Any, cache: Any, slot: int) -> Any:
    """Write one request's cache into slot ``slot`` of a stacked cache tree
    (admission after prefill). Leaf dtypes follow the stacked tree.

    Jitted with the stacked tree donated, so on backends with buffer
    donation the write is in place rather than a full-stack copy per
    admission; the caller must treat the input tree as consumed."""
    return _insert_slot_jit(stacked, cache, jnp.asarray(slot, jnp.int32))


def take_slot(stacked: Any, slot: int) -> Any:
    """Extract slot ``slot`` from a stacked cache tree (inverse of
    insert_slot; used by tests and debugging)."""
    return jax.tree.map(lambda full: full[slot], stacked)


def _remat_wrap(apply_fn, cfg):
    if cfg.remat == "none":
        return apply_fn
    if cfg.remat == "full":
        return jax.checkpoint(apply_fn, static_argnums=(2,))
    if cfg.remat == "dots":
        return jax.checkpoint(
            apply_fn, static_argnums=(2,),
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


def _apply_segment(params_seg, x, cfg, blk, count, cache_seg, positions):
    apply_fn = _remat_wrap(BLOCKS[blk][1], cfg)
    if count == 1:
        x, aux, nc = apply_fn(params_seg, x, cfg, cache_seg, positions)
        return x, aux, nc

    if cache_seg is None:
        def body(carry, p):
            xc, auxc = carry
            xo, aux, _ = apply_fn(p, xc, cfg, None, positions)
            return (xo, auxc + aux), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params_seg)
        return x, aux, None

    def body(carry, pc):
        xc, auxc = carry
        p, c = pc
        xo, aux, nc = apply_fn(p, xc, cfg, c, positions)
        return (xo, auxc + aux), nc

    (x, aux), ncache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (params_seg, cache_seg))
    return x, aux, ncache


def apply(params, batch: Dict[str, jax.Array], cfg, cache=None):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,d)}.

    Returns (logits, aux_loss, new_cache). With cache, positions start at
    cache idx (uniform across layers by construction).
    """
    if cfg.input_mode == "tokens":
        x = cm.embed(params["embed"], batch["tokens"])
    else:
        x = batch["embeds"]
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = x.astype(dtype)
    B, S = x.shape[:2]

    # positions are derived inside attention blocks from their cache idx
    positions = None
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None

    for item, payload in execution_plan(cfg):
        if item == "seg":
            seg_idx, blk, count = payload
            key = f"seg{seg_idx}"
            cseg = cache[key] if cache is not None else None
            x, aux, nc = _apply_segment(params[key], x, cfg, blk, count, cseg,
                                        positions)
            aux_total = aux_total + aux
            if cache is not None:
                new_cache[key] = nc
        else:  # shared application
            app_idx = payload
            apply_fn = BLOCKS[cfg.shared_block][1]
            if cache is not None:
                c_app = jax.tree.map(lambda a: a[app_idx], cache["shared"])
                x, aux, nc = apply_fn(params["shared"], x, cfg, c_app, positions)
                new_cache.setdefault("shared", jax.tree.map(jnp.copy, cache["shared"]))
                new_cache["shared"] = jax.tree.map(
                    lambda full, upd: full.at[app_idx].set(upd),
                    new_cache["shared"], nc)
            else:
                x, aux, _ = apply_fn(params["shared"], x, cfg, None, positions)
            aux_total = aux_total + aux

    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = cm.unembed(head, x.astype(jnp.float32))
    mesh = shd.active_serving_mesh()
    if mesh is not None:
        # The one serving collective: an untied lm_head is vocab-sharded
        # (serve_param_shardings), so the unembed produces vocab-sharded
        # logits; pinning them replicated here forces exactly one
        # all-gather per step, at the logits/vocab boundary, and keeps the
        # sampling tail shard-local + bit-identical to single-device
        # (every shard sees the same concatenated logit row). Tied heads
        # are replicated, so this is a no-op there.
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    return logits, aux_total, new_cache


def loss_fn(params, batch, cfg):
    """Next-token cross entropy (labels = batch['labels']); adds MoE aux.

    The log-softmax datapath is selected by ``cfg.loss_impl`` (exact |
    cordic | cordic_pallas — see repro.train.losses); the backward pass is
    the analytic softmax-minus-onehot form regardless of impl.
    """
    from repro.train import losses  # lazy: keeps models importable standalone

    logits, aux, _ = apply(params, batch, cfg, cache=None)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss = losses.cross_entropy(logits, labels, mask,
                                impl=getattr(cfg, "loss_impl", "exact"))
    total = loss + aux
    return total, {"loss": loss, "aux": aux, "ppl_proxy": loss}
