"""Attention blocks: GQA (optionally biased QKV) and MLA (DeepSeek-V2 style
multi-head latent attention with compressed KV cache).

Both expose:
    *_spec(cfg)                    -> param spec tree (common.P leaves)
    *_apply(params, x, cfg, ...)   -> (y, new_cache)

Training/prefill uses query-chunked causal attention (flash-style memory
behaviour in pure jnp: no S x S materialization beyond a chunk row), which
also keeps the sequence dimension shardable for SP. Decode attends a single
query against the cache; MLA decode uses the absorbed-projection form so the
cache stays compressed (the whole point of MLA).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import common as cm
from repro.models.common import P

NEG_INF = -1e30


def _softmax_fn(impl: str):
    """Row-softmax selected by cfg.softmax_impl.

    "exact"         — jax.nn.softmax (XLA transcendental lowering)
    "cordic_pallas" — fused CORDIC kernel (kernels/softmax_cordic.py):
                      max-subtract + CORDIC-exp + LVC normalize, one VMEM pass
    "cordic_fixed"  — same Q2.14 math in plain jnp (oracle / CPU path)
    """
    if impl in (None, "exact"):
        return jax.nn.softmax
    if impl == "cordic_pallas":
        from repro.kernels import ops as kops  # lazy: kernels optional at import

        return lambda s, axis=-1: kops.softmax(s, axis)
    if impl == "cordic_fixed":
        from repro.cordic_engine import functions as F

        return lambda s, axis=-1: F.softmax(s, axis)  # custom_jvp wrapper
    raise ValueError(f"unknown softmax_impl {impl!r}")


# ---------------------------------------------------------------------------
# Chunked causal attention core (shared by GQA / MLA prefill)
# ---------------------------------------------------------------------------
def _attend_block(q, k, v, q_pos, k_pos, scale, score_dtype: str = "f32",
                  softmax_impl: str = "exact"):
    """q: (B,c,KH,G,D)  k/v: (B,T,KH,D)  -> (B,c,KH,G,D), full-row softmax.

    score_dtype="f32": cast operands to f32 (exact reference; on bf16 caches
    this materializes an f32 copy of K/V — measurably bad at decode scale).
    score_dtype="bf16_mxu": keep operands in their storage dtype and
    accumulate in f32 via preferred_element_type — the MXU-native mode; no
    K/V copies, identical accumulation width.
    """
    if score_dtype == "f32":
        q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q32, k32) * scale
    else:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                       preferred_element_type=jnp.float32) * scale
    mask = (k_pos[None, :] <= q_pos[:, None])[None, None, None]  # (1,1,1,c,T)
    s = jnp.where(mask, s, NEG_INF)
    p = _softmax_fn(softmax_impl)(s, axis=-1)
    if score_dtype == "f32":
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v32)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
    return o


def causal_attention(q, k, v, *, q_offset=0, k_len=None, chunk: int = 1024,
                     score_dtype: str = "f32", softmax_impl: str = "exact"):
    """Causal attention with query chunking.

    q: (B,S,KH,G,D) grouped queries; k/v: (B,T,KH,D).
    q_offset: absolute position of q[0] (decode/prefill continuation).
    k_len: number of valid cache positions (defaults to T).
    """
    B, S, KH, G, D = q.shape
    Dv = v.shape[-1]        # may differ from D (MLA: qk=192, v=128)
    T = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    k_pos = jnp.arange(T)
    if k_len is not None:
        k_pos = jnp.where(jnp.arange(T) < k_len, jnp.arange(T), T + 1)

    if S <= chunk:
        q_pos = q_offset + jnp.arange(S)
        o = _attend_block(q, k, v, q_pos, k_pos, scale, score_dtype, softmax_impl)
        return o.astype(q.dtype)

    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    qr = q.reshape(B, n, chunk, KH, G, D).transpose(1, 0, 2, 3, 4, 5)

    def body(i, qc):
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        return _attend_block(qc, k, v, q_pos, k_pos, scale, score_dtype, softmax_impl)

    o = jax.lax.map(lambda args: body(*args), (jnp.arange(n), qr))
    return o.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KH, G, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV plumbing (kv_impl="paged"): a global block pool per layer plus
# per-slot block tables. Host-side allocation lives in serve/kv_pager.py;
# this is the device side — block-granular writes, table gathers, and a
# per-row-positioned attend that is bit-identical to the dense path.
# ---------------------------------------------------------------------------
def _paged_attend_impl(cfg) -> str:
    """cfg.paged_attend_impl with validation: how a paged decode attends.

    "gather" — assemble the full table gather and attend over dense shapes
               (_pool_gather + _attend_rows / _mla_absorbed_decode): the
               provably bit-identical reference.
    "pallas" — walk the block table in place with the block-walking decode
               kernel (kernels/paged_attention.py): O(block_len) transient
               instead of O(max_len); emitted tokens identical (enforced
               per backend in tests/test_paged_attention.py).  Applies to
               the single-query decode step only — paged *prefill* always
               takes the gather path.
    """
    impl = getattr(cfg, "paged_attend_impl", "gather")
    if impl not in ("gather", "pallas"):
        raise ValueError(f"unknown paged_attend_impl {impl!r}")
    if impl == "pallas" and cfg.score_dtype != "f32":
        # the kernels score in f32; a bf16_mxu gather attend would round
        # differently and the token-identity contract would silently break
        raise ValueError(
            "paged_attend_impl='pallas' supports score_dtype='f32' only "
            f"(got {cfg.score_dtype!r}); use the gather path for "
            "bf16_mxu scoring")
    return impl


def _pool_write(pool, tables, lens, new):
    """Write ``S`` new positions per batch row into the block pool.

    pool: (N, L, *f)  tables: (B, M) int32  lens: (B,) int32  new: (B, S, *f).

    S == 1      — decode: one scattered element per row at logical position
                  ``lens`` (block ``tables[b, lens//L]``, offset ``lens%L``).
                  Vacant slots carry an all-zero table, so their garbage
                  write lands in the reserved scratch block 0.
    S % L == 0  — block-aligned prefill starting at the (block-aligned)
                  position ``lens``: whole blocks are scattered through
                  table entries ``lens//L .. lens//L + S/L``. A fresh
                  admission writes from ``lens == 0`` (the first S/L
                  entries, exactly as before); a chunked-prefill
                  continuation resumes at the chunk frontier. The caller
                  guarantees ``lens % L == 0`` and ``lens + S`` within the
                  table, so the clip mode below never actually clips.
    """
    B, S = new.shape[:2]
    L = pool.shape[1]
    if S == 1:
        blk = jnp.take_along_axis(tables, (lens // L)[:, None], axis=1,
                                  mode="clip")[:, 0]
        return pool.at[blk, lens % L].set(new[:, 0].astype(pool.dtype))
    assert S % L == 0, f"prefill width {S} not a multiple of block_len {L}"
    nb = S // L
    idx = (lens // L)[:, None] + jnp.arange(nb)[None, :]        # (B, nb)
    blk = jnp.take_along_axis(tables, idx, axis=1, mode="clip")
    blocks = new.reshape((B * nb, L) + new.shape[2:]).astype(pool.dtype)
    return pool.at[blk.reshape(-1)].set(blocks)


def _pool_write_quant(pool, scale_pool, tables, lens, new, spec):
    """_pool_write for a quantized pool: quantize-at-write against
    per-block-per-head amax scales. Returns (pool, scale_pool) updated.

    pool: (N, L, KH, hd) integer codes; scale_pool: (N, 1, KH, 1) f32.

    S % L == 0  — prefill: each written block gets a fresh scale from its
                  own per-head amax (bucket padding rides along in the
                  amax — deterministic, and padded positions are masked at
                  attend time anyway), codes and scales scattered through
                  the same table entries.
    S == 1      — decode append into a possibly part-filled block: the
                  block scale is a running per-head max. A fresh block
                  (lens % L == 0) takes the new element's scale outright
                  (the old pool entry is a previous tenant's); otherwise
                  the scale can only grow, and when it does the block's
                  existing codes are re-quantized by the ratio old/new —
                  ratio <= 1, so the rescale itself never clips. When the
                  scale is unchanged the ratio is exactly 1.0 and integer
                  codes survive the round-trip bit-for-bit, which keeps
                  append-only decode deterministic across TP layouts.

    Vacant slots (all-zero tables) land both writes in scratch block 0,
    same as _pool_write — scratch contents are garbage by contract and
    masked at every read.
    """
    from repro.core import kv_quant as kvq  # lazy: quant optional at import

    B, S = new.shape[:2]
    L = pool.shape[1]
    fmt = spec.fmt
    if S == 1:
        blk = jnp.take_along_axis(tables, (lens // L)[:, None], axis=1,
                                  mode="clip")[:, 0]
        elem = new[:, 0].astype(jnp.float32)                     # (B, KH, hd)
        need = kvq.scale_for_amax(
            jnp.max(jnp.abs(elem), axis=-1)[:, None, :, None], spec)
        old = scale_pool[blk]                                    # (B,1,KH,1)
        fresh = (lens % L == 0)[:, None, None, None]
        new_scale = jnp.where(fresh, need, jnp.maximum(old, need))
        # re-quantize the block's existing codes to the (possibly grown)
        # scale; ratio <= 1 for live blocks so the clip below is only a
        # guard against stale garbage under a fresh block's ratio > 1
        ratio = old / new_scale
        cur = pool[blk].astype(jnp.float32)                      # (B,L,KH,hd)
        resc = jnp.clip(jnp.round(cur * ratio), fmt.min_int,
                        fmt.max_int).astype(pool.dtype)
        code = kvq.quantize(elem, spec, new_scale[:, 0])         # (B, KH, hd)
        pool = pool.at[blk].set(resc).at[blk, lens % L].set(code)
        return pool, scale_pool.at[blk].set(new_scale)
    assert S % L == 0, f"prefill width {S} not a multiple of block_len {L}"
    nb = S // L
    idx = (lens // L)[:, None] + jnp.arange(nb)[None, :]         # (B, nb)
    blk = jnp.take_along_axis(tables, idx, axis=1, mode="clip").reshape(-1)
    blocks = new.reshape((B * nb, L) + new.shape[2:]).astype(jnp.float32)
    scales = kvq.block_scale(blocks, spec)                       # (B*nb,1,KH,1)
    codes = kvq.quantize(blocks, spec, scales)
    return pool.at[blk].set(codes), scale_pool.at[blk].set(scales)


def _pool_gather(pool, tables):
    """Assemble each row's logical KV buffer from its block table:
    (N, L, *f) pool + (B, M) tables -> (B, M*L, *f). Entries past the
    slot's real length point at scratch/stale blocks and are masked by the
    caller (zero softmax weight, so their values never contribute).

    The gather spans the FULL table (M*L == max_len positions), trading
    transient working set for exactness: the attend then runs over the
    same shapes as the dense path, which is what keeps paged decode
    bit-identical to dense. Paging therefore shrinks *resident* KV (the
    pool) but not the per-step gather; ``cfg.paged_attend_impl="pallas"``
    swaps the decode step for the block-walking kernel in
    kernels/paged_attention.py, whose transient is O(block_len) instead."""
    B, M = tables.shape
    L = pool.shape[1]
    return pool[tables].reshape((B, M * L) + pool.shape[2:])


def _pool_gather_dequant(pool, scale_pool, tables, spec):
    """_pool_gather for a quantized pool: gather codes and per-block
    scales through the same table, then CORDIC-dequantize elementwise.

    Returns (B, M*L, KH, hd) f32. This is the single dequant definition
    both the engine's gather attend and kernels/ref.py's oracle call, so
    the reference cannot drift from production: (code, scale) pairs are
    identical to what the Pallas kernel sees per block, and
    kv_quant.dequantize is elementwise-deterministic."""
    from repro.core import kv_quant as kvq  # lazy: quant optional at import

    L = pool.shape[1]
    codes = _pool_gather(pool, tables)                   # (B, M*L, KH, hd)
    scales = jnp.repeat(_pool_gather(scale_pool, tables),  # (B, M, KH, 1)
                        L, axis=1)                       # (B, M*L, KH, 1)
    return kvq.dequantize(codes, spec, scales)


def _attend_rows(q, k, v, q_pos, k_len, scale, score_dtype: str = "f32",
                 softmax_impl: str = "exact"):
    """_attend_block with per-batch-row positions: q: (B,S,KH,G,D),
    k/v: (B,T,KH,Dv), q_pos: (B,S) absolute query positions, k_len: (B,)
    valid key counts. Identical einsum contractions to _attend_block —
    only the mask gains a batch axis — so a paged decode step produces
    bit-identical outputs to the dense (vmapped per-slot) decode."""
    if score_dtype == "f32":
        q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q32, k32) * scale
    else:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                       preferred_element_type=jnp.float32) * scale
    T = k.shape[1]
    k_pos = jnp.arange(T)
    mask = ((k_pos[None, None, :] < k_len[:, None, None])
            & (k_pos[None, None, :] <= q_pos[:, :, None]))      # (B,S,T)
    s = jnp.where(mask[:, None, None], s, NEG_INF)              # (B,h,g,S,T)
    p = _softmax_fn(softmax_impl)(s, axis=-1)
    if score_dtype == "f32":
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v32)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
    return o


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def _padded_heads(cfg):
    """(H', KH') after optional padding to a TP-friendly multiple.

    Padding head counts (MaxText-style) keeps the head dimension shardable
    on wide model axes when the arch's native count is not divisible
    (e.g. 40 q heads / 8 kv heads on 16-way TP). KV heads pad up to the
    multiple and q heads follow as H' = KH' * G (G = original group size),
    preserving the kv-major q->kv mapping for the real heads.

    Forward exactness: padded k/v projections are zero, so padded heads
    attend uniformly over zero values -> zero output -> zero contribution
    through wo, whatever its padded rows hold (asserted in tests). Under
    training the padded rows become extra capacity (documented in DESIGN).
    """
    H, KH = cfg.num_heads, cfg.num_kv_heads
    p = cfg.pad_heads_to
    if p and p > 1:
        G = H // KH
        KH = -(-KH // p) * p
        H = KH * G
    return H, KH


def gqa_spec(cfg) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    H, KH = _padded_heads(cfg)
    spec = {
        "wq": P((d, H, hd), ("embed", "heads", None)),
        "wk": P((d, KH, hd), ("embed", "kv_heads", None)),
        "wv": P((d, KH, hd), ("embed", "kv_heads", None)),
        "wo": P((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((H, hd), ("heads", None), init="zeros")
        spec["bk"] = P((KH, hd), ("kv_heads", None), init="zeros")
        spec["bv"] = P((KH, hd), ("kv_heads", None), init="zeros")
    return spec


def gqa_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    _, KH = _padded_heads(cfg)
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, KH, hd), dtype),
        "v": jnp.zeros((batch, max_len, KH, hd), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def gqa_init_paged_cache(cfg, slots: int, num_blocks: int, block_len: int,
                         max_blocks: int, dtype=jnp.bfloat16):
    """Paged decode cache for one GQA layer: a global (num_blocks,
    block_len, KH, hd) K/V pool shared by every slot, per-slot block
    tables (slots, max_blocks) into it, and per-slot lengths. Block 0 is
    the scratch block (kv_pager.SCRATCH_BLOCK): vacant slots point at it.

    With ``cfg.kv_quant`` != "none" the K/V pools store integer codes in
    the format's lane dtype and two extra leaves carry the per-block
    per-head f32 scales, shape (num_blocks, 1, KH, 1) — the "_pool"
    suffix routes them through the same view/merge plumbing as the code
    pools, and dim -2 is KH so the TP kv-heads sharding rule covers them.
    Scales start at 1.0 (scratch/unwritten blocks dequantize to zero)."""
    from repro.core import kv_quant as kvq  # lazy: quant optional at import

    _, KH = _padded_heads(cfg)
    hd = cfg.head_dim
    spec = kvq.spec_for(getattr(cfg, "kv_quant", "none"))
    kv_dtype = dtype if spec is None else spec.code_dtype
    cache = {
        "k_pool": jnp.zeros((num_blocks, block_len, KH, hd), kv_dtype),
        "v_pool": jnp.zeros((num_blocks, block_len, KH, hd), kv_dtype),
        "tables": jnp.zeros((slots, max_blocks), jnp.int32),
        "lens": jnp.zeros((slots,), jnp.int32),
    }
    if spec is not None:
        cache["k_scale_pool"] = jnp.ones((num_blocks, 1, KH, 1), jnp.float32)
        cache["v_scale_pool"] = jnp.ones((num_blocks, 1, KH, 1), jnp.float32)
    return cache


def _gqa_paged_apply(params, x, cfg, cache, q, k, v):
    """Paged continuation of gqa_apply (cache holds a block pool).

    Decode (S==1): every row writes its new K/V element through its block
    table, then attends against the table-gathered (B, M*L, KH, hd) buffer
    masked past the per-slot length — or, with
    ``cfg.paged_attend_impl="pallas"``, walks its live blocks in place via
    the block-walking kernel (no gather is materialized). Prefill
    (S==bucket width, one row): whole-block writes, then the
    gather-and-attend — shape-identical to the dense path's full-cache
    attend, which keeps logits bit-equal.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim
    KH = k.shape[2]
    G = q.shape[2] // KH
    lens, tables = cache["lens"], cache["tables"]

    positions = lens[:, None] + jnp.arange(S)[None, :]          # (B,S)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)

    from repro.core import kv_quant as kvq  # lazy: quant optional at import
    spec = kvq.spec_for(getattr(cfg, "kv_quant", "none"))
    if spec is None:
        kp = _pool_write(cache["k_pool"], tables, lens, k)
        vp = _pool_write(cache["v_pool"], tables, lens, v)
        ks = vs = None
    else:
        kp, ks = _pool_write_quant(cache["k_pool"], cache["k_scale_pool"],
                                   tables, lens, k, spec)
        vp, vs = _pool_write_quant(cache["v_pool"], cache["v_scale_pool"],
                                   tables, lens, v, spec)
    qg = q.reshape(B, S, KH, G, hd)

    if S == 1 and _paged_attend_impl(cfg) == "pallas":
        # Block-walking decode kernel: never materializes the table gather.
        from repro.kernels import ops as kops  # lazy: kernels optional
        from repro.kernels import paged_attention as PA

        attend = functools.partial(
            kops.paged_attend_gqa, scale=1.0 / np.sqrt(hd),
            softmax_impl=getattr(cfg, "softmax_impl", "exact"),
            kv_dtype=x.dtype,
            kv_quant=getattr(cfg, "kv_quant", "none"))
        mesh = shd.active_serving_mesh()
        if mesh is not None:
            # pallas_call is opaque to GSPMD — run the kernel shard-local
            # over the model axis: per-shard KH slice of q and the pools,
            # tables/lens replicated, no collective inside attention.
            # ServeEngine init guarantees KH % tp == 0 on this path.
            o = PA.shard_local_gqa(attend, mesh, qg[:, 0], kp, vp,
                                   tables, lens + 1,
                                   k_scale_pool=ks,
                                   v_scale_pool=vs)[:, None]
        else:
            o = attend(qg[:, 0], kp, vp, tables, lens + 1,
                       k_scale_pool=ks, v_scale_pool=vs)[:, None]
    else:
        if spec is None:
            k_full = _pool_gather(kp, tables).astype(x.dtype)
            v_full = _pool_gather(vp, tables).astype(x.dtype)
        else:
            k_full = _pool_gather_dequant(kp, ks, tables, spec).astype(x.dtype)
            v_full = _pool_gather_dequant(vp, vs, tables, spec).astype(x.dtype)
        o = _attend_rows(qg, k_full, v_full, positions, lens + S,
                         1.0 / np.sqrt(hd), cfg.score_dtype,
                         getattr(cfg, "softmax_impl", "exact"))
    o = o.astype(qg.dtype).reshape(B, S, KH * G, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    new_cache = {"k_pool": kp, "v_pool": vp, "tables": tables,
                 "lens": lens + S}
    if spec is not None:
        new_cache["k_scale_pool"] = ks
        new_cache["v_scale_pool"] = vs
    return y, new_cache


def gqa_apply(params, x, cfg, *, cache: Optional[dict] = None,
              positions: Optional[jax.Array] = None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B,S,d). With cache: writes S new positions at cache['idx']."""
    B, S, d = x.shape
    hd = cfg.head_dim
    if cfg.pad_heads_to:
        # padded layout keeps the kv-major grouping: H' = KH' * G_orig
        KH = _padded_heads(cfg)[1]
        G = cfg.num_heads // cfg.num_kv_heads
        H = KH * G
    else:
        H, KH = cfg.num_heads, cfg.num_kv_heads
        G = H // KH

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)

    if cache is not None and "k_pool" in cache:
        return _gqa_paged_apply(params, x, cfg, cache, q, k, v)

    if positions is None:
        offset = cache["idx"] if cache is not None else 0
        positions = offset + jnp.arange(S)[None, :]
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        from jax.sharding import PartitionSpec as PS

        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache["idx"], 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache["idx"], 0, 0))
        if cfg.kv_shard == "seq_model":
            # flash-decode SP: pin cache seq dim to the model axis; the
            # softmax/output reductions over seq become small all-reduces
            cands = [PS(("pod", "data"), "model", None, None),
                     PS("data", "model", None, None),
                     PS(None, "model", None, None)]
            ck = cm.maybe_shard(ck, *cands)
            cv = cm.maybe_shard(cv, *cands)
        new_cache = {"k": ck, "v": cv, "idx": cache["idx"] + S}
        k_full, v_full = ck.astype(x.dtype), cv.astype(x.dtype)
        k_len = cache["idx"] + S
        q_offset = cache["idx"]
    else:
        new_cache, k_full, v_full, k_len, q_offset = None, k, v, None, 0

    qg = q.reshape(B, S, KH, G, hd)
    o = causal_attention(qg, k_full, v_full, q_offset=q_offset, k_len=k_len,
                         chunk=cfg.attn_chunk, score_dtype=cfg.score_dtype,
                         softmax_impl=getattr(cfg, "softmax_impl", "exact"))
    o = o.reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def mla_spec(cfg) -> Dict[str, Any]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    return {
        "wq": P((d, H, m.qk_nope_dim + m.qk_rope_dim), ("embed", "heads", None)),
        "wkv_a": P((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", None)),
        "kv_norm": cm.rmsnorm_spec(m.kv_lora_rank),
        "wkv_b": P((m.kv_lora_rank, H, m.qk_nope_dim + m.v_dim),
                   (None, "heads", None)),
        "wo": P((H, m.v_dim, d), ("heads", None, "embed")),
    }


def mla_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _mla_project_q(params, x, cfg, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_compress(params, x, cfg, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dk->bsk", x, params["wkv_a"].astype(x.dtype))
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = cm.rmsnorm(params["kv_norm"], c_kv)
    k_rope = cm.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_init_paged_cache(cfg, slots: int, num_blocks: int, block_len: int,
                         max_blocks: int, dtype=jnp.bfloat16):
    """Paged decode cache for one MLA layer: global block pools over the
    *compressed* latent (c_kv) and the shared rope key, plus per-slot
    block tables/lengths (layout mirrors gqa_init_paged_cache)."""
    if getattr(cfg, "kv_quant", "none") not in (None, "none"):
        # quantizing the compressed latent is a different design (error
        # amplifies through the absorbed up-projection); the engine
        # rejects this combination at init, this guard covers direct users
        raise ValueError("kv_quant applies to GQA paged pools only; MLA "
                         "layers store the compressed latent unquantized")
    m = cfg.mla
    return {
        "c_kv_pool": jnp.zeros((num_blocks, block_len, m.kv_lora_rank), dtype),
        "k_rope_pool": jnp.zeros((num_blocks, block_len, m.qk_rope_dim), dtype),
        "tables": jnp.zeros((slots, max_blocks), jnp.int32),
        "lens": jnp.zeros((slots,), jnp.int32),
    }


def _mla_absorbed_decode(q_nope, q_rope, cc, cr, wk_b, wv_b, scale, valid,
                         score_dtype, softmax_impl):
    """Absorbed-form single-query MLA decode against a compressed buffer:
    q_nope/q_rope (B,1,H,·), cc/cr (B,T,·), ``valid`` broadcastable to the
    (B,H,1,T) score mask. One implementation shared by the dense and paged
    branches — only the mask differs — so the two stay bit-identical by
    construction. Returns o (B,1,H,v_dim) in f32."""
    q_eff = jnp.einsum("bshk,lhk->bshl", q_nope, wk_b)          # (B,1,H,L)
    if score_dtype == "f32":
        s = (jnp.einsum("bshl,btl->bhst", q_eff.astype(jnp.float32),
                        cc.astype(jnp.float32))
             + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                          cr.astype(jnp.float32))) * scale
    else:
        s = (jnp.einsum("bshl,btl->bhst", q_eff, cc.astype(q_eff.dtype),
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshk,btk->bhst", q_rope, cr.astype(q_rope.dtype),
                          preferred_element_type=jnp.float32)) * scale
    s = jnp.where(valid, s, NEG_INF)
    p = _softmax_fn(softmax_impl)(s, axis=-1)
    if score_dtype == "f32":
        o_lat = jnp.einsum("bhst,btl->bshl", p, cc.astype(jnp.float32))
    else:
        o_lat = jnp.einsum("bhst,btl->bshl", p.astype(cc.dtype), cc,
                           preferred_element_type=jnp.float32)
    return jnp.einsum("bshl,lhv->bshv", o_lat, wv_b.astype(jnp.float32))


def _mla_decompress_kq(q_nope, q_rope, cc, cr, m, H, wk_b, wv_b):
    """Decompress a (compressed-latent, rope-key) buffer into full k/v and
    build the grouped query for the chunked/row attends — the prefill
    counterpart of _mla_absorbed_decode, shared by the dense and paged
    branches so the two stay bit-identical by construction."""
    dtype = q_nope.dtype
    B, T = cc.shape[:2]
    S = q_nope.shape[1]
    k_nope = jnp.einsum("btl,lhk->bthk", cc.astype(dtype), wk_b)
    v = jnp.einsum("btl,lhv->bthv", cc.astype(dtype), wv_b)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(cr[:, :, None, :].astype(dtype),
                                  (B, T, H, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, jnp.broadcast_to(
        q_rope, (B, S, H, m.qk_rope_dim))], axis=-1)
    qg = q.reshape(B, S, H, 1, m.qk_nope_dim + m.qk_rope_dim)
    return k, v, qg


def _mla_paged_apply(params, x, cfg, cache):
    """Paged MLA: block-pool writes of the compressed latent + rope key,
    then absorbed decode (S==1) or decompress-and-attend prefill against
    the table-gathered buffer, masked past each row's length. Einsums
    mirror the dense branches exactly (bit-identical decode)."""
    B, S, d = x.shape
    m, H = cfg.mla, cfg.num_heads
    lens, tables = cache["lens"], cache["tables"]
    positions = lens[:, None] + jnp.arange(S)[None, :]

    q_nope, q_rope = _mla_project_q(params, x, cfg, positions)
    c_kv, k_rope = _mla_compress(params, x, cfg, positions)

    cp = _pool_write(cache["c_kv_pool"], tables, lens, c_kv)
    rp = _pool_write(cache["k_rope_pool"], tables, lens, k_rope)

    wkv_b = params["wkv_b"].astype(x.dtype)
    wk_b, wv_b = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim:]
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    k_len = lens + S

    if S == 1:
        if _paged_attend_impl(cfg) == "pallas":
            # Block-walking absorbed decode: the kernel accumulates the
            # latent output; wv_b projection mirrors _mla_absorbed_decode.
            from repro.kernels import ops as kops  # lazy: kernels optional
            from repro.kernels import paged_attention as PA

            q_eff = jnp.einsum("bshk,lhk->bshl", q_nope, wk_b)
            attend = functools.partial(
                kops.paged_attend_mla, scale=scale,
                softmax_impl=getattr(cfg, "softmax_impl", "exact"))
            mesh = shd.active_serving_mesh()
            if mesh is not None:
                # Shard-local over the model axis: per-shard H slice of
                # the absorbed queries against the replicated latent/rope
                # pools (they carry no head axis). Engine init guarantees
                # H % tp == 0 on this path.
                o_lat = PA.shard_local_mla(attend, mesh, q_eff[:, 0],
                                           q_rope[:, 0], cp, rp, tables,
                                           lens + 1)
            else:
                o_lat = attend(q_eff[:, 0], q_rope[:, 0], cp, rp, tables,
                               lens + 1)
            o = jnp.einsum("bshl,lhv->bshv", o_lat[:, None],
                           wv_b.astype(jnp.float32))
        else:
            # Absorbed decode against the gathered buffer; per-row mask.
            cc = _pool_gather(cp, tables)                       # (B,T,R)
            cr = _pool_gather(rp, tables)                       # (B,T,rope)
            T = cc.shape[1]
            valid = (jnp.arange(T)[None, :] < k_len[:, None])[:, None, None, :]
            o = _mla_absorbed_decode(q_nope, q_rope, cc, cr, wk_b, wv_b,
                                     scale, valid, cfg.score_dtype,
                                     getattr(cfg, "softmax_impl", "exact"))
    else:
        # Prefill: decompress the gathered buffer, per-row-positioned attend
        # (always the gather path — paged_attend_impl selects decode only).
        cc = _pool_gather(cp, tables)                           # (B,T,R)
        cr = _pool_gather(rp, tables)                           # (B,T,rope)
        k, v, qg = _mla_decompress_kq(q_nope, q_rope, cc, cr, m, H,
                                      wk_b, wv_b)
        o = _attend_rows(qg, k, v, positions, k_len, scale,
                         softmax_impl=getattr(cfg, "softmax_impl", "exact"))
        o = o.astype(qg.dtype).reshape(B, S, H, m.v_dim)

    y = jnp.einsum("bshv,hvd->bsd", o.astype(x.dtype), params["wo"].astype(x.dtype))
    new_cache = {"c_kv_pool": cp, "k_rope_pool": rp, "tables": tables,
                 "lens": k_len}
    return y, new_cache


def mla_apply(params, x, cfg, *, cache: Optional[dict] = None,
              positions: Optional[jax.Array] = None):
    """MLA attention. Prefill decompresses K/V per chunk; decode uses the
    absorbed form against the compressed cache. A paged cache (block pool
    + tables, see mla_init_paged_cache) takes the paged path instead."""
    B, S, d = x.shape
    m, H = cfg.mla, cfg.num_heads
    if cache is not None and "c_kv_pool" in cache:
        return _mla_paged_apply(params, x, cfg, cache)
    offset = cache["idx"] if cache is not None else 0
    if positions is None:
        positions = offset + jnp.arange(S)[None, :]

    q_nope, q_rope = _mla_project_q(params, x, cfg, positions)
    c_kv, k_rope = _mla_compress(params, x, cfg, positions)

    if cache is not None:
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                                          (0, cache["idx"], 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                                          (0, cache["idx"], 0))
        if cfg.kv_shard == "seq_model":
            from jax.sharding import PartitionSpec as PS

            cands3 = [PS(("pod", "data"), "model", None),
                      PS("data", "model", None), PS(None, "model", None)]
            cc = cm.maybe_shard(cc, *cands3)
            cr = cm.maybe_shard(cr, *cands3)
        new_cache = {"c_kv": cc, "k_rope": cr, "idx": cache["idx"] + S}
    else:
        new_cache = None

    wkv_b = params["wkv_b"].astype(x.dtype)
    wk_b, wv_b = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim:]
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    if cache is not None and S == 1:
        # Absorbed decode: score against the compressed cache directly.
        T = cc.shape[1]
        valid = (jnp.arange(T) < cache["idx"] + 1)[None, None, None, :]
        o = _mla_absorbed_decode(q_nope, q_rope, cc, cr, wk_b, wv_b, scale,
                                 valid, cfg.score_dtype,
                                 getattr(cfg, "softmax_impl", "exact"))
    else:
        # Prefill / train: decompress K,V and run the chunked causal core.
        src_c = cc if cache is not None else c_kv
        src_r = cr if cache is not None else k_rope
        k, v, qg = _mla_decompress_kq(q_nope, q_rope, src_c, src_r, m, H,
                                      wk_b, wv_b)
        k_len = (cache["idx"] + S) if cache is not None else None
        o = causal_attention(qg, k, v, q_offset=offset, k_len=k_len,
                             chunk=cfg.attn_chunk,
                             softmax_impl=getattr(cfg, "softmax_impl", "exact"))
        o = o.reshape(B, S, H, m.v_dim)

    y = jnp.einsum("bshv,hvd->bsd", o.astype(x.dtype), params["wo"].astype(x.dtype))
    return y, new_cache
