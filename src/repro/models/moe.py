"""Mixture-of-Experts FFN: GShard-style einsum dispatch/combine with a
capacity factor — the formulation that shards cleanly under pjit (experts on
the "model" axis become expert parallelism; the dispatch einsums lower to
all-to-all / all-gather collectives, visible in the dry-run HLO).

Supports DeepSeek-V2-style shared experts + routed top-k with softmax
scoring, and a sigmoid-scored router option (DeepSeek-V3 style) that routes
the router's gate through the CORDIC sigmoid — the paper's technique applied
to MoE gating (beyond-paper integration).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import get_activation
from repro.models.common import P


def moe_spec(cfg) -> Dict[str, Any]:
    m, d = cfg.moe, cfg.d_model
    spec = {
        "router": P((d, m.num_experts), ("embed", "experts"), scale=0.02),
        "w_gate": P((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "mlp")),
        "w_up": P((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "mlp")),
        "w_down": P((m.num_experts, m.d_ff_expert, d), ("experts", "mlp", "embed")),
    }
    if m.num_shared_experts:
        dsh = m.d_ff_expert * m.num_shared_experts
        spec["shared"] = {
            "w_gate": P((d, dsh), ("embed", "mlp")),
            "w_up": P((d, dsh), ("embed", "mlp")),
            "w_down": P((dsh, d), ("mlp", "embed")),
        }
    return spec


def _router_scores(params, x, cfg):
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if m.router_score == "softmax":
        return jax.nn.softmax(logits, axis=-1), logits
    if m.router_score == "sigmoid":
        # V3-style sigmoid scoring; CORDIC impl when configured.
        sig = get_activation("sigmoid", cfg.act_impl, range_mode="reduce")
        s = sig(logits)
        return s / (jnp.sum(s, axis=-1, keepdims=True) + 1e-9), logits
    raise ValueError(m.router_score)


def moe_apply(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y, aux_loss). GShard dispatch with capacity factor.

    Tokens are dispatched in per-sequence groups (g = batch dim): the
    expert capacity is C = ceil(S * K * cap / E) *per group*, so the
    one-hot dispatch/combine einsums stay O(S * E * C) per group — the
    GShard/Mesh-TF formulation. (Computing capacity over the global token
    count makes the dispatch einsum quadratic in tokens — measured as a
    330x compute blow-up in the dry-run before this grouping.)
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    xg = x                                                  # (G=B, S, d)

    scores, logits = _router_scores(params, xg.reshape(B * S, d), cfg)
    scores = scores.reshape(B, S, E)
    gate_vals, gate_idx = jax.lax.top_k(scores, K)          # (G,S,K)
    if m.normalize_gates:
        gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    C = int(np.ceil(S * K * m.capacity_factor / E))
    C = max(C, 4)

    # position of each (token, k) within its expert queue, per group
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)   # (G,S,K,E)
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1               # (G,S*K,E)
    pos = pos.reshape(B, S, K, E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)               # (G,S,K)
    keep = (pos_in_e < C) & (pos_in_e >= 0)

    # dispatch/combine tensors (G,S,K,E,C)
    disp = (jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1,
                             dtype=x.dtype)[..., None, :-1])
    combine = disp * gate_vals[..., None, None].astype(x.dtype)
    disp_t = jnp.sum(disp, axis=2)                          # (G,S,E,C)
    combine_t = jnp.sum(combine, axis=2)

    # expert compute (einsum formulation; experts sharded -> EP all-to-all)
    xe = jnp.einsum("gsec,gsd->gecd", disp_t, xg)           # (G,E,C,d)
    act = get_activation("silu", cfg.act_impl, range_mode="reduce")
    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
    h = act(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    y = jnp.einsum("gsec,gecd->gsd", combine_t, ye)         # (G,S,d)

    # load-balancing aux loss (Switch/GShard form)
    me = jnp.mean(scores, axis=(0, 1))                      # (E,)
    ce = jnp.mean(jnp.sum(disp_t, axis=-1), axis=(0, 1))    # (E,)
    aux = E * jnp.sum(me * ce) * m.aux_loss_coef

    if m.num_shared_experts:
        sp = params["shared"]
        g = jnp.einsum("gsd,df->gsf", xg, sp["w_gate"].astype(x.dtype))
        u = jnp.einsum("gsd,df->gsf", xg, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("gsf,fd->gsd", act(g) * u,
                           sp["w_down"].astype(x.dtype))

    return y, aux
