"""Deterministic, resumable, shardable synthetic-LM data pipeline.

Real deployments swap `SyntheticLMDataset` for a tokenized corpus reader;
every other property the trainer relies on is provided here:

* **Determinism** — batch t is a pure function of (seed, step), so restarts
  reproduce the exact token stream (bitwise), which makes checkpoint-resume
  testable and straggler-failover deterministic.
* **Skip-ahead resume** — `state = dict(step=...)`: O(1) seek, no replay.
* **Sharding** — `global_batch` is laid out host-major; `local_slice` maps a
  (process_index, process_count) pair to its contiguous batch rows, matching
  the (pod, data) mesh axes the trainer shards batches over.
* **Structured stream** — the synthetic stream is a mixture of repeated
  n-grams + noise with per-document Zipf unigrams, so a real LM *can learn
  it* (loss drops well below uniform), which the examples rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    ngram_order: int = 3
    noise_prob: float = 0.1


class SyntheticLMDataset:
    """Markov-chain synthetic corpus with deterministic random access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed sparse transition structure: each state has 4 likely successors
        self._succ = root.integers(0, v, size=(v, 4))
        self._zipf = 1.0 / np.arange(1, v + 1)
        self._zipf /= self._zipf.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a given step (pure function of (seed, step))."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, v, B)
        noise = rng.random((B, S)) < cfg.noise_prob
        branch = rng.integers(0, 4, (B, S))
        rand_tok = rng.integers(0, v, (B, S))
        for t in range(S):
            nxt = self._succ[toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def local_slice(self, batch: Dict[str, np.ndarray], process_index: int,
                    process_count: int) -> Dict[str, np.ndarray]:
        B = self.cfg.global_batch
        assert B % process_count == 0
        per = B // process_count
        lo = process_index * per
        return {k: v[lo: lo + per] for k, v in batch.items()}


class DataIterator:
    """Stateful iterator with O(1) checkpointable state."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0):
        self.dataset = dataset
        self.step = start_step

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.dataset.batch_at(self.step)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
