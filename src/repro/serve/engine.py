"""Batched serving engine: prefill + decode steps and a slot-based
continuous-batching loop.

`make_prefill_step`/`make_decode_step` are the functions the dry-run lowers
for the decode shapes (decode_32k / long_500k): one new token against a KV /
recurrent-state cache. The engine runs them on whatever mesh it is given;
requests are packed into fixed batch slots and refilled as sequences finish
(continuous batching at step granularity).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf


def make_prefill_step(cfg):
    def prefill(params, cache, batch):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        return logits[:, -1], cache
    return prefill


def make_decode_step(cfg, *, greedy: bool = True, temperature: float = 1.0):
    def decode(params, cache, tokens, rng=None):
        """tokens: (B,1) int32 (or (B,1,d) embeds). Returns next token ids.

        Sampling decode consumes `rng` — the caller threads a fresh split
        per step (see ServeEngine.step); reusing one key would make every
        step/batch draw the same sample.
        """
        batch = ({"tokens": tokens} if cfg.input_mode == "tokens"
                 else {"embeds": tokens})
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            if rng is None:
                raise ValueError("sampling decode requires an rng key")
            nxt = jax.random.categorical(
                rng, last / temperature).astype(jnp.int32)
        return nxt, cache
    return decode


def make_score_step(cfg):
    """Teacher-forced per-token log-probs of a prompt.

    The log-softmax datapath follows ``cfg.loss_impl`` (exact | cordic |
    cordic_pallas — repro.train.losses), so served log-prob scoring uses
    the same CORDIC exp/log legs as the training loss.
    """
    from repro.train import losses

    logp_fn = losses.log_softmax_fn(getattr(cfg, "loss_impl", "exact"))

    def score(params, batch):
        """batch: {"tokens": (B,S)}. Returns (B,S-1) log p(token_t | <t)."""
        logits, _, _ = tf.apply(params, batch, cfg, cache=None)
        logp = logp_fn(logits[:, :-1])
        nxt = batch["tokens"][:, 1:]
        return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]

    return score


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching on top of prefill/decode steps.

    Static batch of `slots`; each slot holds one request; finished slots are
    refilled from the queue between decode steps (per-slot cache reset via
    masking — slot caches are re-prefilled on admission).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_token: Optional[int] = None, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 softmax_impl: Optional[str] = None,
                 loss_impl: Optional[str] = None):
        assert cfg.input_mode == "tokens", "engine serves token LMs"
        if softmax_impl is not None:
            cfg = dataclasses.replace(cfg, softmax_impl=softmax_impl)
        if loss_impl is not None:
            cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_token
        self.greedy = greedy
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(
            make_decode_step(cfg, greedy=greedy, temperature=temperature))
        self._score = jax.jit(make_score_step(cfg))
        self._queue: List[Request] = []
        self._active: List[Optional[Request]] = [None] * slots
        self._caches = [tf.init_cache(cfg, 1, max_len, jnp.float32)
                        for _ in range(slots)]
        self._next_tok = np.zeros((slots, 1), np.int32)

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def score(self, prompt: np.ndarray) -> np.ndarray:
        """(S,) int32 prompt -> (S-1,) per-token log-probs (teacher-forced),
        through the cfg.loss_impl-selected log-softmax datapath."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        return np.asarray(self._score(self.params, {"tokens": toks})[0])

    def _next_key(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def _admit(self) -> None:
        for s in range(self.slots):
            if self._active[s] is None and self._queue:
                req = self._queue.pop(0)
                self._active[s] = req
                cache = tf.init_cache(self.cfg, 1, self.max_len, jnp.float32)
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = self._prefill(self.params, cache,
                                              {"tokens": toks})
                self._caches[s] = cache
                if self.greedy:
                    first = int(jnp.argmax(logits[0]))
                else:
                    first = int(jax.random.categorical(
                        self._next_key(), logits[0] / self.temperature))
                self._next_tok[s, 0] = first
                req.out.append(first)

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        active = [s for s in range(self.slots) if self._active[s] is not None]
        if not active:
            return 0
        for s in active:
            req = self._active[s]
            rng = None if self.greedy else self._next_key()
            nxt, cache = self._decode(self.params, self._caches[s],
                                      jnp.asarray(self._next_tok[s:s + 1]),
                                      rng)
            self._caches[s] = cache
            tok = int(nxt[0])
            req.out.append(tok)
            self._next_tok[s, 0] = tok
            if (self.eos is not None and tok == self.eos) or \
                    len(req.out) >= req.max_new_tokens:
                req.done = True
                self._active[s] = None
        return len(active)

    def run(self) -> List[Request]:
        done: List[Request] = []
        while self._queue or any(a is not None for a in self._active):
            self.step()
        return done
