"""Batched serving engine: bucketed prefill + decode steps and a slot-based
continuous-batching loop over a dense or *paged* KV memory plane.

`make_prefill_step`/`make_decode_step` are the functions the dry-run lowers
for the decode shapes (decode_32k / long_500k): one new token against a KV /
recurrent-state cache.

`ServeEngine` packs requests into fixed batch slots and refills them as
sequences finish (continuous batching at step granularity). Every engine
step issues exactly one jitted decode call regardless of occupancy; the KV
layout behind it is selected by ``cfg.kv_impl``:

``dense``  — one max_len K/V buffer per slot, stacked into a (slots, ...)
    pytree (models.transformer.stack_caches) and decoded as a vmap over the
    slot axis. Memory is slots x max_len whatever the real lengths are.
``paged``  — a global pool of ``block_len``-position KV blocks per layer
    (models.attention.*_init_paged_cache) with per-slot block tables, host
    allocation in serve.kv_pager.KVPager. Admission allocates just the
    blocks a request can reach (bucketed prompt + max_new_tokens) and frees
    them the step it finishes, so memory follows the *actual* traffic;
    a request that does not fit stays queued (backpressure) instead of
    crashing. Decode gathers each slot's blocks through its table and masks
    past the per-slot length — bit-identical tokens to the dense path
    (greedy and seeded sampling), CI-enforced. ``cfg.paged_attend_impl``
    picks how that decode attends: ``gather`` (assemble the full table
    gather; dense-shaped transient) or ``pallas`` (the block-walking
    kernel in kernels/paged_attention.py: one KV block in VMEM per grid
    step, online softmax, transient independent of max_len — same emitted
    tokens, enforced per backend in tests/test_paged_attention.py).

Admission prefills are *bucketed*: prompts are padded to a small geometric
set of lengths (serve.kv_pager.bucket_lengths, 16/32/.../max_len) with the
real length masked back in (`transformer.override_cache_length`), so
serving N distinct prompt lengths compiles at most len(buckets) prefills —
not N — plus exactly two decode variants (argmax-only and sampling).
Bucketing (and with it the paged plane) is attention-family only: a
recurrent scan has no causal mask to hide a pad tail, so mamba/xlstm archs
prefill at exact length on the dense plane, exactly as before.
Sampling (serve.sampling) stays per-slot: each request carries its own
SamplingParams, temperature scaling runs through the CORDIC linear-rotation
multiply by the R2-LVC reciprocal, and every request draws from its own rng
key stream fold_in(fold_in(base, rid), t) — making the emitted tokens
independent of slot placement, batch composition, and KV layout.

Observability (repro.obs): construct the engine with ``obs=Observability()``
(optionally ``trace=True`` for a Chrome-trace/Perfetto request-lifecycle +
engine-phase timeline) and read ``obs.metrics.snapshot()`` afterwards. All
instrumentation is host-side: nothing here feeds a jitted function, so
compile counts and emitted tokens are bit-identical with observability on
or off (CI-enforced in tests/test_obs.py). Metrics emitted:

    name                              type       unit      emitted at
    --------------------------------  ---------  --------  -----------------
    engine.requests.submitted         counter    requests  submit()
    engine.requests.finished          counter    requests  _finish()
    engine.tokens.emitted             counter    tokens    admission + step()
    engine.steps                      counter    steps     step()
    engine.queue_depth                gauge      requests  step() (pre-admit)
    engine.batch_occupancy            gauge      slots     step() (post-admit)
    engine.ttft_ms                    histogram  ms        first token
                                                           (admission prefill)
    engine.tpot_ms                    histogram  ms        _finish() (decode
                                                           interval mean)
    engine.e2e_ms                     histogram  ms        _finish()
    engine.prefill_ms                 histogram  ms        admission
    engine.step_ms                    histogram  ms        step()
    engine.phase.admit_ms             histogram  ms        step() span
    engine.phase.dispatch_ms          histogram  ms        step() span (jit
                                                           call, async)
    engine.phase.host_sync_ms         histogram  ms        step() span
                                                           (device->host)
    engine.phase.sample_copy_ms       histogram  ms        step() span (host
                                                           bookkeeping)
    engine.compiles.prefill/.decode   counter    compiles  compile_counts()
                                                           delta per step
    kv.pool.blocks_in_use             gauge      blocks    KVPager alloc/free
    kv.pool.allocs                    counter    allocs    KVPager.alloc
    kv.pool.alloc_failures            counter    events    KVPager.alloc
                                                           (backpressure)
    kv.pool.blocks_freed              counter    blocks    KVPager.free
    fixed_point.saturation.clips{fmt=Q2.14}  counter  elements  eager
        quantize under obs.observe_saturation (plus .elements{...} totals)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_lib
from repro.models import transformer as tf
from repro.serve import kv_pager as kvp
from repro.serve import sampling as sp
from repro.serve.sampling import SamplingParams


def make_prefill_step(cfg):
    def prefill(params, cache, batch):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        return logits[:, -1], cache
    return prefill


def make_bucketed_prefill_step(cfg):
    """Dense prefill over a bucket-padded prompt: the returned function
    takes the *real* prompt length, hands back the logits at the last real
    position, and pins the cache position counters to it — the pad tail is
    causally invisible to that row and is overwritten by decode writes, so
    padding never changes the emitted tokens. One compile per bucket width
    instead of one per distinct prompt length."""
    def prefill(params, cache, batch, true_len):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        cache = tf.override_cache_length(cache, true_len)
        last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                            keepdims=False)
        return last, cache
    return prefill


def make_paged_prefill_step(cfg):
    """Admission prefill straight into pool blocks: binds the slot's block
    table, runs the bucket-padded prefill through a batch-1 slot view
    (fresh recurrent state, shared pools), writes the updated pools + slot
    rows back, and pins the slot length to the real prompt length. No
    dense max_len cache is materialized and nothing is copied at insert.

    Tail-write trim: the prefill runs against ``write_row``, whose entries
    past the last block holding a *real* prompt position are redirected to
    the scratch block — bucket-pad positions past that block scatter into
    scratch instead of burning pool write traffic on blocks whose content
    would never be read (pad keys are causally invisible to the last real
    position, and decode overwrites pad positions before the length mask
    ever exposes them).  ``full_row`` — the real allocation — is bound
    afterwards so decode writes land in live blocks."""
    def prefill(params, caches, tokens, slot, write_row, full_row, true_len):
        caches = tf.paged_set_slot(cfg, caches, slot, write_row,
                                   jnp.zeros((), jnp.int32))
        view = tf.paged_slot_view(cfg, caches, slot)
        logits, _, nview = tf.apply(params, {"tokens": tokens}, cfg,
                                    cache=view)
        caches = tf.paged_slot_merge(cfg, caches, nview, slot)
        caches = tf.paged_set_slot(cfg, caches, slot, full_row, true_len)
        last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                            keepdims=False)
        return last, caches
    return prefill


def make_decode_step(cfg, *, greedy: bool = True, temperature: float = 1.0):
    """Single-cache decode step (the shape the dry-run lowers; the engine
    itself uses make_batched_decode_step over stacked slot caches)."""
    def decode(params, cache, tokens, rng=None):
        """tokens: (B,1) int32 (or (B,1,d) embeds). Returns next token ids.

        Sampling decode consumes `rng` — the caller threads a fresh split
        per step; reusing one key would make every step/batch draw the
        same sample.
        """
        batch = ({"tokens": tokens} if cfg.input_mode == "tokens"
                 else {"embeds": tokens})
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            if rng is None:
                raise ValueError("sampling decode requires an rng key")
            nxt = jax.random.categorical(
                rng, last / temperature).astype(jnp.int32)
        return nxt, cache
    return decode


def _sample_step(last, rids, steps, temps, top_ks, greedy, base_key,
                 greedy_only: bool):
    """Shared tail of the batched decode variants: (S,V) last-position
    logits -> (S,) next tokens. ``greedy_only`` compiles the argmax-only
    datapath; greedy tokens are argmax of the raw logits in BOTH variants,
    so which one runs never changes the output."""
    if greedy_only:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    keys = jax.vmap(lambda r, t: sp.request_key(base_key, r, t))(rids, steps)
    return sp.sample_batched(last, keys, temps, top_ks, greedy)


def make_batched_decode_step(cfg, *, greedy_only: bool = False):
    """One jitted decode for ALL slots of a *dense* stacked cache: vmap
    over the stacked (slots, 1, ...) cache axis.

    Arguments of the returned function (S = slot count):
        params        — model params (broadcast across slots)
        caches        — stacked (S, 1, ...) cache pytree (stack_caches)
        tokens        — (S, 1) int32 previous token per slot
        rids, steps   — (S,) int32: request id + token index, hashed into
                        per-slot keys fold_in(fold_in(base_key, rid), step)
        temps, top_ks, greedy — (S,) per-slot SamplingParams (traced, so a
                        changed request mix never recompiles)
        base_key      — engine-level PRNG key

    Returns ((S,) int32 next tokens, updated stacked caches). Inactive
    slots decode garbage tokens against their stale caches — the engine
    masks them on the host; their caches are re-prefilled at admission.
    """
    def decode(params, caches, tokens, rids, steps, temps, top_ks, greedy,
               base_key):
        def one(cache, tok):
            logits, _, nc = tf.apply(params, {"tokens": tok[None, :]}, cfg,
                                     cache=cache)
            return logits[0, -1], nc

        last, caches = jax.vmap(one)(caches, tokens)
        nxt = _sample_step(last, rids, steps, temps, top_ks, greedy,
                           base_key, greedy_only)
        return nxt, caches
    return decode


def make_paged_decode_step(cfg, *, greedy_only: bool = False):
    """One jitted decode for ALL slots of a *paged* cache: a single
    batch-``slots`` apply — the block pool is global, so there is no
    per-slot cache axis to vmap; per-slot positions live in the cache's
    ``lens`` leaves and each row attends its own table-gathered blocks.
    Same signature and same emitted tokens as make_batched_decode_step."""
    def decode(params, caches, tokens, rids, steps, temps, top_ks, greedy,
               base_key):
        logits, _, caches = tf.apply(params, {"tokens": tokens}, cfg,
                                     cache=caches)
        nxt = _sample_step(logits[:, -1], rids, steps, temps, top_ks, greedy,
                           base_key, greedy_only)
        return nxt, caches
    return decode


def make_score_step(cfg):
    """Teacher-forced per-token log-probs of a prompt.

    The log-softmax datapath follows ``cfg.loss_impl`` (exact | cordic |
    cordic_pallas — repro.train.losses), so served log-prob scoring uses
    the same CORDIC exp/log legs as the training loss.
    """
    from repro.train import losses

    logp_fn = losses.log_softmax_fn(getattr(cfg, "loss_impl", "exact"))

    def score(params, batch):
        """batch: {"tokens": (B,S)}. Returns (B,S-1) log p(token_t | <t)."""
        logits, _, _ = tf.apply(params, batch, cfg, cache=None)
        logp = logp_fn(logits[:, :-1])
        nxt = batch["tokens"][:, 1:]
        return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]

    return score


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None   # None -> engine default
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle timestamps on the engine's Observability clock (seconds);
    # -1 = stage not reached, or engine constructed without observability
    t_enqueue: float = dataclasses.field(default=-1.0, repr=False)
    t_admit: float = dataclasses.field(default=-1.0, repr=False)
    t_first: float = dataclasses.field(default=-1.0, repr=False)
    t_finish: float = dataclasses.field(default=-1.0, repr=False)


class ServeEngine:
    """Slot-based continuous batching on top of bucketed prefill + one
    batched decode, over a dense or paged KV plane (see module docstring).

    Static batch of `slots`; each slot holds one request and an active-slot
    mask tracks occupancy. Admission pads the prompt to a length bucket,
    prefills it (into a fresh stacked-tree slot for ``dense``, straight
    into freshly allocated pool blocks for ``paged``), and emits the first
    token; every `step()` then advances ALL slots with exactly one jitted
    decode call and appends the sampled token to each active request.
    Finished slots release their blocks (paged) and are refilled from the
    queue between steps, head-of-queue first — a head that does not fit
    the pool blocks admission until something frees (FIFO backpressure).
    Per-request sampling params can mix greedy / temperature / top-k within
    one batch (see serve.sampling).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_token: Optional[int] = None, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 softmax_impl: Optional[str] = None,
                 loss_impl: Optional[str] = None,
                 kv_impl: Optional[str] = None,
                 block_len: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 paged_attend_impl: Optional[str] = None,
                 obs: Optional[obs_lib.Observability] = None):
        assert cfg.input_mode == "tokens", "engine serves token LMs"
        self.obs = obs if obs is not None else obs_lib.NULL
        if softmax_impl is not None:
            cfg = dataclasses.replace(cfg, softmax_impl=softmax_impl)
        if loss_impl is not None:
            cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
        if kv_impl is not None:
            cfg = dataclasses.replace(cfg, kv_impl=kv_impl)
        if block_len is not None:
            cfg = dataclasses.replace(cfg, kv_block_len=block_len)
        if paged_attend_impl is not None:
            cfg = dataclasses.replace(cfg, paged_attend_impl=paged_attend_impl)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_token
        self.kv_impl = getattr(cfg, "kv_impl", "dense")
        self.block_len = getattr(cfg, "kv_block_len", 16)
        if self.kv_impl not in ("dense", "paged"):
            raise ValueError(f"unknown kv_impl {self.kv_impl!r}")
        self.paged_attend_impl = getattr(cfg, "paged_attend_impl", "gather")
        if self.paged_attend_impl not in ("gather", "pallas"):
            raise ValueError(
                f"unknown paged_attend_impl {self.paged_attend_impl!r}")
        if self.paged_attend_impl == "pallas" and self.kv_impl != "paged":
            raise ValueError(
                "paged_attend_impl='pallas' selects the block-walking "
                "decode kernel over the paged KV plane; serve it with "
                "kv_impl='paged' (the dense plane has no block tables)")
        if self.paged_attend_impl == "pallas" and cfg.score_dtype != "f32":
            # fail at init, not mid-serving out of the first decode trace
            # (models.attention._paged_attend_impl enforces the same rule)
            raise ValueError(
                "paged_attend_impl='pallas' supports score_dtype='f32' "
                f"only (got {cfg.score_dtype!r})")
        self.buckets = kvp.bucket_lengths(max_len, self.block_len)
        # Bucket-pad prefills only for attention-cache families: causal
        # attention makes the pad tail invisible to the last real position,
        # but recurrent blocks (mamba2/xlstm) would fold pad tokens into
        # their state. Recurrent/hybrid archs prefill at exact prompt
        # length (one compile per distinct length, as before) until the
        # scans learn position masking.
        blk_kinds = set(cfg.block_pattern) | (
            {cfg.shared_block} if cfg.shared_block is not None else set())
        self._bucketed = blk_kinds <= set(tf.PAGED_CACHE_FNS)
        self.default_sampling = (sampling if sampling is not None
                                 else SamplingParams(temperature=temperature,
                                                     greedy=greedy))
        self._base_key = jax.random.PRNGKey(seed)

        if self.kv_impl == "paged":
            if not self._bucketed:
                # block-granular prefill writes need block-aligned (i.e.
                # bucket-padded) widths, and padding is only output-neutral
                # for attention; recurrent families keep the dense plane
                raise ValueError(
                    "paged KV requires an attention-cache-only arch "
                    f"(block pattern {sorted(blk_kinds)} includes recurrent "
                    "blocks); serve it with kv_impl='dense'")
            if max_len % self.block_len:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"block_len {self.block_len}")
            self.max_blocks = max_len // self.block_len
            if num_blocks is None:
                # worst-case default: every slot full-length, + scratch
                num_blocks = slots * self.max_blocks + 1
            self.pager: Optional[kvp.KVPager] = kvp.KVPager(
                num_blocks, self.block_len, slots,
                metrics=self.obs.metrics if self.obs.enabled else None)
            self._caches = tf.init_paged_cache(
                cfg, slots, num_blocks, self.block_len, self.max_blocks,
                jnp.float32)
            self._prefill = jax.jit(make_paged_prefill_step(cfg),
                                    donate_argnums=(1,))
            sample_fn = jax.jit(make_paged_decode_step(cfg))
            greedy_fn = jax.jit(
                make_paged_decode_step(cfg, greedy_only=True))
            self._clear_slot = jax.jit(
                lambda caches, slot: tf.paged_set_slot(
                    cfg, caches, slot,
                    jnp.zeros((self.max_blocks,), jnp.int32),
                    jnp.zeros((), jnp.int32)),
                donate_argnums=(0,))
        else:
            self.pager = None
            self._caches = tf.stack_caches(
                [tf.init_cache(cfg, 1, max_len, jnp.float32)
                 for _ in range(slots)])
            self._prefill = jax.jit(make_bucketed_prefill_step(cfg))
            sample_fn = jax.jit(make_batched_decode_step(cfg))
            greedy_fn = jax.jit(
                make_batched_decode_step(cfg, greedy_only=True))

        def _dispatch(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key):
            # all-greedy batches take the argmax-only compile (no sampling
            # datapath); tokens are identical either way, see _sample_step
            fn = greedy_fn if bool(np.asarray(greedy).all()) else sample_fn
            return fn(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key)

        self._decode = _dispatch
        self._decode_jits = (greedy_fn, sample_fn)
        self._sample = jax.jit(sp.sample_batched)
        self._score = jax.jit(make_score_step(cfg))
        self._queue: List[Request] = []
        self._done: List[Request] = []
        self._active: List[Optional[Request]] = [None] * slots
        self._next_tok = np.zeros((slots, 1), np.int32)
        # per-slot host state mirrored into the batched decode each step
        self._rids = np.zeros(slots, np.int32)
        self._steps = np.zeros(slots, np.int32)    # == len(req.out) per slot
        self._temps = np.ones(slots, np.float32)
        self._top_ks = np.zeros(slots, np.int32)
        self._greedy = np.ones(slots, bool)

        self._bind_obs_handles()

    def _bind_obs_handles(self) -> None:
        # observability handles (null no-ops when obs is disabled; the
        # metric name/type/unit table lives in the module docstring)
        m = self.obs.metrics
        self._m_submitted = m.counter("engine.requests.submitted",
                                      unit="requests")
        self._m_finished = m.counter("engine.requests.finished",
                                     unit="requests")
        self._m_tokens = m.counter("engine.tokens.emitted", unit="tokens")
        self._m_steps = m.counter("engine.steps", unit="steps")
        self._m_queue = m.gauge("engine.queue_depth", unit="requests")
        self._m_occ = m.gauge("engine.batch_occupancy", unit="slots")
        self._m_ttft = m.histogram("engine.ttft_ms", unit="ms")
        self._m_tpot = m.histogram("engine.tpot_ms", unit="ms")
        self._m_e2e = m.histogram("engine.e2e_ms", unit="ms")
        self._m_prefill = m.histogram("engine.prefill_ms", unit="ms")
        self._m_step = m.histogram("engine.step_ms", unit="ms")
        self._m_compiles = {
            "prefill": m.counter("engine.compiles.prefill", unit="compiles"),
            "decode": m.counter("engine.compiles.decode", unit="compiles"),
        }
        self._last_compiles = (self.compile_counts() if self.obs.enabled
                               else None)
        if self.pager is not None:
            self.pager.attach_metrics(m if self.obs.enabled else None)

    def attach_obs(self, obs: Optional[obs_lib.Observability]) -> None:
        """Attach (or replace, or with None detach) the observability
        handle mid-lifetime — e.g. after a warm-up pass, so compile walls
        stay out of the latency histograms. Metrics recorded so far stay
        in the previous handle's registry; compile counters restart from
        the current jit-cache sizes."""
        self.obs = obs if obs is not None else obs_lib.NULL
        self._bind_obs_handles()

    def _obs_compiles(self) -> None:
        """Fold compile_counts() deltas into compile counters + trace
        instants — jit-cache growth observed from the host, never traced."""
        if not self.obs.enabled:
            return
        counts = self.compile_counts()
        for kind, n in counts.items():
            d = n - self._last_compiles[kind]
            if d > 0:
                self._m_compiles[kind].inc(d)
                if self.obs.trace is not None:
                    self.obs.trace.instant(f"compile:{kind}",
                                           self.obs.now_us(),
                                           args={"cache_size": n})
        self._last_compiles = counts

    def _obs_prefilled(self, req: Request, first: int) -> None:
        """Admission-side lifecycle record: prefill span, TTFT (enqueue ->
        first token, queueing included), first-token event + compiles."""
        if not self.obs.enabled:
            return
        now = self.obs.now()
        req.t_first = now
        self._m_prefill.observe((now - req.t_admit) * 1e3)
        if req.t_enqueue >= 0:
            self._m_ttft.observe((now - req.t_enqueue) * 1e3)
        self._m_tokens.inc()
        self.obs.request_span("prefill", req.rid, req.t_admit)
        self.obs.request_event("first_token", req.rid, {"token": first})
        self._obs_compiles()

    def submit(self, req: Request) -> None:
        if self.obs.enabled:
            req.t_enqueue = self.obs.now()
            self._m_submitted.inc()
            self.obs.request_event("enqueue", req.rid,
                                   {"prompt_len": len(req.prompt),
                                    "max_new_tokens": req.max_new_tokens})
        self._queue.append(req)

    def score(self, prompt: np.ndarray) -> np.ndarray:
        """(S,) int32 prompt -> (S-1,) per-token log-probs (teacher-forced),
        through the cfg.loss_impl-selected log-softmax datapath."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        return np.asarray(self._score(self.params, {"tokens": toks})[0])

    @property
    def active_mask(self) -> np.ndarray:
        """(slots,) bool — which slots currently hold a request."""
        return np.asarray([a is not None for a in self._active])

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes of the serving datapath — the bucketed-prefill
        guarantee made checkable: after serving any mix of prompt lengths,
        ``prefill <= len(self.buckets)`` and ``decode <= 2`` (argmax-only
        + sampling variants). The prefill bound holds for attention-family
        archs; recurrent archs prefill at exact length (see _bucketed)."""
        return {
            "prefill": int(self._prefill._cache_size()),
            "decode": int(sum(fn._cache_size() for fn in self._decode_jits)),
        }

    def _finish(self, req: Request) -> None:
        req.done = True
        if self.obs.enabled:
            req.t_finish = self.obs.now()
            self._m_finished.inc()
            if req.t_enqueue >= 0:
                self._m_e2e.observe((req.t_finish - req.t_enqueue) * 1e3)
            if req.t_first >= 0 and len(req.out) > 1:
                # mean decode interval: first token is TTFT's, the rest
                # amortize the decode steps (the standard TPOT definition)
                self._m_tpot.observe((req.t_finish - req.t_first)
                                     / (len(req.out) - 1) * 1e3)
            self.obs.request_event("finish", req.rid,
                                   {"tokens": len(req.out)})
        self._done.append(req)

    def _release_slot(self, s: int) -> None:
        """Return slot ``s`` to the free state: paged mode hands its blocks
        back to the pool and resets the device-side table row to scratch
        zeros (a vacant slot must never scribble on blocks that get
        reallocated); sampling knobs reset to greedy defaults so a vacated
        sampling slot can't pin _dispatch off the cheap all-greedy compile."""
        self._active[s] = None
        if self.pager is not None:
            self.pager.free(s)
            self._caches = self._clear_slot(self._caches,
                                            jnp.asarray(s, jnp.int32))
        self._temps[s] = 1.0
        self._top_ks[s] = 0
        self._greedy[s] = True

    def _sample_first(self, req: Request, logits) -> int:
        """Sample the prefill-emitted token (step 0 of the request's key
        stream) with the request's own SamplingParams."""
        temp, top_k, greedy = (req.sampling or self.default_sampling).resolved()
        key = sp.request_key(self._base_key, req.rid, 0)
        tok = self._sample(logits[:1], key[None],
                           jnp.full((1,), temp, jnp.float32),
                           jnp.full((1,), top_k, jnp.int32),
                           jnp.full((1,), greedy, bool))
        return int(tok[0])

    def _padded_prompt(self, req: Request) -> np.ndarray:
        """(1, width) int32 prompt, padded to its length bucket for
        attention-family archs (exact length otherwise — see _bucketed)."""
        plen = len(req.prompt)
        width = (kvp.bucket_for(plen, self.buckets) if self._bucketed
                 else plen)
        toks = np.zeros((1, width), np.int32)
        toks[0, :plen] = np.asarray(req.prompt, np.int32)
        return toks

    def _blocks_for(self, req: Request) -> int:
        """Pool blocks a request can ever touch: the bucket-padded prefill
        width or prompt + full decode budget, clamped to max_len."""
        need_len = min(max(kvp.bucket_for(len(req.prompt), self.buckets),
                           len(req.prompt) + req.max_new_tokens),
                       self.max_len)
        return kvp.blocks_needed(need_len, self.block_len)

    def _register_slot(self, s: int, req: Request, first: int) -> None:
        """Host-side mirrors for an admitted request."""
        self._active[s] = req
        self._next_tok[s, 0] = first
        temp, top_k, greedy = (req.sampling
                               or self.default_sampling).resolved()
        self._rids[s] = req.rid
        self._steps[s] = len(req.out)
        self._temps[s] = temp
        self._top_ks[s] = top_k
        self._greedy[s] = greedy

    def _finishes_at_prefill(self, req: Request, first: int) -> bool:
        """A request whose first token already hits `eos_token` or whose
        budget is max_new_tokens=1 finishes at admission and never
        occupies a slot."""
        req.out.append(first)
        if (self.eos is not None and first == self.eos) or \
                len(req.out) >= req.max_new_tokens:
            self._finish(req)
            return True
        return False

    def _admit_dense(self) -> None:
        for s in range(self.slots):
            while self._active[s] is None and self._queue:
                req = self._queue.pop(0)
                if self.obs.enabled:
                    req.t_admit = self.obs.now()
                    self.obs.request_event("admit", req.rid, {"slot": s})
                cache = tf.init_cache(self.cfg, 1, self.max_len, jnp.float32)
                toks = self._padded_prompt(req)
                logits, cache = self._prefill(
                    self.params, cache, {"tokens": jnp.asarray(toks)},
                    jnp.asarray(len(req.prompt), jnp.int32))
                first = self._sample_first(req, logits)
                self._obs_prefilled(req, first)
                if self._finishes_at_prefill(req, first):
                    continue                      # slot stays free; try next
                self._caches = tf.insert_slot(self._caches, cache, s)
                self._register_slot(s, req, first)

    def _admit_paged(self) -> None:
        for s in range(self.slots):
            while self._active[s] is None and self._queue:
                req = self._queue[0]
                toks = self._padded_prompt(req)
                need = self._blocks_for(req)
                blocks = self.pager.alloc(s, need)
                if blocks is None:
                    return      # FIFO backpressure: head waits for frees
                self._queue.pop(0)
                if self.obs.enabled:
                    req.t_admit = self.obs.now()
                    self.obs.request_event("admit", req.rid,
                                           {"slot": s, "blocks": need})
                row = np.zeros(self.max_blocks, np.int32)
                row[:need] = blocks
                # tail-write trim: prefill writes for bucket-pad positions
                # past the last real block go to scratch (see
                # make_paged_prefill_step); decode uses the full row.
                write_row = row.copy()
                nb_real = kvp.blocks_needed(len(req.prompt), self.block_len)
                nb_bucket = toks.shape[1] // self.block_len
                write_row[nb_real:nb_bucket] = kvp.SCRATCH_BLOCK
                logits, self._caches = self._prefill(
                    self.params, self._caches, jnp.asarray(toks),
                    jnp.asarray(s, jnp.int32), jnp.asarray(write_row),
                    jnp.asarray(row),
                    jnp.asarray(len(req.prompt), jnp.int32))
                first = self._sample_first(req, logits)
                self._obs_prefilled(req, first)
                if self._finishes_at_prefill(req, first):
                    self._release_slot(s)         # blocks back; try next
                    continue
                self._register_slot(s, req, first)

    def _clamp_budget(self, req: Request) -> None:
        """Truncate max_new_tokens so decode can never write past max_len:
        positions written are prompt..prompt+max_new-2, so the budget caps
        at max_len - len(prompt) + 1. Without this the dense path clamps
        its update into the last position and the paged path's clipped
        table index overwrites a live block — garbage either way, and
        differently, which would break the bit-identity contract."""
        req.max_new_tokens = min(req.max_new_tokens,
                                 self.max_len - len(req.prompt) + 1)

    def _admit(self) -> None:
        """Fill free slots from the queue (bucket-padded prefill + first
        token; paged mode also binds freshly allocated pool blocks).
        Budgets that would decode past max_len are truncated to fit."""
        for req in self._queue:
            self._clamp_budget(req)
        if self.kv_impl == "paged":
            self._admit_paged()
        else:
            self._admit_dense()

    def step(self) -> int:
        """One batched decode step across all slots; returns #active.

        Exactly ONE jitted decode call regardless of slot count: inactive
        slots ride along (their output is ignored; dense slots are
        re-prefilled at admission, paged slots write into the scratch
        block), so the dispatch count and the compiled shape never depend
        on occupancy.
        """
        ob = self.obs
        t_step = ob.now()
        self._m_steps.inc()
        self._m_queue.set(len(self._queue))     # backlog before admission
        with ob.phase("admit"):
            self._admit()
        active = [s for s in range(self.slots) if self._active[s] is not None]
        self._m_occ.set(len(active))
        if ob.trace is not None:
            ob.trace.counter("engine.load", ob.now_us(),
                             {"queue_depth": len(self._queue),
                              "batch_occupancy": len(active)})
        if not active:
            if self._queue and self.pager is not None:
                raise RuntimeError(
                    f"request {self._queue[0].rid} can never be admitted: "
                    f"needs {self._blocks_for(self._queue[0])} KV blocks, "
                    f"pool has {self.pager.num_blocks - 1} allocatable")
            return 0
        # phase spans: dispatch ends when jax hands back async futures,
        # host_sync is the device->host block on the sampled tokens,
        # sample_copy is pure host bookkeeping over the active slots
        with ob.phase("dispatch"):
            nxt, self._caches = self._decode(
                self.params, self._caches, jnp.asarray(self._next_tok),
                jnp.asarray(self._rids), jnp.asarray(self._steps),
                jnp.asarray(self._temps), jnp.asarray(self._top_ks),
                jnp.asarray(self._greedy), self._base_key)
        with ob.phase("host_sync"):
            nxt = np.asarray(nxt)
        with ob.phase("sample_copy"):
            for s in active:
                req = self._active[s]
                tok = int(nxt[s])
                req.out.append(tok)
                self._next_tok[s, 0] = tok
                self._steps[s] = len(req.out)
                ob.request_event("token", req.rid,
                                 {"step": len(req.out), "token": tok})
                if (self.eos is not None and tok == self.eos) or \
                        len(req.out) >= req.max_new_tokens:
                    self._finish(req)
                    self._release_slot(s)
        if ob.enabled:
            self._m_tokens.inc(len(active))
            self._m_step.observe((ob.now() - t_step) * 1e3)
            self._obs_compiles()
        return len(active)

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns the finished requests
        (every submitted request, in completion order)."""
        while self._queue or any(a is not None for a in self._active):
            self.step()
        done, self._done = self._done, []
        return done
