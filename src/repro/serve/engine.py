"""Batched serving engine: prefill + decode steps and a slot-based
continuous-batching loop.

`make_prefill_step`/`make_decode_step` are the functions the dry-run lowers
for the decode shapes (decode_32k / long_500k): one new token against a KV /
recurrent-state cache.

`ServeEngine` packs requests into fixed batch slots and refills them as
sequences finish (continuous batching at step granularity). The per-slot KV /
recurrent caches are *stacked* into one (slots, ...) pytree
(models.transformer.stack_caches), so every engine step issues exactly one
jitted decode call — a vmap over the slot axis — regardless of how many
slots are active; per-slot sequence positions live in the stacked ``idx``
leaves. Sampling (serve.sampling) is per-slot: each request carries its own
SamplingParams, temperature scaling runs through the CORDIC linear-rotation
multiply by the R2-LVC reciprocal, and every request draws from its own rng
key stream fold_in(fold_in(base, rid), t) — making the emitted tokens
independent of slot placement and batch composition (bit-reproducible
against a sequential decode of the same requests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.serve import sampling as sp
from repro.serve.sampling import SamplingParams


def make_prefill_step(cfg):
    def prefill(params, cache, batch):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        return logits[:, -1], cache
    return prefill


def make_decode_step(cfg, *, greedy: bool = True, temperature: float = 1.0):
    """Single-cache decode step (the shape the dry-run lowers; the engine
    itself uses make_batched_decode_step over stacked slot caches)."""
    def decode(params, cache, tokens, rng=None):
        """tokens: (B,1) int32 (or (B,1,d) embeds). Returns next token ids.

        Sampling decode consumes `rng` — the caller threads a fresh split
        per step; reusing one key would make every step/batch draw the
        same sample.
        """
        batch = ({"tokens": tokens} if cfg.input_mode == "tokens"
                 else {"embeds": tokens})
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            if rng is None:
                raise ValueError("sampling decode requires an rng key")
            nxt = jax.random.categorical(
                rng, last / temperature).astype(jnp.int32)
        return nxt, cache
    return decode


def make_batched_decode_step(cfg, *, greedy_only: bool = False):
    """One jitted decode for ALL slots: vmap over the stacked cache axis.

    Arguments of the returned function (S = slot count):
        params        — model params (broadcast across slots)
        caches        — stacked (S, 1, ...) cache pytree (stack_caches)
        tokens        — (S, 1) int32 previous token per slot
        rids, steps   — (S,) int32: request id + token index, hashed into
                        per-slot keys fold_in(fold_in(base_key, rid), step)
        temps, top_ks, greedy — (S,) per-slot SamplingParams (traced, so a
                        changed request mix never recompiles)
        base_key      — engine-level PRNG key

    Returns ((S,) int32 next tokens, updated stacked caches). Inactive
    slots decode garbage tokens against their stale caches — the engine
    masks them on the host; their caches are re-prefilled at admission.

    ``greedy_only`` compiles the argmax-only variant: an all-greedy batch
    skips the sampling datapath (CORDIC temperature multiply, vocab sort,
    categorical draw) entirely. Greedy tokens are argmax of the raw logits
    in BOTH variants, so which one runs never changes the output.
    """
    def decode(params, caches, tokens, rids, steps, temps, top_ks, greedy,
               base_key):
        def one(cache, tok):
            logits, _, nc = tf.apply(params, {"tokens": tok[None, :]}, cfg,
                                     cache=cache)
            return logits[0, -1], nc

        last, caches = jax.vmap(one)(caches, tokens)
        if greedy_only:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            keys = jax.vmap(lambda r, t: sp.request_key(base_key, r, t))(rids,
                                                                         steps)
            nxt = sp.sample_batched(last, keys, temps, top_ks, greedy)
        return nxt, caches
    return decode


def make_score_step(cfg):
    """Teacher-forced per-token log-probs of a prompt.

    The log-softmax datapath follows ``cfg.loss_impl`` (exact | cordic |
    cordic_pallas — repro.train.losses), so served log-prob scoring uses
    the same CORDIC exp/log legs as the training loss.
    """
    from repro.train import losses

    logp_fn = losses.log_softmax_fn(getattr(cfg, "loss_impl", "exact"))

    def score(params, batch):
        """batch: {"tokens": (B,S)}. Returns (B,S-1) log p(token_t | <t)."""
        logits, _, _ = tf.apply(params, batch, cfg, cache=None)
        logp = logp_fn(logits[:, :-1])
        nxt = batch["tokens"][:, 1:]
        return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]

    return score


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None   # None -> engine default
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching on top of prefill + one batched decode.

    Static batch of `slots`, all caches stacked into one (slots, ...) tree;
    each slot holds one request and an active-slot mask tracks occupancy.
    Admission prefills a fresh single-request cache and writes it into the
    stack (insert_slot); every `step()` then advances ALL slots with exactly
    one jitted vmapped decode call and appends the sampled token to each
    active request. Finished slots are refilled from the queue between
    steps. Per-request sampling params can mix greedy / temperature / top-k
    within one batch (see serve.sampling).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_token: Optional[int] = None, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 softmax_impl: Optional[str] = None,
                 loss_impl: Optional[str] = None):
        assert cfg.input_mode == "tokens", "engine serves token LMs"
        if softmax_impl is not None:
            cfg = dataclasses.replace(cfg, softmax_impl=softmax_impl)
        if loss_impl is not None:
            cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_token
        self.default_sampling = (sampling if sampling is not None
                                 else SamplingParams(temperature=temperature,
                                                     greedy=greedy))
        self._base_key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg))
        sample_fn = jax.jit(make_batched_decode_step(cfg))
        greedy_fn = jax.jit(make_batched_decode_step(cfg, greedy_only=True))

        def _dispatch(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key):
            # all-greedy batches take the argmax-only compile (no sampling
            # datapath); tokens are identical either way, see
            # make_batched_decode_step
            fn = greedy_fn if bool(np.asarray(greedy).all()) else sample_fn
            return fn(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key)

        self._decode = _dispatch
        self._sample = jax.jit(sp.sample_batched)
        self._score = jax.jit(make_score_step(cfg))
        self._queue: List[Request] = []
        self._done: List[Request] = []
        self._active: List[Optional[Request]] = [None] * slots
        self._caches = tf.stack_caches(
            [tf.init_cache(cfg, 1, max_len, jnp.float32)
             for _ in range(slots)])
        self._next_tok = np.zeros((slots, 1), np.int32)
        # per-slot host state mirrored into the batched decode each step
        self._rids = np.zeros(slots, np.int32)
        self._steps = np.zeros(slots, np.int32)    # == len(req.out) per slot
        self._temps = np.ones(slots, np.float32)
        self._top_ks = np.zeros(slots, np.int32)
        self._greedy = np.ones(slots, bool)

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def score(self, prompt: np.ndarray) -> np.ndarray:
        """(S,) int32 prompt -> (S-1,) per-token log-probs (teacher-forced),
        through the cfg.loss_impl-selected log-softmax datapath."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        return np.asarray(self._score(self.params, {"tokens": toks})[0])

    @property
    def active_mask(self) -> np.ndarray:
        """(slots,) bool — which slots currently hold a request."""
        return np.asarray([a is not None for a in self._active])

    def _finish(self, req: Request) -> None:
        req.done = True
        self._done.append(req)

    def _sample_first(self, req: Request, logits) -> int:
        """Sample the prefill-emitted token (step 0 of the request's key
        stream) with the request's own SamplingParams."""
        temp, top_k, greedy = (req.sampling or self.default_sampling).resolved()
        key = sp.request_key(self._base_key, req.rid, 0)
        tok = self._sample(logits[:1], key[None],
                           jnp.full((1,), temp, jnp.float32),
                           jnp.full((1,), top_k, jnp.int32),
                           jnp.full((1,), greedy, bool))
        return int(tok[0])

    def _admit(self) -> None:
        """Fill free slots from the queue: prefill into a fresh cache, write
        it into the stacked tree, and emit the first token. A request whose
        first token already hits `eos_token` or whose budget is
        max_new_tokens=1 finishes here and never occupies a slot."""
        for s in range(self.slots):
            while self._active[s] is None and self._queue:
                req = self._queue.pop(0)
                cache = tf.init_cache(self.cfg, 1, self.max_len, jnp.float32)
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = self._prefill(self.params, cache,
                                              {"tokens": toks})
                first = self._sample_first(req, logits)
                req.out.append(first)
                if (self.eos is not None and first == self.eos) or \
                        len(req.out) >= req.max_new_tokens:
                    self._finish(req)
                    continue                      # slot stays free; try next
                self._active[s] = req
                self._caches = tf.insert_slot(self._caches, cache, s)
                self._next_tok[s, 0] = first
                temp, top_k, greedy = (req.sampling
                                       or self.default_sampling).resolved()
                self._rids[s] = req.rid
                self._steps[s] = len(req.out)
                self._temps[s] = temp
                self._top_ks[s] = top_k
                self._greedy[s] = greedy

    def step(self) -> int:
        """One batched decode step across all slots; returns #active.

        Exactly ONE jitted decode call regardless of slot count: inactive
        slots ride along (their output is ignored and their cache is
        re-prefilled at admission), so the dispatch count and the compiled
        shape never depend on occupancy.
        """
        self._admit()
        active = [s for s in range(self.slots) if self._active[s] is not None]
        if not active:
            return 0
        nxt, self._caches = self._decode(
            self.params, self._caches, jnp.asarray(self._next_tok),
            jnp.asarray(self._rids), jnp.asarray(self._steps),
            jnp.asarray(self._temps), jnp.asarray(self._top_ks),
            jnp.asarray(self._greedy), self._base_key)
        nxt = np.asarray(nxt)
        for s in active:
            req = self._active[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self._next_tok[s, 0] = tok
            self._steps[s] = len(req.out)
            if (self.eos is not None and tok == self.eos) or \
                    len(req.out) >= req.max_new_tokens:
                self._finish(req)
                self._active[s] = None
                # reset to greedy defaults so a vacated sampling slot can't
                # pin _dispatch off the cheap all-greedy compile
                self._temps[s] = 1.0
                self._top_ks[s] = 0
                self._greedy[s] = True
        return len(active)

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns the finished requests
        (every submitted request, in completion order)."""
        while self._queue or any(a is not None for a in self._active):
            self.step()
        done, self._done = self._done, []
        return done
