"""Batched serving engine: bucketed prefill + decode steps and a slot-based
continuous-batching loop over a dense or *paged* KV memory plane.

`make_prefill_step`/`make_decode_step` are the functions the dry-run lowers
for the decode shapes (decode_32k / long_500k): one new token against a KV /
recurrent-state cache.

`ServeEngine` packs requests into fixed batch slots and refills them as
sequences finish (continuous batching at step granularity). Every engine
step issues exactly one jitted decode call regardless of occupancy (the
end-to-end dataflow picture and the full datapath selection matrix live
in ``docs/architecture.md``); the KV layout behind it is selected by
``cfg.kv_impl``:

``dense``  — one max_len K/V buffer per slot, stacked into a (slots, ...)
    pytree (models.transformer.stack_caches) and decoded as a vmap over the
    slot axis. Memory is slots x max_len whatever the real lengths are.
``paged``  — a global pool of ``block_len``-position KV blocks per layer
    (models.attention.*_init_paged_cache) with per-slot block tables, host
    allocation in the *refcounted* serve.kv_pager.KVPager. Admission
    allocates just the blocks a request can reach (bucketed prompt +
    max_new_tokens) minus any prefix-cache-shared blocks — its *unshared
    footprint* — and drops its references the step it finishes (a block
    rejoins the free list at refcount zero, so shared prefix blocks
    outlive individual requests), so memory follows the *actual* traffic;
    a request that does not fit stays queued (backpressure) instead of
    crashing. Decode gathers each slot's blocks through its table and masks
    past the per-slot length — bit-identical tokens to the dense path
    (greedy and seeded sampling), CI-enforced. ``cfg.paged_attend_impl``
    picks how that decode attends: ``gather`` (assemble the full table
    gather; dense-shaped transient) or ``pallas`` (the block-walking
    kernel in kernels/paged_attention.py: one KV block in VMEM per grid
    step, online softmax, transient independent of max_len — same emitted
    tokens, enforced per backend in tests/test_paged_attention.py).

Admission prefills are *bucketed*: prompts are padded to a small geometric
set of lengths (serve.kv_pager.bucket_lengths, 16/32/.../max_len) with the
real length masked back in (`transformer.override_cache_length`), so
serving N distinct prompt lengths compiles at most len(buckets) prefills —
not N — plus exactly two decode variants (argmax-only and sampling).
Bucketing (and with it the paged plane) is attention-family only: a
recurrent scan has no causal mask to hide a pad tail, so mamba/xlstm archs
prefill at exact length on the dense plane, exactly as before.

*When* prompts prefill is decided per iteration by the iteration-level
scheduler (serve.scheduler.IterationScheduler, Orca/Sarathi/vLLM shape):
every ``step()`` runs a prefill phase (chunk continuations first, then
FIFO admissions, under a ``max_prefill_tokens`` budget) before its single
batched decode dispatch. Two knobs extend the legacy one-prompt-per-
dispatch admission:

``prefill_chunk=C``   — prompts longer than C stream in as block-aligned
    C-wide chunks, one per iteration, interleaved with decode steps, so a
    long prompt no longer stalls every decoding slot for its whole
    prefill and short requests' TTFT stays flat. Mid-prefill slots are
    excluded from decode (their chunks re-pin the length counter each
    dispatch); the final chunk pins the true length and emits the first
    token. Compile widths stay bounded: {buckets <= C} ∪ {C}.
``prefill_batch=R``   — paged mode packs up to R scheduled rows into ONE
    multi-row prefill dispatch (make_paged_prefill_step binds R block-
    table rows by value; pad rows write to scratch), pow2-padded so
    compile batch dims are bounded by log2(R)+1.

``prefix_cache=True`` (paged only) adds the radix-tree prefix cache
(serve/prefix_cache.py): admission matches the prompt's token-id blocks
against previously prefilled prompts, binds the matched pool blocks into
the slot's table (refcounts keep them alive and shared), and prefill
*resumes at the first uncached block-aligned position* — a hit is
literally prefill chunks skipped, with the resumed row pinned like a
mid-chunk continuation. The divergent / partially-filled block is
copy-on-write by construction: shared blocks are never written (resumed
prefill writes only at positions >= its block-aligned start, decode only
at positions >= the pinned length — both land in the slot's fresh
blocks). Eviction (``prefix_eviction``: "lru" default, "fifo") reclaims
refcount-one radix leaves when the pool runs dry. Emitted tokens stay
bit-identical cache-on vs cache-off (tests/test_prefix_cache.py).

Both default off (chunk=None, batch=1): shapes, dispatch order, and tokens
are then bit-for-bit the legacy path. With them on, emitted tokens stay
bit-identical to the unchunked engine — the KV prefix written is the same
bytes, pad keys are causally invisible (exact 0.0 softmax weights), and
per-request key streams make sampling independent of scheduling — which
tests/test_scheduler.py enforces for greedy + seeded sampling, GQA + MLA,
dense + paged. One documented carve-out: capacity-factor MoE routing
(models/moe.py) computes its per-expert capacity from the dispatch width
(``C = ceil(S*K*cap/E)``) and queues tokens per apply, so a GShard MoE
arch's routing — like under any batch-size change — is not invariant to
how a prompt is split into chunks; the attention/KV plane is.

``submit()`` validates rather than trusting ``step()`` to survive: an
empty or over-max_len prompt, or a paged request whose worst-case block
footprint exceeds pool capacity (it could never be admitted and would
head-of-line-block the queue forever), is rejected immediately —
``req.error`` set, ``req.done=True``, returned from ``run()`` with the
finished requests — and the engine keeps serving everyone else.
Sampling (serve.sampling) stays per-slot: each request carries its own
SamplingParams, temperature scaling runs through the CORDIC linear-rotation
multiply by the R2-LVC reciprocal, and every request draws from its own rng
key stream fold_in(fold_in(base, rid), t) — making the emitted tokens
independent of slot placement, batch composition, and KV layout.

Observability (repro.obs): construct the engine with ``obs=Observability()``
(optionally ``trace=True`` for a Chrome-trace/Perfetto request-lifecycle +
engine-phase timeline) and read ``obs.metrics.snapshot()`` afterwards. All
instrumentation is host-side: nothing here feeds a jitted function, so
compile counts and emitted tokens are bit-identical with observability on
or off (CI-enforced in tests/test_obs.py). The full metric-name reference
(every ``engine.*`` / ``kv.pool.*`` / ``prefix.*`` / ``fixed_point.*``
series, with types, units, and emission points) lives in
``docs/observability.md`` — the handles themselves are registered in
``_bind_obs_handles`` and ``KVPager.attach_metrics``, and CI's docs lane
cross-checks the doc against the registration code in both directions.

Sharding contract (``tp=N`` / ``mesh=``): the engine runs SPMD on a
("data","model") mesh (launch.mesh.make_host_mesh). Decode is still ONE
jitted dispatch per step; the GSPMD partitioner splits it across shards.
Emitted tokens are bit-identical per shard count (TP=1 == TP=2 == TP=4;
greedy + seeded sampling, GQA + MLA, dense/paged/pallas, chunked +
unchunked — tests/test_sharded_serving.py), and the collective schedule
is exactly one all-gather per decode step, at the logits, with none
inside the attention datapath (the HLO-cost lane asserts this):

    leaf / tensor                 PartitionSpec          why
    ----------------------------  ---------------------  -------------------
    wq / wk / wv / wo             heads on "model"       Megatron column/row
    mlp w_in / w_gate / w_out     d_ff on "model"        Megatron column/row
    embed table                   replicated (forced)    jnp.take must stay
                                                         shard-local
    lm_head table (untied)        vocab on "model"       -> the ONE logits
                                                         all-gather/step
    dense cache k / v             KH axis on "model"     head-parallel GQA
    paged k_pool / v_pool         (N,L,KH/tp,hd)/shard   head-parallel GQA
    MLA c_kv_pool / k_rope_pool   replicated             latent is head-less
    block tables / lens / idx     replicated             host metadata; the
                                                         refcounted KVPager
                                                         (and with it the
                                                         prefix cache's
                                                         block sharing)
                                                         stays shard-
                                                         agnostic: one
                                                         logical block id
                                                         space, every shard
                                                         holds a head-slice
                                                         of every block
    tokens/rids/steps/temps/...   replicated             tiny host state
    logits                        replicated (pinned in  sampling tail runs
                                  transformer.apply)     shard-local, bit-
                                                         identical per tp

Tied-embeddings models replicate the head too (carve-out: zero
all-gathers). The paged-attention Pallas kernel runs under shard_map
(kernels.paged_attention.shard_local_*) — per-shard head slices against
replicated tables, grid unchanged — since pallas_call is opaque to the
partitioner; engine init enforces head % tp == 0 on that path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_lib
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.serve import kv_pager as kvp
from repro.serve import sampling as sp
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import IterationScheduler, PrefillRow


def make_prefill_step(cfg):
    def prefill(params, cache, batch):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        return logits[:, -1], cache
    return prefill


def make_bucketed_prefill_step(cfg):
    """Dense prefill over one bucket-padded prompt segment: runs the
    segment through the cache, pins the cache position counters to
    ``pin_len`` and hands back the logits row at ``logit_idx``.

    Single-shot (the legacy path): the segment is the whole bucket-padded
    prompt, ``pin_len`` is the real prompt length and ``logit_idx`` is its
    last real position — the pad tail is causally invisible to that row
    and is overwritten by decode writes, so padding never changes the
    emitted tokens. One compile per bucket width instead of one per
    distinct prompt length.

    Chunked prefill reuses the same function per chunk: a mid-prompt chunk
    pins ``pin_len`` to the chunk frontier (its logits are discarded, so
    ``logit_idx`` is any in-range row) and the final chunk pins the true
    length and indexes the last real position relative to its own start.
    """
    def prefill(params, cache, batch, pin_len, logit_idx):
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        cache = tf.override_cache_length(cache, pin_len)
        last = jax.lax.dynamic_index_in_dim(logits, logit_idx, axis=1,
                                            keepdims=False)
        return last, cache
    return prefill


def make_paged_prefill_step(cfg):
    """Multi-row prefill straight into pool blocks: R scheduled prompt
    segments (whole prompts, or ``prefill_chunk``-wide chunks of longer
    ones) run as ONE batch-R apply against the shared pools. No dense
    max_len cache is materialized and nothing is copied at insert.

    Per row ``r`` of the dispatch:
        tokens[r]     — (W,) bucket/chunk-padded segment tokens
        slot_ids[r]   — the seated slot
        view_rows[r]  — block-table row the apply reads/writes through:
                        the slot's real allocation up to the last block
                        holding a position this row can see, every entry
                        past that redirected to the scratch block (the
                        tail-write trim: pad positions scatter into
                        scratch instead of burning pool traffic on blocks
                        whose content is never read). Pad rows (R is
                        pow2-padded) are all-scratch.
        full_rows[r]  — the slot's real allocation, registered on the
                        device after the apply so decode writes land in
                        live blocks
        start_lens[r] — first position this segment covers (0 for a fresh
                        admission, the chunk frontier for a continuation);
                        block-aligned, feeds RoPE positions + pool-write
                        offsets
        pin_lens[r]   — length the slot is pinned to afterwards: the true
                        prompt length on a final row, the new chunk
                        frontier mid-prompt
        logit_idx[r]  — segment-relative row of the logits to return (the
                        last real position on final rows; discarded
                        otherwise)
        valid[r]      — False for pad rows: their slot registration is
                        masked out entirely, so a pad row may alias a live
                        slot id without clobbering it

    Tables/lens enter the apply *by value* (paged_pool_view) rather than
    through a device gather, so pad rows never read or corrupt real slot
    state; only the pools carry updates back (paged_pool_merge) and slot
    registration is a separate masked write (paged_set_rows)."""
    def prefill(params, caches, tokens, slot_ids, view_rows, full_rows,
                start_lens, pin_lens, logit_idx, valid):
        view = tf.paged_pool_view(cfg, caches, view_rows, start_lens)
        logits, _, nview = tf.apply(params, {"tokens": tokens}, cfg,
                                    cache=view)
        caches = tf.paged_pool_merge(cfg, caches, nview)
        caches = tf.paged_set_rows(cfg, caches, slot_ids, full_rows,
                                   pin_lens, valid)
        last = jax.vmap(lambda row, i: jax.lax.dynamic_index_in_dim(
            row, i, axis=0, keepdims=False))(logits, logit_idx)
        return last, caches
    return prefill


def make_decode_step(cfg, *, greedy: bool = True, temperature: float = 1.0):
    """Single-cache decode step (the shape the dry-run lowers; the engine
    itself uses make_batched_decode_step over stacked slot caches)."""
    def decode(params, cache, tokens, rng=None):
        """tokens: (B,1) int32 (or (B,1,d) embeds). Returns next token ids.

        Sampling decode consumes `rng` — the caller threads a fresh split
        per step; reusing one key would make every step/batch draw the
        same sample.
        """
        batch = ({"tokens": tokens} if cfg.input_mode == "tokens"
                 else {"embeds": tokens})
        logits, _, cache = tf.apply(params, batch, cfg, cache=cache)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            if rng is None:
                raise ValueError("sampling decode requires an rng key")
            nxt = jax.random.categorical(
                rng, last / temperature).astype(jnp.int32)
        return nxt, cache
    return decode


def _sample_step(last, rids, steps, temps, top_ks, greedy, base_key,
                 greedy_only: bool):
    """Shared tail of the batched decode variants: (S,V) last-position
    logits -> (S,) next tokens. ``greedy_only`` compiles the argmax-only
    datapath; greedy tokens are argmax of the raw logits in BOTH variants,
    so which one runs never changes the output."""
    if greedy_only:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    keys = jax.vmap(lambda r, t: sp.request_key(base_key, r, t))(rids, steps)
    return sp.sample_batched(last, keys, temps, top_ks, greedy)


def make_batched_decode_step(cfg, *, greedy_only: bool = False):
    """One jitted decode for ALL slots of a *dense* stacked cache: vmap
    over the stacked (slots, 1, ...) cache axis.

    Arguments of the returned function (S = slot count):
        params        — model params (broadcast across slots)
        caches        — stacked (S, 1, ...) cache pytree (stack_caches)
        tokens        — (S, 1) int32 previous token per slot
        rids, steps   — (S,) int32: request id + token index, hashed into
                        per-slot keys fold_in(fold_in(base_key, rid), step)
        temps, top_ks, greedy — (S,) per-slot SamplingParams (traced, so a
                        changed request mix never recompiles)
        base_key      — engine-level PRNG key

    Returns ((S,) int32 next tokens, updated stacked caches). Inactive
    slots decode garbage tokens against their stale caches — the engine
    masks them on the host; their caches are re-prefilled at admission.
    """
    def decode(params, caches, tokens, rids, steps, temps, top_ks, greedy,
               base_key):
        def one(cache, tok):
            logits, _, nc = tf.apply(params, {"tokens": tok[None, :]}, cfg,
                                     cache=cache)
            return logits[0, -1], nc

        last, caches = jax.vmap(one)(caches, tokens)
        nxt = _sample_step(last, rids, steps, temps, top_ks, greedy,
                           base_key, greedy_only)
        return nxt, caches
    return decode


def make_paged_decode_step(cfg, *, greedy_only: bool = False):
    """One jitted decode for ALL slots of a *paged* cache: a single
    batch-``slots`` apply — the block pool is global, so there is no
    per-slot cache axis to vmap; per-slot positions live in the cache's
    ``lens`` leaves and each row attends its own table-gathered blocks.
    Same signature and same emitted tokens as make_batched_decode_step."""
    def decode(params, caches, tokens, rids, steps, temps, top_ks, greedy,
               base_key):
        logits, _, caches = tf.apply(params, {"tokens": tokens}, cfg,
                                     cache=caches)
        nxt = _sample_step(logits[:, -1], rids, steps, temps, top_ks, greedy,
                           base_key, greedy_only)
        return nxt, caches
    return decode


def make_score_step(cfg):
    """Teacher-forced per-token log-probs of a prompt.

    The log-softmax datapath follows ``cfg.loss_impl`` (exact | cordic |
    cordic_pallas — repro.train.losses), so served log-prob scoring uses
    the same CORDIC exp/log legs as the training loss.
    """
    from repro.train import losses

    logp_fn = losses.log_softmax_fn(getattr(cfg, "loss_impl", "exact"))

    def score(params, batch):
        """batch: {"tokens": (B,S)}. Returns (B,S-1) log p(token_t | <t)."""
        logits, _, _ = tf.apply(params, batch, cfg, cache=None)
        logp = logp_fn(logits[:, :-1])
        nxt = batch["tokens"][:, 1:]
        return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]

    return score


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 16
    sampling: Optional[SamplingParams] = None   # None -> engine default
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: set when submit() rejects the request (over-long prompt, impossible
    #: block footprint, ...); a rejected request is done with out == []
    error: Optional[str] = None
    # lifecycle timestamps: absolute time.perf_counter() seconds, stamped
    # unconditionally (obs attached or not, so a post-warm-up attach_obs
    # still observes requests queued earlier); -1 = stage not reached
    t_enqueue: float = dataclasses.field(default=-1.0, repr=False)
    t_admit: float = dataclasses.field(default=-1.0, repr=False)
    t_first: float = dataclasses.field(default=-1.0, repr=False)
    t_finish: float = dataclasses.field(default=-1.0, repr=False)


class ServeEngine:
    """Slot-based continuous batching on top of bucketed prefill + one
    batched decode, over a dense or paged KV plane (see module docstring).

    Static batch of `slots`; each slot holds one request and an active-slot
    mask tracks occupancy. Admission pads the prompt to a length bucket,
    prefills it (into a fresh stacked-tree slot for ``dense``, straight
    into freshly allocated pool blocks for ``paged``), and emits the first
    token; every `step()` then advances ALL slots with exactly one jitted
    decode call and appends the sampled token to each active request.
    Finished slots release their blocks (paged) and are refilled from the
    queue between steps, head-of-queue first — a head that does not fit
    the pool blocks admission until something frees (FIFO backpressure).
    Per-request sampling params can mix greedy / temperature / top-k within
    one batch (see serve.sampling).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_token: Optional[int] = None, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 softmax_impl: Optional[str] = None,
                 loss_impl: Optional[str] = None,
                 kv_impl: Optional[str] = None,
                 block_len: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 paged_attend_impl: Optional[str] = None,
                 kv_quant: Optional[str] = None,
                 prefill_chunk: Optional[int] = None,
                 prefill_batch: Optional[int] = None,
                 max_prefill_tokens: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefix_eviction: str = "lru",
                 obs: Optional[obs_lib.Observability] = None,
                 tp: Optional[int] = None,
                 mesh: Optional[Any] = None):
        assert cfg.input_mode == "tokens", "engine serves token LMs"
        self.obs = obs if obs is not None else obs_lib.NULL
        if softmax_impl is not None:
            cfg = dataclasses.replace(cfg, softmax_impl=softmax_impl)
        if loss_impl is not None:
            cfg = dataclasses.replace(cfg, loss_impl=loss_impl)
        if kv_impl is not None:
            cfg = dataclasses.replace(cfg, kv_impl=kv_impl)
        if block_len is not None:
            cfg = dataclasses.replace(cfg, kv_block_len=block_len)
        if paged_attend_impl is not None:
            cfg = dataclasses.replace(cfg, paged_attend_impl=paged_attend_impl)
        if kv_quant is not None:
            cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_token
        self.kv_impl = getattr(cfg, "kv_impl", "dense")
        self.block_len = getattr(cfg, "kv_block_len", 16)
        if self.kv_impl not in ("dense", "paged"):
            raise ValueError(f"unknown kv_impl {self.kv_impl!r}")
        self.paged_attend_impl = getattr(cfg, "paged_attend_impl", "gather")
        if self.paged_attend_impl not in ("gather", "pallas"):
            raise ValueError(
                f"unknown paged_attend_impl {self.paged_attend_impl!r}")
        if self.paged_attend_impl == "pallas" and self.kv_impl != "paged":
            raise ValueError(
                "paged_attend_impl='pallas' selects the block-walking "
                "decode kernel over the paged KV plane; serve it with "
                "kv_impl='paged' (the dense plane has no block tables)")
        if self.paged_attend_impl == "pallas" and cfg.score_dtype != "f32":
            # fail at init, not mid-serving out of the first decode trace
            # (models.attention._paged_attend_impl enforces the same rule)
            raise ValueError(
                "paged_attend_impl='pallas' supports score_dtype='f32' "
                f"only (got {cfg.score_dtype!r})")
        # -- quantized paged-KV plane (core/kv_quant.py) --------------------
        from repro.core import kv_quant as kvq_mod

        self.kv_quant = getattr(cfg, "kv_quant", "none")
        self._kv_quant_spec = kvq_mod.spec_for(self.kv_quant)  # raises on typo
        if self._kv_quant_spec is not None:
            if self.kv_impl != "paged":
                raise ValueError(
                    "kv_quant quantizes the paged block pools; serve it "
                    "with kv_impl='paged' (the dense plane stays full-"
                    f"width), got kv_impl={self.kv_impl!r}")
            if getattr(cfg, "mla", None) is not None or any(
                    k.startswith("mla") for k in cfg.block_pattern):
                raise ValueError(
                    "kv_quant applies to GQA paged pools only; MLA layers "
                    "store the compressed latent unquantized")
        if self.paged_attend_impl == "pallas":
            # the kv_dtype seam the kernel replays is cfg.dtype — reject
            # unknown/integer dtypes at init instead of letting them fall
            # through to the pool dtype mid-serving (kernels validate too)
            from repro.kernels.paged_attention import canonical_kv_dtype

            canonical_kv_dtype(cfg.dtype)
        # -- tensor-parallel mesh (tentpole refactor; see docstring table) --
        # tp=N resolves to a ("data","model") host mesh with an N-wide
        # model axis; mesh=None/tp=1 is the legacy single-device path
        # byte-for-byte (mesh_or_none never builds a trivial mesh).
        if mesh is None and tp is not None:
            from repro.launch import mesh as mesh_lib

            mesh = mesh_lib.mesh_or_none(tp)
        self.mesh = mesh
        self.tp = int(mesh.shape["model"]) if mesh is not None else 1
        if mesh is not None and self.paged_attend_impl == "pallas":
            # The block-walking kernel runs under shard_map with a strict
            # head-axis split (pallas_call is opaque to GSPMD, so there is
            # no replicated fallback on this path — the gather/dense paths
            # fall back via spec_for_axes divisibility instead).
            from repro.models.attention import _padded_heads

            if getattr(cfg, "mla", None) is not None:
                n_heads, axis = cfg.num_heads, "num_heads"
            else:
                n_heads, axis = _padded_heads(cfg)[1], "kv heads (padded)"
            if n_heads % self.tp:
                raise ValueError(
                    f"paged_attend_impl='pallas' shards attention heads "
                    f"over the model axis: {axis}={n_heads} is not "
                    f"divisible by tp={self.tp}")
        if mesh is not None:
            self._param_sh = shd.serve_param_shardings(cfg, self.params, mesh)
            self.params = jax.device_put(self.params, self._param_sh)
            self._repl = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
        self.buckets = kvp.bucket_lengths(max_len, self.block_len)
        # Bucket-pad prefills only for attention-cache families: causal
        # attention makes the pad tail invisible to the last real position,
        # but recurrent blocks (mamba2/xlstm) would fold pad tokens into
        # their state. Recurrent/hybrid archs prefill at exact prompt
        # length (one compile per distinct length, as before) until the
        # scans learn position masking.
        blk_kinds = set(cfg.block_pattern) | (
            {cfg.shared_block} if cfg.shared_block is not None else set())
        self._bucketed = blk_kinds <= set(tf.PAGED_CACHE_FNS)
        self.default_sampling = (sampling if sampling is not None
                                 else SamplingParams(temperature=temperature,
                                                     greedy=greedy))
        self._base_key = jax.random.PRNGKey(seed)
        # iteration-level prefill policy (serve/scheduler.py): chunk
        # continuations + FIFO admissions under a token budget. With
        # chunk=None / batch=1 (the defaults) the plan degenerates to the
        # legacy one-single-shot-prompt-per-dispatch admission, bit-for-bit.
        self.scheduler = IterationScheduler(
            buckets=self.buckets if self._bucketed else None,
            block_len=self.block_len, max_len=max_len,
            prefill_chunk=prefill_chunk,
            max_prefill_tokens=max_prefill_tokens)
        self.prefill_chunk = prefill_chunk
        if prefill_batch is None:
            prefill_batch = (slots if (prefill_chunk is not None
                                       and self.kv_impl == "paged") else 1)
        if prefill_batch < 1:
            raise ValueError(f"prefill_batch must be >= 1, got {prefill_batch}")
        if prefill_batch > 1 and self.kv_impl != "paged":
            # dense prefill builds one fresh cache per request; batching
            # rows is a paged-plane feature (multi-row block-table binding)
            prefill_batch = 1
        self.prefill_batch = int(prefill_batch)

        if self.kv_impl == "paged":
            if not self._bucketed:
                # block-granular prefill writes need block-aligned (i.e.
                # bucket-padded) widths, and padding is only output-neutral
                # for attention; recurrent families keep the dense plane
                raise ValueError(
                    "paged KV requires an attention-cache-only arch "
                    f"(block pattern {sorted(blk_kinds)} includes recurrent "
                    "blocks); serve it with kv_impl='dense'")
            if max_len % self.block_len:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"block_len {self.block_len}")
            self.max_blocks = max_len // self.block_len
            if num_blocks is None:
                # worst-case default: every slot full-length, + scratch
                num_blocks = slots * self.max_blocks + 1
            self.pager: Optional[kvp.KVPager] = kvp.KVPager(
                num_blocks, self.block_len, slots,
                metrics=self.obs.metrics if self.obs.enabled else None)
            if prefix_cache:
                from repro.serve.prefix_cache import PrefixCache

                # block-table indirection + refcounts make sharing shard-
                # safe for free: one logical block id space per engine
                # regardless of tp (see module docstring table)
                self.prefix: Optional[PrefixCache] = PrefixCache(
                    self.pager, self.block_len, policy=prefix_eviction)
            else:
                self.prefix = None
            self._caches = tf.init_paged_cache(
                cfg, slots, num_blocks, self.block_len, self.max_blocks,
                jnp.float32)
            # device bytes per block across layers (codes + quant scales):
            # feeds the pager's kv.pool.bytes_in_use gauge and the
            # kv.quant.bytes_per_token series the bench gates on
            self.pager.block_bytes = self.kv_pool_bytes() // num_blocks

            def _clear_fn(caches, slot):
                return tf.paged_set_slot(
                    cfg, caches, slot,
                    jnp.zeros((self.max_blocks,), jnp.int32),
                    jnp.zeros((), jnp.int32))

            if self.mesh is not None:
                # head-sharded pools, everything else (tables/lens/latent)
                # replicated; explicit in/out shardings on every jit so
                # decode stays ONE dispatch and cache state round-trips
                # without resharding (donation stays in place)
                self._cache_sh = shd.kv_cache_shardings(self._caches,
                                                        self.mesh)
                self._caches = jax.device_put(self._caches, self._cache_sh)
                repl = self._repl
                self._prefill = jax.jit(
                    make_paged_prefill_step(cfg), donate_argnums=(1,),
                    in_shardings=(self._param_sh, self._cache_sh)
                    + (repl,) * 8,
                    out_shardings=(repl, self._cache_sh))
                decode_sh = dict(
                    in_shardings=(self._param_sh, self._cache_sh)
                    + (repl,) * 7,
                    out_shardings=(repl, self._cache_sh))
                sample_fn = jax.jit(make_paged_decode_step(cfg), **decode_sh)
                greedy_fn = jax.jit(
                    make_paged_decode_step(cfg, greedy_only=True),
                    **decode_sh)
                self._clear_slot = jax.jit(
                    _clear_fn, donate_argnums=(0,),
                    in_shardings=(self._cache_sh, repl),
                    out_shardings=self._cache_sh)
            else:
                self._prefill = jax.jit(make_paged_prefill_step(cfg),
                                        donate_argnums=(1,))
                sample_fn = jax.jit(make_paged_decode_step(cfg))
                greedy_fn = jax.jit(
                    make_paged_decode_step(cfg, greedy_only=True))
                self._clear_slot = jax.jit(_clear_fn, donate_argnums=(0,))
        else:
            if prefix_cache:
                raise ValueError(
                    "prefix_cache shares KV pool blocks through the block-"
                    "table indirection; serve it with kv_impl='paged' (the "
                    "dense plane has per-slot buffers, nothing to share)")
            self.pager = None
            self.prefix = None
            self._caches = tf.stack_caches(
                [tf.init_cache(cfg, 1, max_len, jnp.float32)
                 for _ in range(slots)])
            if self.mesh is not None:
                self._cache_sh = shd.kv_cache_shardings(self._caches,
                                                        self.mesh)
                self._caches = jax.device_put(self._caches, self._cache_sh)
                # batch-1 per-request cache template (prefill in/out +
                # insert_slot's second arg): same KH-sharded leaves
                p1 = jax.eval_shape(
                    lambda: tf.init_cache(cfg, 1, max_len, jnp.float32))
                self._p1_sh = shd.kv_cache_shardings(p1, self.mesh)
                repl = self._repl
                self._prefill = jax.jit(
                    make_bucketed_prefill_step(cfg),
                    in_shardings=(self._param_sh, self._p1_sh, repl, repl,
                                  repl),
                    out_shardings=(repl, self._p1_sh))
                decode_sh = dict(
                    in_shardings=(self._param_sh, self._cache_sh)
                    + (repl,) * 7,
                    out_shardings=(repl, self._cache_sh))
                sample_fn = jax.jit(make_batched_decode_step(cfg),
                                    **decode_sh)
                greedy_fn = jax.jit(
                    make_batched_decode_step(cfg, greedy_only=True),
                    **decode_sh)

                def _insert_fn(stacked, cache, slot):
                    return jax.tree.map(
                        lambda full, one: jax.lax.dynamic_update_index_in_dim(
                            full, one.astype(full.dtype), slot, 0),
                        stacked, cache)

                # engine-owned mesh-aware insert (tf.insert_slot's module-
                # level jit carries no shardings; an explicit one keeps the
                # donated stacked tree's sharding stable across admissions)
                self._insert_jit = jax.jit(
                    _insert_fn, donate_argnums=(0,),
                    in_shardings=(self._cache_sh, self._p1_sh, repl),
                    out_shardings=self._cache_sh)
            else:
                self._prefill = jax.jit(make_bucketed_prefill_step(cfg))
                sample_fn = jax.jit(make_batched_decode_step(cfg))
                greedy_fn = jax.jit(
                    make_batched_decode_step(cfg, greedy_only=True))
                self._insert_jit = None

        def _dispatch(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key):
            # all-greedy batches take the argmax-only compile (no sampling
            # datapath); tokens are identical either way, see _sample_step
            fn = greedy_fn if bool(np.asarray(greedy).all()) else sample_fn
            return fn(params, caches, tokens, rids, steps, temps, top_ks,
                      greedy, base_key)

        self._decode = _dispatch
        self._decode_jits = (greedy_fn, sample_fn)
        self._sample = jax.jit(sp.sample_batched)
        if self.mesh is not None:
            self._score = jax.jit(make_score_step(cfg),
                                  in_shardings=(self._param_sh, self._repl),
                                  out_shardings=self._repl)
        else:
            self._score = jax.jit(make_score_step(cfg))
        self._done: List[Request] = []
        self._active: List[Optional[Request]] = [None] * slots
        # per-slot full block-table rows (paged; built at admission, reused
        # by every chunk dispatch) and mid-prefill partial caches (dense
        # chunking; held host-side, inserted into the stacked tree only
        # when the final chunk lands)
        self._slot_rows: Dict[int, np.ndarray] = {}
        self._pending: Dict[int, Any] = {}
        self._next_tok = np.zeros((slots, 1), np.int32)
        # per-slot host state mirrored into the batched decode each step
        self._rids = np.zeros(slots, np.int32)
        self._steps = np.zeros(slots, np.int32)    # == len(req.out) per slot
        self._temps = np.ones(slots, np.float32)
        self._top_ks = np.zeros(slots, np.int32)
        self._greedy = np.ones(slots, bool)

        self._bind_obs_handles()

    def _bind_obs_handles(self) -> None:
        # observability handles (null no-ops when obs is disabled; the
        # metric name/type/unit table lives in the module docstring)
        m = self.obs.metrics
        self._m_submitted = m.counter("engine.requests.submitted",
                                      unit="requests")
        self._m_rejected = m.counter("engine.requests.rejected",
                                     unit="requests")
        self._m_finished = m.counter("engine.requests.finished",
                                     unit="requests")
        self._m_pre_disp = m.counter("engine.prefill.dispatches",
                                     unit="calls")
        self._m_pre_rows = m.counter("engine.prefill.rows", unit="rows")
        self._m_pre_chunks = m.counter("engine.prefill.chunks", unit="rows")
        self._m_pre_tokens = m.counter("engine.prefill.tokens", unit="tokens")
        # prefix-cache series (stay zero with the cache off; the bench
        # gate reads prefill.tokens + pool peak to prove the collapse)
        self._m_prefix_hits = m.counter("prefix.hit_tokens", unit="tokens")
        self._m_prefix_shared = m.gauge("prefix.blocks_shared",
                                        unit="blocks")
        self._m_blocks_saved = m.counter("kv.pool.blocks_saved",
                                         unit="blocks")
        self._m_tokens = m.counter("engine.tokens.emitted", unit="tokens")
        self._m_steps = m.counter("engine.steps", unit="steps")
        self._m_queue = m.gauge("engine.queue_depth", unit="requests")
        self._m_occ = m.gauge("engine.batch_occupancy", unit="slots")
        # mesh topology gauges: constant per engine lifetime, set once so
        # every metrics snapshot records what topology produced it
        self._m_mesh_tp = m.gauge("engine.mesh.tp", unit="shards")
        self._m_mesh_dev = m.gauge("engine.mesh.devices", unit="devices")
        self._m_mesh_tp.set(self.tp)
        self._m_mesh_dev.set(self.mesh.size if self.mesh is not None else 1)
        # quantized-KV series: format choice and pool geometry are fixed at
        # init, so (like the mesh gauges) these are set once per bind —
        # code_bits is the pool lane width (32 means unquantized), and
        # bytes_per_token is resident pool bytes per position of capacity
        # (codes + scales), the number the kv_quant bench section gates
        self._m_kvq_bits = m.gauge("kv.quant.code_bits", unit="bits")
        self._m_kvq_bpt = m.gauge("kv.quant.bytes_per_token", unit="bytes")
        spec = self._kv_quant_spec
        self._m_kvq_bits.set(spec.code_bits if spec is not None else 32)
        if self.pager is not None and self.pager.block_bytes:
            self._m_kvq_bpt.set(self.pager.block_bytes / self.block_len)
        self._m_ttft = m.histogram("engine.ttft_ms", unit="ms")
        self._m_tpot = m.histogram("engine.tpot_ms", unit="ms")
        self._m_e2e = m.histogram("engine.e2e_ms", unit="ms")
        self._m_prefill = m.histogram("engine.prefill_ms", unit="ms")
        self._m_step = m.histogram("engine.step_ms", unit="ms")
        self._m_compiles = {
            "prefill": m.counter("engine.compiles.prefill", unit="compiles"),
            "decode": m.counter("engine.compiles.decode", unit="compiles"),
        }
        self._last_compiles = (self.compile_counts() if self.obs.enabled
                               else None)
        if self.pager is not None:
            self.pager.attach_metrics(m if self.obs.enabled else None)

    def attach_obs(self, obs: Optional[obs_lib.Observability]) -> None:
        """Attach (or replace, or with None detach) the observability
        handle mid-lifetime — e.g. after a warm-up pass, so compile walls
        stay out of the latency histograms. Metrics recorded so far stay
        in the previous handle's registry; compile counters restart from
        the current jit-cache sizes."""
        self.obs = obs if obs is not None else obs_lib.NULL
        self._bind_obs_handles()

    def kv_pool_bytes(self) -> int:
        """Resident device bytes of the paged pool leaves across layers —
        K/V code pools plus, under kv_quant, the per-block scale pools
        (every ``*_pool`` leaf). This is the footprint quantization
        shrinks; the kv_quant bench section compares it across formats at
        matched block count. 0 on the dense plane."""
        if self.kv_impl != "paged":
            return 0
        total = 0

        def one(path, leaf):
            nonlocal total
            name = getattr(path[-1], "key", None)
            if isinstance(name, str) and name.endswith("_pool"):
                total += leaf.size * jnp.dtype(leaf.dtype).itemsize

        jax.tree_util.tree_map_with_path(one, self._caches)
        return total

    def _obs_compiles(self) -> None:
        """Fold compile_counts() deltas into compile counters + trace
        instants — jit-cache growth observed from the host, never traced."""
        if not self.obs.enabled:
            return
        counts = self.compile_counts()
        for kind, n in counts.items():
            d = n - self._last_compiles[kind]
            if d > 0:
                self._m_compiles[kind].inc(d)
                if self.obs.trace is not None:
                    self.obs.trace.instant(f"compile:{kind}",
                                           self.obs.now_us(),
                                           args={"cache_size": n})
        self._last_compiles = counts

    def _obs_prefilled(self, req: Request, first: int) -> None:
        """Prefill-completion lifecycle record: prefill span (admit ->
        first token, chunk interleaving included), TTFT (enqueue -> first
        token, queueing included), first-token event + compiles. The
        timestamp is stamped whether obs is attached or not."""
        now = time.perf_counter()
        req.t_first = now
        if not self.obs.enabled:
            return
        self._m_prefill.observe((now - req.t_admit) * 1e3)
        if req.t_enqueue >= 0:
            self._m_ttft.observe((now - req.t_enqueue) * 1e3)
        self._m_tokens.inc()
        # stamps are absolute perf_counter values; the trace timeline is
        # relative to this obs handle's epoch (clamped: a request admitted
        # before a later attach_obs starts its span at the epoch)
        self.obs.request_span("prefill", req.rid,
                              max(0.0, req.t_admit - self.obs.epoch))
        self.obs.request_event("first_token", req.rid, {"token": first})
        self._obs_compiles()

    @property
    def _queue(self):
        """Pending (validated, unadmitted) requests — the scheduler's FIFO
        deque. Exposed for introspection; mutate only through submit()."""
        return self.scheduler.queue

    def _validate(self, req: Request) -> Optional[str]:
        """Reason this request can never be served, or None if admissible.
        Catching these at submit() keeps one bad request from killing (or
        permanently head-of-line-blocking) the serving loop: an over-long
        prompt used to raise ValueError out of bucket_for deep inside
        step(), and an over-capacity paged request was only detected once
        the engine went fully idle."""
        plen = len(req.prompt)
        if plen < 1:
            return "empty prompt"
        if plen > self.max_len:
            return (f"prompt length {plen} exceeds engine max_len "
                    f"{self.max_len}")
        if self.pager is not None:
            need = self._blocks_for(req)
            if need > self.pager.capacity:
                return (f"needs {need} KV blocks worst-case — admission "
                        f"budgets the unshared footprint, which with no "
                        f"prefix hit is the whole request — but the pool "
                        f"has {self.pager.capacity} allocatable")
        return None

    def _reject(self, req: Request, reason: str) -> None:
        req.error = f"rejected at submit: {reason}"
        req.done = True
        self._m_submitted.inc()
        self._m_rejected.inc()
        if self.obs.enabled:
            self.obs.request_event("reject", req.rid, {"reason": reason})
        self._done.append(req)

    def submit(self, req: Request) -> None:
        """Validate and enqueue one request. Inadmissible requests are
        rejected immediately (``req.error`` set, ``done=True``, surfaced in
        ``run()``'s result) — the engine keeps serving. Budgets that would
        decode past max_len are truncated here, once, not re-scanned per
        admission."""
        req.t_enqueue = time.perf_counter()
        err = self._validate(req)
        if err is not None:
            self._reject(req, err)
            return
        self._clamp_budget(req)
        self._m_submitted.inc()
        if self.obs.enabled:
            self.obs.request_event("enqueue", req.rid,
                                   {"prompt_len": len(req.prompt),
                                    "max_new_tokens": req.max_new_tokens})
        self.scheduler.enqueue(req)

    def score(self, prompt: np.ndarray) -> np.ndarray:
        """(S,) int32 prompt -> (S-1,) per-token log-probs (teacher-forced),
        through the cfg.loss_impl-selected log-softmax datapath."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        with shd.serving_mesh(self.mesh):
            out = self._score(self.params, {"tokens": toks})
        return np.asarray(out[0])

    @property
    def active_mask(self) -> np.ndarray:
        """(slots,) bool — which slots currently hold a request."""
        return np.asarray([a is not None for a in self._active])

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes of the serving datapath — the bucketed-prefill
        guarantee made checkable: after serving any mix of prompt lengths,
        ``prefill <= len(self.buckets) * chunk-variants`` and
        ``decode <= 2`` (argmax-only + sampling variants). Unchunked
        single-row serving (the defaults) keeps the tight legacy bound
        ``prefill <= len(self.buckets)``; chunking adds at most the chunk
        width, and multi-row batching multiplies by the pow2 batch dims
        (<= log2(prefill_batch)+1) — still O(log), never per-prompt-length.
        The prefill bound holds for attention-family archs; recurrent
        archs prefill at exact length (see _bucketed)."""
        return {
            "prefill": int(self._prefill._cache_size()),
            "decode": int(sum(fn._cache_size() for fn in self._decode_jits)),
        }

    def _finish(self, req: Request) -> None:
        req.done = True
        req.t_finish = time.perf_counter()
        if self.obs.enabled:
            self._m_finished.inc()
            if req.t_enqueue >= 0:
                self._m_e2e.observe((req.t_finish - req.t_enqueue) * 1e3)
            if req.t_first >= 0 and len(req.out) > 1:
                # mean decode interval: first token is TTFT's, the rest
                # amortize the decode steps (the standard TPOT definition)
                self._m_tpot.observe((req.t_finish - req.t_first)
                                     / (len(req.out) - 1) * 1e3)
            self.obs.request_event("finish", req.rid,
                                   {"tokens": len(req.out)})
        self._done.append(req)

    def _release_slot(self, s: int) -> None:
        """Return slot ``s`` to the free state: paged mode hands its blocks
        back to the pool and resets the device-side table row to scratch
        zeros (a vacant slot must never scribble on blocks that get
        reallocated); sampling knobs reset to greedy defaults so a vacated
        sampling slot can't pin _dispatch off the cheap all-greedy compile."""
        self._active[s] = None
        self._slot_rows.pop(s, None)
        self._pending.pop(s, None)
        self.scheduler.drop_slot(s)
        if self.pager is not None:
            # drops the slot's reference on every block it bound; blocks
            # the prefix cache (or a sibling slot) still references stay
            # resident, the rest rejoin the free list
            self.pager.free(s)
            if self.prefix is not None:
                self._m_prefix_shared.set(self.pager.blocks_shared)
            self._caches = self._clear_slot(self._caches,
                                            jnp.asarray(s, jnp.int32))
        self._temps[s] = 1.0
        self._top_ks[s] = 0
        self._greedy[s] = True

    def _sample_first(self, req: Request, logits) -> int:
        """Sample the prefill-emitted token (step 0 of the request's key
        stream) with the request's own SamplingParams."""
        temp, top_k, greedy = (req.sampling or self.default_sampling).resolved()
        key = sp.request_key(self._base_key, req.rid, 0)
        tok = self._sample(logits[:1], key[None],
                           jnp.full((1,), temp, jnp.float32),
                           jnp.full((1,), top_k, jnp.int32),
                           jnp.full((1,), greedy, bool))
        return int(tok[0])

    def _blocks_for(self, req: Request) -> int:
        """Pool blocks a request can ever touch: the bucket-padded prefill
        width or prompt + full decode budget, clamped to max_len."""
        need_len = min(max(kvp.bucket_for(len(req.prompt), self.buckets),
                           len(req.prompt) + req.max_new_tokens),
                       self.max_len)
        return kvp.blocks_needed(need_len, self.block_len)

    def _register_slot(self, s: int, req: Request, first: int) -> None:
        """Host-side mirrors for an admitted request."""
        self._active[s] = req
        self._next_tok[s, 0] = first
        temp, top_k, greedy = (req.sampling
                               or self.default_sampling).resolved()
        self._rids[s] = req.rid
        self._steps[s] = len(req.out)
        self._temps[s] = temp
        self._top_ks[s] = top_k
        self._greedy[s] = greedy

    def _finishes_at_prefill(self, req: Request, first: int) -> bool:
        """A request whose first token already hits `eos_token` or whose
        budget is max_new_tokens=1 finishes at admission and never
        occupies a slot."""
        req.out.append(first)
        if (self.eos is not None and first == self.eos) or \
                len(req.out) >= req.max_new_tokens:
            self._finish(req)
            return True
        return False

    def _clamp_budget(self, req: Request) -> None:
        """Truncate max_new_tokens so decode can never write past max_len:
        positions written are prompt..prompt+max_new-2, so the budget caps
        at max_len - len(prompt) + 1. Without this the dense path clamps
        its update into the last position and the paged path's clipped
        table index overwrites a live block — garbage either way, and
        differently, which would break the bit-identity contract. Applied
        once at submit()."""
        req.max_new_tokens = min(req.max_new_tokens,
                                 self.max_len - len(req.prompt) + 1)

    # -- the per-iteration prefill phase ------------------------------------
    def _admit_slot(self, req: Request):
        """Scheduler seating callback: pick a free slot and (paged)
        allocate the request's blocks — with the prefix cache on, only its
        *unshared footprint*: matched pool blocks bind into the slot's
        table (match's pins transfer to the slot) and prefill resumes past
        them, so a hit allocates and computes only the uncached tail.
        Returns the slot id, ``(slot, start)`` on a prefix hit, or None
        when the request cannot be seated right now (no free slot, or
        pool backpressure — the head waits, FIFO)."""
        s = next((i for i in range(self.slots)
                  if self._active[i] is None), None)
        if s is None:
            return None
        need = 0
        start = 0
        if self.pager is not None:
            need_total = self._blocks_for(req)
            shared: List[int] = []
            if self.prefix is not None:
                matched = self.prefix.match(req.prompt)      # pinned for us
                start = self.scheduler.resume_start(
                    len(req.prompt), len(matched) * self.block_len)
                m_used = start // self.block_len
                if m_used < len(matched):
                    # row-geometry alignment used fewer blocks than the
                    # cache matched: drop the surplus pins right away
                    self.pager.release(matched[m_used:])
                shared = matched[:m_used]
            need = need_total - len(shared)
            if self.prefix is not None and not self.pager.can_alloc(need):
                self.prefix.evict_until(need)
            blocks = self.pager.alloc(s, need, shared=shared)
            if blocks is None:
                if shared:                # unwind the match pins; re-match
                    self.pager.release(shared)        # on the next attempt
                return None
            row = np.zeros(self.max_blocks, np.int32)
            row[:len(shared)] = shared
            row[len(shared):need_total] = blocks
            self._slot_rows[s] = row
            if start:
                self._m_prefix_hits.inc(start)
                self._m_blocks_saved.inc(len(shared))
            if self.prefix is not None:
                self._m_prefix_shared.set(self.pager.blocks_shared)
        self._active[s] = req
        req.t_admit = time.perf_counter()
        if self.obs.enabled:
            ev = {"slot": s}
            if self.pager is not None:
                ev["blocks"] = need
                if start:
                    ev["prefix_tokens"] = start
            self.obs.request_event("admit", req.rid, ev)
        return (s, start) if start else s

    def _complete_prefill(self, req: Request, s: int, logits) -> None:
        """Final prefill row landed: sample the first token; the slot joins
        decode next iteration (or frees immediately on eos / budget-1).
        With the prefix cache on, the prompt's full blocks are indexed
        here — KV bytes for a prefix are deterministic (chunked-vs-
        unchunked identity already enforces this), so the blocks are
        shareable the moment the last prompt position is written."""
        first = self._sample_first(req, logits)
        self._obs_prefilled(req, first)
        if self.prefix is not None:
            nfull = len(req.prompt) // self.block_len
            if nfull:
                self.prefix.insert(
                    req.prompt,
                    [int(b) for b in self._slot_rows[s][:nfull]])
        if self._finishes_at_prefill(req, first):
            self._release_slot(s)
        else:
            self._register_slot(s, req, first)

    def _dispatch_prefill_paged(self, group: List[PrefillRow]) -> None:
        """One multi-row prefill dispatch over up to ``prefill_batch``
        scheduled rows, pow2-padded with all-scratch pad rows so compile
        batch dims stay bounded (see make_paged_prefill_step)."""
        rp = 1
        while rp < len(group):
            rp *= 2
        width = max(r.width for r in group)
        toks = np.zeros((rp, width), np.int32)
        slot_ids = np.zeros(rp, np.int32)
        view_rows = np.full((rp, self.max_blocks), kvp.SCRATCH_BLOCK,
                            np.int32)
        full_rows = np.zeros((rp, self.max_blocks), np.int32)
        starts = np.zeros(rp, np.int32)
        pins = np.zeros(rp, np.int32)
        lidx = np.zeros(rp, np.int32)
        valid = np.zeros(rp, bool)
        for i, row in enumerate(group):
            plen = len(row.req.prompt)
            hi = min(plen, row.start + width)
            seg = np.asarray(row.req.prompt[row.start:hi], np.int32)
            toks[i, :len(seg)] = seg
            frow = self._slot_rows[row.slot]
            # tail-write trim: entries past the last block holding a
            # position this row can see go to scratch
            nb_live = kvp.blocks_needed(hi, self.block_len)
            view_rows[i, :nb_live] = frow[:nb_live]
            full_rows[i] = frow
            slot_ids[i] = row.slot
            starts[i] = row.start
            pins[i] = plen if row.final else row.start + row.width
            lidx[i] = (plen - 1 - row.start) if row.final else 0
            valid[i] = True
        self._m_pre_disp.inc()
        logits, self._caches = self._prefill(
            self.params, self._caches, jnp.asarray(toks),
            jnp.asarray(slot_ids), jnp.asarray(view_rows),
            jnp.asarray(full_rows), jnp.asarray(starts),
            jnp.asarray(pins), jnp.asarray(lidx), jnp.asarray(valid))
        for i, row in enumerate(group):
            if row.final:
                self._complete_prefill(row.req, row.slot, logits[i:i + 1])

    def _dispatch_prefill_dense(self, row: PrefillRow) -> None:
        """One dense prefill row. A fresh row starts from an empty batch-1
        cache; a chunk continuation resumes the host-held partial cache.
        The cache only enters the stacked decode tree (insert_slot) when
        the final chunk lands — mid-prefill state never rides in decode."""
        req, s = row.req, row.slot
        plen = len(req.prompt)
        cache = (tf.init_cache(self.cfg, 1, self.max_len, jnp.float32)
                 if row.fresh else self._pending.pop(s))
        toks = np.zeros((1, row.width), np.int32)
        hi = min(plen, row.start + row.width)
        seg = np.asarray(req.prompt[row.start:hi], np.int32)
        toks[0, :len(seg)] = seg
        pin = plen if row.final else row.start + row.width
        li = (plen - 1 - row.start) if row.final else row.width - 1
        self._m_pre_disp.inc()
        logits, cache = self._prefill(
            self.params, cache, {"tokens": jnp.asarray(toks)},
            jnp.asarray(pin, jnp.int32), jnp.asarray(li, jnp.int32))
        if row.final:
            if self._insert_jit is not None:
                self._caches = self._insert_jit(self._caches, cache,
                                                jnp.asarray(s, jnp.int32))
            else:
                self._caches = tf.insert_slot(self._caches, cache, s)
            self._complete_prefill(req, s, logits)
        else:
            self._pending[s] = cache

    def _prefill_phase(self) -> int:
        """Run this iteration's scheduled prefill rows; returns how many.
        Paged rows pack into multi-row dispatches of up to prefill_batch;
        dense rows dispatch one at a time (fresh cache per request)."""
        rows = self.scheduler.plan(self._admit_slot)
        if not rows:
            return 0
        self._m_pre_rows.inc(len(rows))
        self._m_pre_tokens.inc(sum(r.width for r in rows))
        n_chunked = sum(1 for r in rows if not (r.fresh and r.final))
        if n_chunked:
            self._m_pre_chunks.inc(n_chunked)
        if self.kv_impl == "paged":
            # pack rows into multi-row dispatches, never letting a group's
            # shared width push any row past max_len: a resumed row's
            # start + its own width fits by construction (resume_start),
            # but a wider groupmate would widen it into scatter-index
            # clamping territory — flush the group instead
            group: List[PrefillRow] = []
            gw = 0
            for row in rows:
                w = max(gw, row.width)
                if group and (len(group) >= self.prefill_batch or any(
                        r.start + w > self.max_len for r in group + [row])):
                    self._dispatch_prefill_paged(group)
                    group, w = [], row.width
                group.append(row)
                gw = w
            if group:
                self._dispatch_prefill_paged(group)
        else:
            for row in rows:
                self._dispatch_prefill_dense(row)
        return len(rows)

    def step(self) -> int:
        """One engine iteration: the scheduler's prefill phase (chunk
        continuations + admissions), then one batched decode step across
        all decodable slots. Returns the number of slots that advanced
        (decoded slots, or scheduled prefill rows on a prefill-only
        iteration) — 0 means no work was, or could be, done.

        At most ONE jitted decode call regardless of slot count: inactive
        and mid-prefill slots ride along (their output is ignored; dense
        slots are re-prefilled at insert, paged slots' garbage writes land
        in scratch or in positions a later chunk/decode write overwrites
        before the length mask exposes them), so the dispatch count and
        the compiled shape never depend on occupancy — and regardless of
        tp: a sharded engine still issues ONE dispatch, the partitioner
        runs it SPMD across the mesh. An iteration whose only work is
        prefill (e.g. a long prompt still chunking, nothing decodable
        yet) skips the decode dispatch entirely.
        """
        # every trace this iteration performs (prefill/decode/clear/insert)
        # sees the engine's mesh (or None) via the ambient context — model
        # code reads it to place the logits constraint / shard_map attention
        with shd.serving_mesh(self.mesh):
            return self._step_impl()

    def _step_impl(self) -> int:
        ob = self.obs
        t_step = time.perf_counter()
        self._m_steps.inc()
        self._m_queue.set(len(self._queue))     # backlog before admission
        with ob.phase("admit"):
            n_rows = self._prefill_phase()
        chunking = self.scheduler.chunking
        decodable = [s for s in range(self.slots)
                     if self._active[s] is not None and s not in chunking]
        self._m_occ.set(len(decodable))
        if ob.trace is not None:
            ob.trace.counter("engine.load", ob.now_us(),
                             {"queue_depth": len(self._queue),
                              "batch_occupancy": len(decodable)})
        if not decodable:
            if n_rows == 0:
                if self._queue and self.pager is not None:
                    # defensive backstop: submit() rejects requests that
                    # can never fit, so a stuck idle queue means the pool
                    # invariants were bypassed
                    raise RuntimeError(
                        f"request {self._queue[0].rid} can never be "
                        f"admitted: needs "
                        f"{self._blocks_for(self._queue[0])} KV blocks "
                        f"worst-case (admission budgets the unshared "
                        f"footprint; with no prefix hit that is the whole "
                        f"request), pool has {self.pager.capacity} "
                        f"allocatable")
                return 0
            # prefill-only iteration: chunks advanced (or every admitted
            # request finished at prefill); no decode work exists yet
            if ob.enabled:
                self._m_step.observe((time.perf_counter() - t_step) * 1e3)
                self._obs_compiles()
            return n_rows
        # phase spans: dispatch ends when jax hands back async futures,
        # host_sync is the device->host block on the sampled tokens,
        # sample_copy is pure host bookkeeping over the decodable slots
        with ob.phase("dispatch"):
            nxt, self._caches = self._decode(
                self.params, self._caches, jnp.asarray(self._next_tok),
                jnp.asarray(self._rids), jnp.asarray(self._steps),
                jnp.asarray(self._temps), jnp.asarray(self._top_ks),
                jnp.asarray(self._greedy), self._base_key)
        if self.mesh is None:
            with ob.phase("host_sync"):
                nxt = np.asarray(nxt)
        else:
            # split the device wait: host_sync blocks on the cache state
            # (the per-shard attention datapath), collective covers the
            # remaining tail — the logits all-gather + sampling — so the
            # one serving collective's cost shows up in the phase
            # breakdown and the Chrome trace
            with ob.phase("host_sync"):
                jax.block_until_ready(self._caches)
            with ob.phase("collective"):
                nxt = np.asarray(nxt)
        with ob.phase("sample_copy"):
            for s in decodable:
                req = self._active[s]
                tok = int(nxt[s])
                req.out.append(tok)
                self._next_tok[s, 0] = tok
                self._steps[s] = len(req.out)
                ob.request_event("token", req.rid,
                                 {"step": len(req.out), "token": tok})
                if (self.eos is not None and tok == self.eos) or \
                        len(req.out) >= req.max_new_tokens:
                    self._finish(req)
                    self._release_slot(s)
        if ob.enabled:
            self._m_tokens.inc(len(decodable))
            self._m_step.observe((time.perf_counter() - t_step) * 1e3)
            self._obs_compiles()
        return len(decodable)

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns every submitted
        request in completion order — including requests submit() rejected
        (``req.error`` set, ``out == []``)."""
        while self._queue or any(a is not None for a in self._active):
            self.step()
        done, self._done = self._done, []
        return done
