"""Paged KV cache management: a host-side block allocator + the prefill
bucket policy.

The serving memory plane is a single global pool of fixed-size KV blocks
per attention layer — device leaves shaped ``(num_blocks, block_len, ...)``
(see models.attention.gqa_init_paged_cache) — and a per-slot *block table*
mapping each slot's logical positions onto pool blocks. This module owns
the host side of that scheme:

``KVPager``
    The free-list allocator. Block 0 is reserved as the *scratch block*:
    every empty table entry (and every table row of a vacant slot) points
    at it, so inactive slots riding along in the batched decode scatter
    their garbage writes into scratch instead of corrupting blocks that
    have been reallocated to live requests. Allocation is all-or-nothing
    per request — a request that does not fit stays in the queue
    (admission backpressure), it never partially holds blocks.

``bucket_lengths`` / ``bucket_for``
    The prefill bucket policy: prompts are padded up to a small geometric
    set of lengths (16, 32, 64, ... max_len), so the number of prefill
    compiles is bounded by the bucket count instead of growing with every
    distinct prompt length. Buckets are multiples of ``block_len`` so a
    padded prefill writes whole blocks. Padding is harmless for output:
    with causal attention the logits at the last *real* position never see
    the pad tail, and pad K/V land past the slot length mask (and are
    overwritten by decode writes).

Sharding: this module is deliberately *shard-agnostic*. Under the
tensor-parallel engine (``ServeEngine(tp=N)``) the pool's device leaves
are sharded over the mesh ``model`` axis on their kv-heads dimension, so
every shard holds ``(num_blocks, block_len, KH/N, dim)`` — the *same*
``num_blocks`` per shard, a head-slice of every block rather than a
block-slice of the pool. There is therefore exactly one logical block id
space: the allocator's free list and the per-slot block tables (which
stay replicated on device) are valid verbatim on every shard, and the
pager never needs to know the mesh exists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Pool block id reserved for garbage writes from vacant slots; never
#: allocated to a request and never read through a live mask.
SCRATCH_BLOCK = 0


def bucket_lengths(max_len: int, block_len: int = 16,
                   min_bucket: int = 16) -> Tuple[int, ...]:
    """Geometric prefill-length buckets up to ``max_len``.

    Every bucket is a multiple of ``block_len`` (whole-block prefill
    writes) and the last bucket is exactly ``max_len``. Doubling keeps the
    set small: len(buckets) == O(log(max_len / min_bucket)).
    """
    if max_len <= 0:
        raise ValueError(f"max_len must be positive, got {max_len}")
    base = -(-max(min_bucket, block_len) // block_len) * block_len
    out: List[int] = []
    b = base
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(sorted({min(b, max_len) for b in out}))


def bucket_for(length: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= ``length`` (the padded prefill width)."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


def blocks_needed(length: int, block_len: int) -> int:
    """Pool blocks required to hold ``length`` positions."""
    return -(-length // block_len)


@dataclasses.dataclass
class PagerStats:
    num_blocks: int            # pool size, including the scratch block
    blocks_in_use: int         # currently allocated to live requests
    blocks_free: int
    peak_in_use: int           # high-water mark since construction
    allocs: int                # successful allocations
    alloc_failures: int        # backpressure events (request stayed queued)


class KVPager:
    """Host-side free-list allocator over the global KV block pool.

    ``num_blocks`` counts the whole pool *including* the reserved scratch
    block, matching the device pool's leading axis. Capacity available to
    requests is therefore ``num_blocks - 1``.
    """

    def __init__(self, num_blocks: int, block_len: int, slots: int,
                 metrics=None):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is scratch)")
        if block_len < 1:
            raise ValueError(f"block_len must be >= 1, got {block_len}")
        self.num_blocks = num_blocks
        self.block_len = block_len
        self.slots = slots
        # LIFO free list: recently freed blocks are reused first, which
        # keeps the working set compact and exercises stale-block masking
        self._free: List[int] = list(range(num_blocks - 1, SCRATCH_BLOCK, -1))
        self._owned: Dict[int, List[int]] = {}
        self._peak = 0
        self._allocs = 0
        self._failures = 0
        self.attach_metrics(metrics)

    def attach_metrics(self, metrics) -> None:
        """Bind pool gauges/counters to a repro.obs MetricsRegistry (None
        detaches: updates become no-ops through the null registry)."""
        if metrics is None:
            from repro.obs.metrics import NULL_REGISTRY
            metrics = NULL_REGISTRY
        self._m_in_use = metrics.gauge("kv.pool.blocks_in_use",
                                       unit="blocks")
        self._m_allocs = metrics.counter("kv.pool.allocs", unit="allocs")
        self._m_failures = metrics.counter("kv.pool.alloc_failures",
                                           unit="events")
        self._m_freed = metrics.counter("kv.pool.blocks_freed",
                                        unit="blocks")

    # -- queries ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Blocks allocatable to requests (pool minus the scratch block).
        A request whose worst-case footprint exceeds this can *never* be
        admitted — the engine rejects it at submit() instead of letting it
        head-of-line-block the queue forever."""
        return self.num_blocks - 1

    @property
    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self._owned.values())

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned.get(slot, ()))

    def stats(self) -> PagerStats:
        return PagerStats(num_blocks=self.num_blocks,
                          blocks_in_use=self.blocks_in_use,
                          blocks_free=self.blocks_free,
                          peak_in_use=self._peak,
                          allocs=self._allocs,
                          alloc_failures=self._failures)

    # -- alloc / free -------------------------------------------------------
    def alloc(self, slot: int, n: int) -> Optional[List[int]]:
        """Allocate ``n`` blocks for ``slot``; all-or-nothing.

        Returns the block ids (order == logical block-table order) or None
        when the pool cannot satisfy the request — the caller leaves the
        request queued (backpressure), nothing is held.
        """
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already holds blocks "
                               f"{self._owned[slot]} (free it first)")
        if n < 1:
            raise ValueError(f"allocation must be >= 1 block, got {n}")
        if n > len(self._free):
            self._failures += 1
            self._m_failures.inc()        # backpressure stall: head waits
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._owned[slot] = blocks
        self._allocs += 1
        self._peak = max(self._peak, self.blocks_in_use)
        self._m_allocs.inc()
        self._m_in_use.set(self.blocks_in_use)
        return list(blocks)

    def free(self, slot: int) -> int:
        """Release every block held by ``slot``; returns how many."""
        blocks = self._owned.pop(slot, [])
        self._free.extend(reversed(blocks))
        if blocks:
            self._m_freed.inc(len(blocks))
            self._m_in_use.set(self.blocks_in_use)
        return len(blocks)
