"""Paged KV cache management: a host-side *refcounted* block allocator +
the prefill bucket policy.

The serving memory plane is a single global pool of fixed-size KV blocks
per attention layer — device leaves shaped ``(num_blocks, block_len, ...)``
(see models.attention.gqa_init_paged_cache) — and a per-slot *block table*
mapping each slot's logical positions onto pool blocks. This module owns
the host side of that scheme:

``KVPager``
    The refcounted allocator. Every resident block carries a reference
    count: one reference per slot table that binds it, plus one held by
    the prefix cache (serve/prefix_cache.py) when the block's tokens are
    indexed for reuse. ``alloc`` hands out fresh blocks at refcount 1;
    ``retain``/``release`` adjust counts when blocks are shared into
    another slot's table or dropped; a block returns to the free list
    only when its refcount reaches zero — so a prefix block shared by
    five requests is freed exactly once, after the last reference
    (including the cache's) lets go. Block 0 is reserved as the *scratch
    block* and is refcount-pinned at construction: every empty table
    entry (and every table row of a vacant slot) points at it, so
    inactive slots riding along in the batched decode scatter their
    garbage writes into scratch instead of corrupting blocks that have
    been reallocated to live requests, and no release path can ever put
    it on the free list. Allocation is all-or-nothing per request over
    its *unshared footprint*: admission counts only the fresh blocks a
    request needs beyond the prefix blocks it shares — a request that
    does not fit stays in the queue (admission backpressure), it never
    partially holds blocks.

``bucket_lengths`` / ``bucket_for``
    The prefill bucket policy: prompts are padded up to a small geometric
    set of lengths (16, 32, 64, ... max_len), so the number of prefill
    compiles is bounded by the bucket count instead of growing with every
    distinct prompt length. Buckets are multiples of ``block_len`` so a
    padded prefill writes whole blocks. Padding is harmless for output:
    with causal attention the logits at the last *real* position never see
    the pad tail, and pad K/V land past the slot length mask (and are
    overwritten by decode writes).

Sharding: this module is deliberately *shard-agnostic*. Under the
tensor-parallel engine (``ServeEngine(tp=N)``) the pool's device leaves
are sharded over the mesh ``model`` axis on their kv-heads dimension, so
every shard holds ``(num_blocks, block_len, KH/N, dim)`` — the *same*
``num_blocks`` per shard, a head-slice of every block rather than a
block-slice of the pool. There is therefore exactly one logical block id
space: the allocator's free list and the per-slot block tables (which
stay replicated on device) are valid verbatim on every shard, and the
pager never needs to know the mesh exists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Pool block id reserved for garbage writes from vacant slots; never
#: allocated to a request and never read through a live mask.
SCRATCH_BLOCK = 0


def bucket_lengths(max_len: int, block_len: int = 16,
                   min_bucket: int = 16) -> Tuple[int, ...]:
    """Geometric prefill-length buckets up to ``max_len``.

    Every bucket is a multiple of ``block_len`` (whole-block prefill
    writes) and the last bucket is exactly ``max_len``. Doubling keeps the
    set small: len(buckets) == O(log(max_len / min_bucket)).
    """
    if max_len <= 0:
        raise ValueError(f"max_len must be positive, got {max_len}")
    base = -(-max(min_bucket, block_len) // block_len) * block_len
    out: List[int] = []
    b = base
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(sorted({min(b, max_len) for b in out}))


def bucket_for(length: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= ``length`` (the padded prefill width)."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


def blocks_needed(length: int, block_len: int) -> int:
    """Pool blocks required to hold ``length`` positions."""
    return -(-length // block_len)


@dataclasses.dataclass
class PagerStats:
    num_blocks: int            # pool size, including the scratch block
    blocks_in_use: int         # resident: bound to a slot table or cache
    blocks_free: int
    peak_in_use: int           # high-water mark since construction
    allocs: int                # successful allocations
    alloc_failures: int        # backpressure events (request stayed queued)
    blocks_shared: int = 0     # resident blocks with refcount >= 2


class KVPager:
    """Host-side refcounted allocator over the global KV block pool.

    ``num_blocks`` counts the whole pool *including* the reserved scratch
    block, matching the device pool's leading axis. Capacity available to
    requests is therefore ``num_blocks - 1``.

    Reference counting: every resident block has a positive refcount —
    one per slot table binding it plus one for a prefix-cache index
    entry. ``alloc`` mints fresh blocks at refcount 1; binding an
    already-resident block into another owner goes through ``retain``;
    ``release``/``free`` decrement, and a block rejoins the free list
    only at refcount zero. The scratch block's refcount is pinned at
    construction, so it can never be freed or handed out.
    """

    def __init__(self, num_blocks: int, block_len: int, slots: int,
                 metrics=None, block_bytes: int = 0):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is scratch)")
        if block_len < 1:
            raise ValueError(f"block_len must be >= 1, got {block_len}")
        self.num_blocks = num_blocks
        self.block_len = block_len
        self.slots = slots
        # device bytes per pool block across all layers (K+V codes plus,
        # under kv_quant, the per-block scale tensors) — the engine sets
        # it once the device pools exist; 0 keeps the bytes gauge silent
        self.block_bytes = block_bytes
        # LIFO free list: recently freed blocks are reused first, which
        # keeps the working set compact and exercises stale-block masking
        self._free: List[int] = list(range(num_blocks - 1, SCRATCH_BLOCK, -1))
        self._owned: Dict[int, List[int]] = {}
        # scratch is born pinned: no release path can reach zero on it
        self._refs: Dict[int, int] = {SCRATCH_BLOCK: 1}
        self._peak = 0
        self._allocs = 0
        self._failures = 0
        self.attach_metrics(metrics)

    def attach_metrics(self, metrics) -> None:
        """Bind pool gauges/counters to a repro.obs MetricsRegistry (None
        detaches: updates become no-ops through the null registry)."""
        if metrics is None:
            from repro.obs.metrics import NULL_REGISTRY
            metrics = NULL_REGISTRY
        self._m_in_use = metrics.gauge("kv.pool.blocks_in_use",
                                       unit="blocks")
        self._m_bytes = metrics.gauge("kv.pool.bytes_in_use", unit="bytes")
        self._m_allocs = metrics.counter("kv.pool.allocs", unit="allocs")
        self._m_failures = metrics.counter("kv.pool.alloc_failures",
                                           unit="events")
        self._m_freed = metrics.counter("kv.pool.blocks_freed",
                                        unit="blocks")

    # -- queries ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Blocks allocatable to requests (pool minus the scratch block).
        A request whose worst-case footprint exceeds this can *never* be
        admitted — the engine rejects it at submit() instead of letting it
        head-of-line-block the queue forever."""
        return self.num_blocks - 1

    @property
    def blocks_in_use(self) -> int:
        """Resident blocks: bound to at least one slot table or held by
        the prefix-cache index (scratch excluded)."""
        return self.num_blocks - 1 - len(self._free)

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_shared(self) -> int:
        """Resident blocks referenced more than once (scratch excluded)."""
        return sum(1 for b, c in self._refs.items()
                   if c >= 2 and b != SCRATCH_BLOCK)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned.get(slot, ()))

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    def stats(self) -> PagerStats:
        return PagerStats(num_blocks=self.num_blocks,
                          blocks_in_use=self.blocks_in_use,
                          blocks_free=self.blocks_free,
                          peak_in_use=self._peak,
                          allocs=self._allocs,
                          alloc_failures=self._failures,
                          blocks_shared=self.blocks_shared)

    # -- refcounts ----------------------------------------------------------
    def retain(self, blocks) -> None:
        """Add one reference to each resident block in ``blocks``.

        Used when a block already bound somewhere (a sibling slot's table
        or the prefix-cache index) gains another owner. Retaining a free
        or scratch block is a bug, not a recovery path.
        """
        for b in blocks:
            if b == SCRATCH_BLOCK:
                raise RuntimeError("cannot retain the scratch block")
            c = self._refs.get(b, 0)
            if c < 1:
                raise RuntimeError(f"retain of non-resident block {b}")
            self._refs[b] = c + 1

    def release(self, blocks) -> int:
        """Drop one reference from each block; free those that hit zero.

        Returns how many blocks actually rejoined the free list.
        """
        freed = 0
        for b in blocks:
            if b == SCRATCH_BLOCK:
                raise RuntimeError("cannot release the scratch block")
            c = self._refs.get(b, 0)
            if c < 1:
                raise RuntimeError(f"release of non-resident block {b}")
            if c == 1:
                del self._refs[b]
                self._free.append(b)
                freed += 1
            else:
                self._refs[b] = c - 1
        if freed:
            self._m_freed.inc(freed)
        self._m_in_use.set(self.blocks_in_use)
        self._m_bytes.set(self.blocks_in_use * self.block_bytes)
        return freed

    # -- alloc / free -------------------------------------------------------
    def alloc(self, slot: int, n: int, shared=()) -> Optional[List[int]]:
        """Allocate ``n`` *fresh* blocks for ``slot``; all-or-nothing.

        ``shared`` is the slot's prefix of already-resident blocks, each
        carrying one reference the caller pinned on its behalf (e.g. via
        ``PrefixCache.match``): ownership of those pins transfers to the
        slot — no refcount change here — and ``free(slot)`` will drop
        them. Only the ``n`` fresh blocks (the request's *unshared
        footprint*) hit the free list; that is all admission has to
        budget for.

        Returns the fresh block ids (order == logical block-table order
        after the shared prefix) or None when the pool cannot satisfy the
        request — the caller leaves the request queued (backpressure)
        and must unwind the ``shared`` pins itself.
        """
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already holds blocks "
                               f"{self._owned[slot]} (free it first)")
        if n < 1 and not shared:
            raise ValueError(f"allocation must be >= 1 block, got {n}")
        if n > len(self._free):
            self._failures += 1
            self._m_failures.inc()        # backpressure stall: head waits
            return None
        for b in shared:
            if self._refs.get(b, 0) < 1:
                raise RuntimeError(f"shared block {b} is not resident")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refs[b] = 1
        self._owned[slot] = list(shared) + blocks
        self._allocs += 1
        self._peak = max(self._peak, self.blocks_in_use)
        self._m_allocs.inc()
        self._m_in_use.set(self.blocks_in_use)
        self._m_bytes.set(self.blocks_in_use * self.block_bytes)
        return list(blocks)

    def free(self, slot: int) -> int:
        """Drop the slot's reference on every block it holds; returns how
        many reached refcount zero and rejoined the free list. Blocks
        still pinned elsewhere (sibling slots, the prefix cache) stay
        resident."""
        blocks = self._owned.pop(slot, [])
        if not blocks:
            return 0
        return self.release(blocks)
