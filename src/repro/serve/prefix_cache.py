"""Radix-tree prefix cache over the paged KV pool.

Production traffic is dominated by shared prefixes — system prompts,
few-shot templates, multi-turn history — so most prefill FLOPs and KV
pool blocks are redundant recomputations of byte-identical K/V. The
block-table indirection (serve/kv_pager.py) already lets two slots point
at the same pool block; this module supplies the *index* that finds the
reusable blocks and the refcount discipline that keeps them alive:

``PrefixCache``
    A radix tree keyed on token ids at **block granularity**: every edge
    carries one or more whole blocks, each a ``(block_len,)`` token tuple
    paired with the pool block id holding that span's K/V. Only blocks
    completely filled by *prompt* tokens are indexed — a partially-filled
    tail block also receives decode writes, so it is never shareable
    (sharing stops at the last full prompt block; the divergent /
    partially-filled block is where copy-on-write happens: the new
    request recomputes it into a fresh block instead of writing into the
    shared one).

Lifecycle contract with ``KVPager``:

* ``insert`` retains each block it newly indexes — the cache holds its
  own reference, so an indexed block survives the owning slot's
  ``free``.
* ``match`` retains each matched block *before* returning it, so the hit
  cannot be evicted (or freed by the lender finishing) between match and
  admission. The engine hands the matched prefix to
  ``KVPager.alloc(slot, n, shared=...)`` — the match pin transfers to
  the slot — and releases any matched blocks it decides not to bind.
* ``match`` never returns the whole prompt: hits are capped at
  ``(plen - 1) // block_len`` blocks so at least one prompt token is
  always prefilled and the logits that emit the first token exist.
* Eviction (``evict_until``) walks refcount-one radix leaves — blocks
  only the cache still references — in LRU (default) or FIFO order,
  releasing from each edge's tail inward. Blocks still bound by a live
  slot (refcount >= 2) are never touched.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.serve import kv_pager as kvp

EVICTION_POLICIES = ("lru", "fifo")


class _Node:
    """One radix edge: parallel lists of per-block token keys and the
    pool block ids holding their K/V."""
    __slots__ = ("keys", "blocks", "children", "parent", "last_used",
                 "created")

    def __init__(self, keys, blocks, parent, clock):
        self.keys: List[Tuple[int, ...]] = list(keys)
        self.blocks: List[int] = list(blocks)
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent: Optional["_Node"] = parent
        self.last_used = clock
        self.created = clock


class PrefixCache:
    """Block-granular radix index of prompt-token prefixes -> pool blocks.

    ``pager`` is the refcounted allocator the indexed blocks live in;
    ``block_len`` must match the pager's. ``policy`` picks the eviction
    order over refcount-one leaves: ``"lru"`` (least-recently matched
    first, the default) or ``"fifo"`` (oldest-inserted first).
    """

    def __init__(self, pager: kvp.KVPager, block_len: int,
                 policy: str = "lru"):
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"choose from {EVICTION_POLICIES}")
        if block_len != pager.block_len:
            raise ValueError(f"block_len {block_len} != pager block_len "
                             f"{pager.block_len}")
        self.pager = pager
        self.block_len = block_len
        self.policy = policy
        self._root = _Node((), (), None, 0)
        self._clock = 0
        self.hits = 0            # match() calls returning >= 1 block
        self.hit_blocks = 0      # total blocks returned by match()
        self.evicted_blocks = 0  # blocks released by evict_until()

    # -- helpers ------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _block_keys(self, tokens, nblocks: int) -> List[Tuple[int, ...]]:
        B = self.block_len
        return [tuple(int(t) for t in tokens[i * B:(i + 1) * B])
                for i in range(nblocks)]

    @property
    def num_blocks(self) -> int:
        """Blocks currently indexed (each holds one cache reference)."""
        total = 0
        stack = [self._root]
        while stack:
            n = stack.pop()
            total += len(n.blocks)
            stack.extend(n.children.values())
        return total

    # -- match --------------------------------------------------------------
    def match(self, tokens) -> List[int]:
        """Longest indexed block-prefix of ``tokens``, pinned.

        Returns the pool block ids (table order), each retained once on
        the caller's behalf; capped at ``(len(tokens) - 1) // block_len``
        blocks so at least one token is left to prefill. The caller must
        either transfer every pin into a slot (``alloc(..., shared=)``)
        or release it.
        """
        cap = max(0, (len(tokens) - 1) // self.block_len)
        keys = self._block_keys(tokens, cap)
        out: List[int] = []
        node = self._root
        now = self._tick()
        i = 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                break
            child.last_used = now
            k = 0
            while (k < len(child.keys) and i + k < len(keys)
                   and child.keys[k] == keys[i + k]):
                out.append(child.blocks[k])
                k += 1
            i += k
            if k < len(child.keys):
                break                     # stopped mid-edge
            node = child
        if out:
            self.pager.retain(out)
            self.hits += 1
            self.hit_blocks += len(out)
        return out

    # -- insert -------------------------------------------------------------
    def insert(self, tokens, blocks) -> int:
        """Index the full prompt blocks of ``tokens`` backed by ``blocks``.

        ``blocks`` is the owning slot's block-table prefix; only the
        first ``len(tokens) // block_len`` entries (blocks completely
        filled by prompt tokens) are considered. Where the tree already
        indexes a key, the existing pool block wins — the duplicate stays
        owned solely by its slot. Newly indexed blocks are retained once
        (the cache's own reference). Returns how many blocks were newly
        indexed.
        """
        nfull = len(tokens) // self.block_len
        nfull = min(nfull, len(blocks))
        if nfull == 0:
            return 0
        keys = self._block_keys(tokens, nfull)
        node = self._root
        now = self._tick()
        i = 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                fresh = list(blocks[i:nfull])
                new = _Node(keys[i:], fresh, node, now)
                node.children[keys[i]] = new
                self.pager.retain(fresh)
                return len(fresh)
            child.last_used = now
            k = 0
            while (k < len(child.keys) and i + k < len(keys)
                   and child.keys[k] == keys[i + k]):
                k += 1
            i += k
            if k < len(child.keys):
                if i >= len(keys):
                    return 0              # new prefix ends inside the edge
                self._split(child, k)     # diverged mid-edge
                node = child
            else:
                node = child
        return 0

    def _split(self, node: _Node, k: int) -> None:
        """Split ``node``'s edge after its first ``k`` blocks: ``node``
        keeps the shared prefix, the tail moves to a new child."""
        tail = _Node(node.keys[k:], node.blocks[k:], node, node.created)
        tail.last_used = node.last_used
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        node.keys = node.keys[:k]
        node.blocks = node.blocks[:k]
        node.children = {tail.keys[0]: tail}

    # -- eviction -----------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out = []
        stack = [self._root]
        while stack:
            n = stack.pop()
            if n is not self._root and not n.children:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def _evictable_tail(self, leaf: _Node) -> int:
        """How many blocks at the edge's tail only the cache references."""
        n = 0
        for b in reversed(leaf.blocks):
            if self.pager.refcount(b) != 1:
                break
            n += 1
        return n

    def evict_until(self, n: int) -> bool:
        """Evict refcount-one leaves until the pool can allocate ``n``
        fresh blocks. Returns True on success, False when nothing more is
        evictable (the request falls back to ordinary backpressure)."""
        while not self.pager.can_alloc(n):
            order = (lambda lf: lf.created) if self.policy == "fifo" \
                else (lambda lf: lf.last_used)
            victim = None
            for leaf in sorted(self._leaves(), key=order):
                if self._evictable_tail(leaf) > 0:
                    victim = leaf
                    break
            if victim is None:
                return False
            drop = self._evictable_tail(victim)
            dead = victim.blocks[len(victim.blocks) - drop:]
            del victim.keys[len(victim.keys) - drop:]
            del victim.blocks[len(victim.blocks) - drop:]
            self.pager.release(dead)
            self.evicted_blocks += len(dead)
            if not victim.keys:
                parent = victim.parent
                for key, c in list(parent.children.items()):
                    if c is victim:
                        del parent.children[key]
                        break
        return True

    def clear(self) -> int:
        """Drop the whole index, releasing every cache reference.
        Returns how many blocks were released."""
        released = 0
        stack = [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.blocks:
                self.pager.release(node.blocks)
                released += len(node.blocks)
        self._root = _Node((), (), None, self._clock)
        return released
