"""Iteration-level prefill scheduler (Orca / Sarathi / vLLM shape).

``ServeEngine.step()`` is one *iteration*: a prefill phase followed by a
single batched decode dispatch. This module owns the prefill-phase policy —
*which prompt tokens get prefilled this iteration* — while the engine keeps
ownership of slots, block allocation, dispatch grouping, and decode.

Policy, per iteration (``plan()``):

1. **Continuations first.** Every slot holding a mid-prefill (chunked)
   request gets its next ``prefill_chunk``-wide chunk, in slot order. A
   request never stalls mid-prompt behind new admissions.
2. **FIFO admissions.** Queue-head requests are admitted while the engine
   can seat them (``admit_fn`` returns a slot, or None on slot/pool
   backpressure — the head then waits, preserving FIFO order). A prompt
   whose padded bucket fits within one chunk is scheduled as a single
   *single-shot* row at its bucket width — exactly the legacy prefill
   path; a longer prompt is split into block-aligned chunks of width
   ``prefill_chunk``, one per iteration, interleaved with decode steps so
   short requests' time-to-first-token stays flat while a long prompt
   streams in.
3. **Token budget.** ``max_prefill_tokens`` caps the total scheduled row
   width per iteration. At least one row always goes through when prefill
   work exists, so progress is guaranteed.

A prefix-cache hit (serve/prefix_cache.py) is *prefill chunks skipped*:
admission binds the matched pool blocks into the slot's table and returns
``(slot, start)``, and the first row covers positions ``start..`` instead
of 0 — ``resume_start`` picks the largest block-aligned start whose row
geometry stays inside the slot's table, so a resumed prompt behaves
exactly like a mid-chunk continuation of today's chunked prefill.

Chunk geometry: a prompt of length P with chunk width C covers positions
``[0, ceil(P/C)*C)`` in exactly ``ceil(P/C)`` chunks — every chunk is full
width (compile shapes stay bounded), the last chunk's pad tail is causally
masked and its KV writes are trimmed to scratch by the engine. Mid-prompt
chunk boundaries are block-aligned (C is a multiple of ``block_len``) so
paged pool writes stay whole-block.

The scheduler is deterministic given the submission order: emitted tokens
are bit-identical to the unchunked engine (see tests/test_scheduler.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.serve import kv_pager as kvp


@dataclasses.dataclass
class PrefillRow:
    """One row of prefill work scheduled for the current iteration."""
    req: object                # the engine Request
    slot: int                  # seated slot
    start: int                 # first prompt position this row covers
    width: int                 # row width (tokens dispatched, incl. pad)
    final: bool                # True when this row completes the prompt
    fresh: bool                # True on the request's first row (admission)


class IterationScheduler:
    """Per-iteration admit/chunk planner for ServeEngine.

    Parameters
    ----------
    buckets : prefill bucket widths (bucketed archs) or None (recurrent
        archs prefill at exact length and never chunk).
    block_len : KV block granularity; chunk widths must be multiples.
    max_len : engine sequence capacity; with chunking enabled it must be a
        multiple of ``prefill_chunk`` so chunk coverage never overruns a
        slot's block table.
    prefill_chunk : chunk width in tokens, or None to disable chunking
        (every prompt prefills single-shot at its bucket width — the
        legacy behavior, bit-for-bit).
    max_prefill_tokens : per-iteration token budget across all scheduled
        rows, or None for unlimited.
    """

    def __init__(self, *, buckets: Optional[Tuple[int, ...]], block_len: int,
                 max_len: int, prefill_chunk: Optional[int] = None,
                 max_prefill_tokens: Optional[int] = None):
        if prefill_chunk is not None:
            if buckets is None:
                raise ValueError(
                    "prefill_chunk requires a bucketed (attention-family) "
                    "arch; recurrent archs prefill at exact length")
            if prefill_chunk < 1 or prefill_chunk % block_len != 0:
                raise ValueError(
                    f"prefill_chunk {prefill_chunk} must be a positive "
                    f"multiple of block_len {block_len}")
            if max_len % prefill_chunk != 0:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of "
                    f"prefill_chunk {prefill_chunk} (chunk coverage must "
                    "not overrun the slot's block table)")
        if max_prefill_tokens is not None and max_prefill_tokens < 1:
            raise ValueError("max_prefill_tokens must be >= 1 or None")
        self.buckets = buckets
        self.block_len = block_len
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.max_prefill_tokens = max_prefill_tokens
        self.queue: Deque = deque()
        # slot -> (req, next chunk start); presence marks a mid-prefill slot
        self._chunking: Dict[int, Tuple[object, int]] = {}

    # -- queries ------------------------------------------------------------
    @property
    def chunking(self) -> Dict[int, Tuple[object, int]]:
        """Slots holding a mid-prefill request (not yet decodable)."""
        return self._chunking

    def enqueue(self, req) -> None:
        self.queue.append(req)

    def drop_slot(self, slot: int) -> None:
        """Forget any mid-prefill state for ``slot`` (engine slot release)."""
        self._chunking.pop(slot, None)

    def single_shot(self, plen: int, start: int = 0) -> bool:
        """True when the remaining prompt (positions ``start..plen``)
        prefills in one row. ``start`` > 0 is a prefix-cache resume: the
        first ``start`` positions are already in shared pool blocks."""
        remaining = plen - start
        if self.prefill_chunk is None:
            return True
        if remaining <= self.prefill_chunk:
            return True
        return kvp.bucket_for(remaining, self.buckets) <= self.prefill_chunk

    def admission_width(self, plen: int, start: int = 0) -> int:
        """Width of the first prefill row for a prompt of length ``plen``
        resuming at position ``start`` (0 = no prefix hit)."""
        remaining = plen - start
        if not self.single_shot(plen, start):
            return self.prefill_chunk
        if self.buckets is None:
            return remaining
        w = kvp.bucket_for(remaining, self.buckets)
        # remaining <= chunk but no bucket in [remaining, chunk]: one
        # chunk-wide row covers the whole tail (still block-aligned)
        if self.prefill_chunk is not None and w > self.prefill_chunk:
            w = self.prefill_chunk
        return w

    def resume_start(self, plen: int, cached_len: int) -> int:
        """Largest safe prefill resume position <= ``cached_len``.

        ``cached_len`` is the prefix-cache hit in tokens (a multiple of
        ``block_len``). The returned start keeps every subsequent row
        inside the slot's table: with chunking it aligns down to the
        chunk grid (continuation chunks then land exactly like mid-chunk
        prefill today); single-shot it backs off block-by-block until
        ``start + bucket_for(remaining) <= max_len``, so the padded row
        can never overrun ``max_len`` and trip scatter-index clamping.
        """
        if self.buckets is None or cached_len <= 0:
            return 0                      # recurrent archs never resume
        start = (cached_len // self.block_len) * self.block_len
        if self.prefill_chunk is not None:
            # chunk-grid alignment: every row (first included, since
            # admission_width caps at prefill_chunk) ends <= max_len
            # because max_len % prefill_chunk == 0
            return (start // self.prefill_chunk) * self.prefill_chunk
        while start > 0 and start + self.admission_width(plen, start) \
                > self.max_len:
            start -= self.block_len
        return max(0, start)

    # -- the per-iteration decision -----------------------------------------
    def plan(self, admit_fn: Callable[[object], Optional[int]]
             ) -> List[PrefillRow]:
        """Schedule this iteration's prefill rows.

        ``admit_fn(req)`` is the engine's seating callback: it picks a free
        slot, allocates pool blocks (paged), marks the slot active, and
        returns the slot id — or ``(slot, start)`` when a prefix-cache hit
        binds shared blocks and prefill resumes at block-aligned position
        ``start`` (see ``resume_start``) — or None when the request cannot
        be seated right now (backpressure; the head stays queued, FIFO
        preserved).
        """
        rows: List[PrefillRow] = []
        used = 0
        budget = (self.max_prefill_tokens
                  if self.max_prefill_tokens is not None else float("inf"))

        # 1. continuations: one chunk per mid-prefill slot, slot order
        for slot in sorted(self._chunking):
            if rows and used + self.prefill_chunk > budget:
                break
            req, start = self._chunking[slot]
            final = start + self.prefill_chunk >= len(req.prompt)
            rows.append(PrefillRow(req=req, slot=slot, start=start,
                                   width=self.prefill_chunk, final=final,
                                   fresh=False))
            used += self.prefill_chunk
            if final:
                del self._chunking[slot]
            else:
                self._chunking[slot] = (req, start + self.prefill_chunk)

        # 2. FIFO admissions from the queue head
        while self.queue:
            req = self.queue[0]
            plen = len(req.prompt)
            # worst-case (no-hit) width for the budget check; the actual
            # admitted width only shrinks on a prefix hit
            if rows and used + self.admission_width(plen) > budget:
                break
            seat = admit_fn(req)
            if seat is None:            # no free slot / pool backpressure
                break
            slot, start = seat if isinstance(seat, tuple) else (seat, 0)
            width = self.admission_width(plen, start)
            final = self.single_shot(plen, start)
            self.queue.popleft()
            rows.append(PrefillRow(req=req, slot=slot, start=start,
                                   width=width, final=final, fresh=True))
            used += width
            if not final:
                self._chunking[slot] = (req, start + width)
        return rows
