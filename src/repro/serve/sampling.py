"""Decode-path token sampling on the CORDIC datapath.

Temperature scaling rides the same shift-add engine as the rest of the
serving stack instead of a float ``logits / T``:

    1/T      — the R2-LVC linear-vectoring divide (functions.reciprocal_*)
    logits/T — the linear-*rotation* multiply (functions.multiply_*): the
               reciprocal mantissa is the rotation angle, the logit mantissa
               sits in the constant x register, y accumulates the product

so the only non-shift-add ops are the frexp/exp2 boundary, exactly like the
softmax/log-softmax legs. ``impl="exact"`` keeps the float division as an
oracle.

``SamplingParams`` is carried per request (serve.engine.Request), so one
batched decode step can mix greedy slots with sampled slots at different
temperatures/top-k: every per-slot knob is a traced array, and the batched
sampler is a single vmap — no recompilation when the mix changes.

Greedy is argmax over the raw logits (temperature and top-k are monotone,
so scaling is skipped for determinism and bit-identity with the historic
greedy decode path). ``temperature <= 0`` resolves to greedy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.cordic_engine import functions as F

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature`` — softmax temperature; <= 0 means greedy.
    ``top_k``       — keep the k highest logits (0 = full vocab).
    ``greedy``      — force argmax regardless of temperature.
    """

    temperature: float = 1.0
    top_k: int = 0
    greedy: bool = False

    def resolved(self) -> Tuple[float, int, bool]:
        """(temperature, top_k, greedy) with temperature<=0 folded into
        greedy and the temperature kept strictly positive for 1/T."""
        greedy = bool(self.greedy) or float(self.temperature) <= 0.0
        temp = 1.0 if greedy else float(self.temperature)
        return temp, int(self.top_k), greedy


GREEDY = SamplingParams(greedy=True)


def scale_by_temperature(logits, temperature, impl: str = "cordic"):
    """logits / T through the CORDIC engine: 1/T from the R2-LVC divide,
    then the linear-rotation multiply. ``impl="exact"`` is the float oracle."""
    if impl == "exact":
        return logits / temperature
    inv_t = F.reciprocal_fixed(temperature)
    return F.multiply_fixed(logits, inv_t)


def top_k_mask(logits, k):
    """Mask all but the k largest entries of the last axis to NEG_INF.

    ``k`` may be a traced scalar (per-slot dynamic): the threshold is the
    k-th largest value via a sorted gather, so no dynamic shapes appear.
    k <= 0 keeps the full vocabulary. Ties at the threshold all survive.
    """
    v = logits.shape[-1]
    kk = jnp.clip(jnp.where(k > 0, k, v), 1, v)
    thr = jnp.take(jnp.sort(logits, axis=-1), v - kk, axis=-1)
    return jnp.where(logits >= thr[..., None], logits, NEG_INF)


def sample_one(logits, key, temperature, top_k, greedy, impl: str = "cordic"):
    """One row: (V,) logits -> int32 token id.

    Greedy rows take argmax of the *raw* logits; sampled rows draw from
    categorical(top_k(logits / T)) with the caller's key.
    """
    scaled = scale_by_temperature(logits, temperature, impl)
    drawn = jax.random.categorical(key, top_k_mask(scaled, top_k))
    return jnp.where(greedy, jnp.argmax(logits, axis=-1), drawn).astype(jnp.int32)


def sample_batched(logits, keys, temperatures, top_ks, greedy, impl: str = "cordic"):
    """Batched sampler: (B,V) logits + per-row keys/params -> (B,) int32.

    Each row uses its own rng key, so a row's draw depends only on
    (logits_row, key_row, params_row) — never on batch composition. That is
    what makes the engine's batched decode bit-reproducible against a
    sequential per-request decode of the same streams.
    """
    return jax.vmap(functools.partial(sample_one, impl=impl))(
        logits, keys, temperatures, top_ks, greedy)


def request_key(base_key, rid, step):
    """The key for token ``step`` of request ``rid``: a per-request stream
    fold_in(fold_in(base, rid), step), independent of slot placement and
    batch composition (step 0 is the prefill-emitted token)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, rid), step)
