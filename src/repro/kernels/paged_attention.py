"""Block-walking paged-attention decode kernels (vLLM-style) in Pallas.

The paged KV plane (PR 4) stores decode K/V in a global pool of
``block_len``-position blocks with per-slot block tables.  The *gather*
attend path (`models.attention._pool_gather` + `_attend_rows`) assembles a
dense ``(slots, max_len, ...)`` buffer from the table before attending —
shape-identical to the dense path (which is what makes bit-identity
provable), but the per-step transient working set still scales with
``max_len`` even when every live sequence is short.

These kernels remove that transient: the grid runs over
``slots x table-chunks`` and each grid step DMAs exactly ONE pool block
into VMEM — the block id comes from the scalar-prefetched block table via
the BlockSpec index map (``tables[slot, chunk]``), so the pipeline walks
each row's *own* blocks in place and a ``max_len``-sized buffer is never
materialized.  Softmax is accumulated online in f32 scratch that persists
across the chunk axis (running max / running sum / weighted-value
accumulator, flash-decoding style), and the per-row length mask is applied
inside the loop; chunks at-or-past a row's live length skip their compute
(their table entries point at the reserved scratch block 0).

Selection (``cfg.paged_attend_impl``):

    "gather" — the PR-4 reference path: full-table gather, attend over
               dense shapes.  Transient = O(max_len) per row.  Exactly
               reproduces the dense path bit-for-bit.
    "pallas" — these kernels.  Transient = O(block_len) per grid step,
               independent of max_len.  The online-softmax accumulation
               reorders float reductions, so attention *outputs* agree
               with the gather path to f32 round-off (~1e-6 relative;
               ~1e-3 for the Q2.14 CORDIC softmax) — and the emitted
               *tokens* are bit-identical, which is the serving contract
               and what the per-backend CI conformance suite enforces
               (tests/test_paged_attention.py).

The softmax follows ``softmax_impl``:

    "exact"          — one sweep over the live blocks with the classic
                       online-softmax rescaling recurrence (jnp.exp).
    "cordic_fixed" / — three sweeps over the same blocks (a pass axis in
    "cordic_pallas"    the grid; blocks stay O(block_len) in VMEM): exact
                       row max, then the CORDIC-exp row sum, then a
                       per-lane normalization that replicates the selected
                       backend's softmax *bit-for-bit* given (max, sum) —
                       ``cordic_fixed`` via functions.exp_fixed +
                       divide_fixed (the jnp engine datapath),
                       ``cordic_pallas`` via the dyadic reduction +
                       ``_coshsinh_q`` rotation + frexp + ``_lvc_div_q``
                       stages of kernels/softmax_cordic.py.  Online
                       rescaling with quantized CORDIC exponentials would
                       drift ~1e-3 from the gather path — enough to flip a
                       sampled token — so the CORDIC impls trade one extra
                       block sweep for lane-exact probabilities.

Two kernels, one accumulation scheme:

    gqa_decode — grouped-query decode: q (B, KH, G, hd) against K/V pools
                 (N, L, KH, hd); returns (B, KH, G, hd) f32.
    mla_decode — absorbed-form MLA decode: q_eff (B, H, R) + q_rope
                 (B, H, P) against the compressed-latent pool (N, L, R)
                 and shared rope-key pool (N, L, P); returns the latent
                 output (B, H, R) f32 (the wv_b projection stays outside,
                 mirroring models.attention._mla_absorbed_decode).

CI exercises interpret mode (CPU); on TPU the same pallas_call compiles
via Mosaic with the block table scalar-prefetched into SMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE
from repro.kernels.cordic_act import (
    _I32,
    _coshsinh_q,
    _dequantize_f,
    _exp2_i32,
    _guard_drop,
    _lvc_div_q,
    _quantize_f,
    _shr,
    _wrap16,
)

NEG_INF = np.float32(-1e30)
_LN2 = np.float32(math.log(2.0))
_INV_LN2 = np.float32(1.0 / math.log(2.0))
#: same flush thresholds as kernels/softmax_cordic.py: lanes more than
#: ~e^-20 below the running max contribute exactly 0.
_DEAD_CUTOFF = np.float32(-20.0)
_MIN_K = np.float32(-30.0)


def _num_passes(impl: str) -> int:
    """Block sweeps per row: 1 (online rescaling) for the exact softmax,
    3 (max / sum / normalize) for the CORDIC impls — see module docstring."""
    if impl in (None, "exact"):
        return 1
    if impl in ("cordic_fixed", "cordic_pallas"):
        return 3
    raise ValueError(f"unknown softmax_impl {impl!r}")


def canonical_kv_dtype(kv_dtype):
    """Validate + canonicalize the ``kv_dtype`` cast seam (None passes
    through; the caller substitutes its pool-derived default).

    kv_dtype is the storage-rounding cast the gather path applies to K/V
    before scoring (x.dtype in models.attention); the kernels replay it
    per block so both paths attend identically-rounded values. It must be
    a *float* dtype — an unrecognized string or an integer dtype used to
    fall through silently and attend garbage-rounded scores; now it fails
    at call/init time, mirroring the _paged_attend_impl validation.
    Integer pool *storage* is selected with ``kv_quant``, not kv_dtype.
    """
    if kv_dtype is None:
        return None
    try:
        dt = jnp.dtype(kv_dtype)
    except TypeError:
        raise ValueError(
            f"unknown kv_dtype {kv_dtype!r}; expected a float dtype such "
            "as jnp.float32 / jnp.bfloat16") from None
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(
            f"kv_dtype {dt} is not a float dtype — kv_dtype is the "
            "storage-rounding cast applied to K/V before scoring; select "
            "integer pool storage with kv_quant instead")
    return dt


def _exp_codes(u, sched: MRSchedule, cfg: FixedConfig):
    """The exp stage of softmax_cordic's _softmax_kernel: dyadic reduction
    u = k ln2 + r and the Q-format cosh+sinh rotation. Returns the e^r
    codes, the dyadic exponents, and the dead-lane mask (u < -20) — the
    shared intermediates of the sum (pass 1) and normalize (pass 2) stages,
    so the two can never desynchronize."""
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits
    dead = u < _DEAD_CUTOFF
    k = jnp.maximum(jnp.floor(u * _INV_LN2 + 0.5), _MIN_K)
    r = jnp.where(dead, 0.0, u - k * _LN2)              # |r| <= ln2/2
    c, s = _coshsinh_q(_quantize_f(r, fb, bits), sched, cfg)
    eq = _wrap16(c + s, bits)                           # e^r codes
    return eq, k.astype(_I32), dead


def _lane_exp(u, impl: str, sched: MRSchedule, cfg: FixedConfig):
    """The selected backend's e^u per lane (u = score - row max <= 0).

    cordic_fixed replicates functions.exp_fixed (softmax_fixed's exp);
    cordic_pallas replicates the exp stage of softmax_cordic's
    _softmax_kernel: _exp_codes + exponent-field 2^k scale, lanes below
    e^-20 flushed to 0.
    """
    if impl == "cordic_fixed":
        from repro.cordic_engine import functions as F

        return F.exp_fixed(u, cfg=cfg)
    eq, ki, dead = _exp_codes(u, sched, cfg)
    ef = _dequantize_f(eq, cfg.fmt.frac_bits) * _exp2_i32(ki)
    return jnp.where(dead, 0.0, ef)


def _lane_probs(u, ssum, impl: str, sched: MRSchedule, cfg: FixedConfig):
    """Normalized probability per lane given the final (row max, row sum),
    replicating the selected backend's softmax bit-for-bit per lane.

    cordic_fixed: functions.divide_fixed(exp_fixed(u), S) — exactly what
    softmax_fixed does.  cordic_pallas: the normalization stage of
    _softmax_kernel — exponent-field frexp of S, Q-format mantissa, R2-LVC
    division, 2^(k - p + 1) scale, dead lanes exactly 0.
    """
    if impl == "cordic_fixed":
        from repro.cordic_engine import functions as F

        return F.divide_fixed(F.exp_fixed(u, cfg=cfg), ssum, cfg=cfg)
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits
    eq, ki, dead = _exp_codes(u, sched, cfg)
    p = (jax.lax.bitcast_convert_type(ssum, jnp.int32) >> 23) - 127
    ms = ssum * _exp2_i32(-p)
    mq = jnp.broadcast_to(_quantize_f(ms, fb, bits), eq.shape)
    t = _lvc_div_q(mq, _shr(eq, 1, bits), sched, cfg)
    tf = _dequantize_f(_guard_drop(t, cfg), fb)
    out = tf * _exp2_i32(ki - p + 1)
    return jnp.where(dead, 0.0, out)


def _pass_update(s, v, pass_idx, impl, sched, cfg, m_sc, l_sc, acc_sc,
                 contract):
    """One grid step of the softmax accumulation shared by both kernels.

    s: (..., L) masked scores for this block (masked lanes == NEG_INF);
    v: the block's values; contract(p, v) -> weighted-value partial sum.
    m_sc/l_sc keep a trailing singleton axis (s.shape[:-1] + (1,)) so
    they broadcast over both the score row and the accumulator's feature
    axis.  For the exact impl (single pass) this is the flash-decoding
    recurrence: rescale the running sum/accumulator by e^(m_old - m_new)
    whenever the running max moves.  For the CORDIC impls, pass 0 takes
    the exact row max, pass 1 the backend's e^u row sum, and pass 2
    accumulates lane-exact probabilities against the values.
    """
    if impl in (None, "exact"):
        m_old = m_sc[...]                                   # (..., 1)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        ef = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(ef, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + contract(ef, v)
        m_sc[...] = m_new
        return

    @pl.when(pass_idx == 0)
    def _():
        m_sc[...] = jnp.maximum(m_sc[...],
                                jnp.max(s, axis=-1, keepdims=True))

    @pl.when(pass_idx == 1)
    def _():
        ef = _lane_exp(s - m_sc[...], impl, sched, cfg)
        l_sc[...] = l_sc[...] + jnp.sum(ef, axis=-1, keepdims=True)

    @pl.when(pass_idx == 2)
    def _():
        pr = _lane_probs(s - m_sc[...], l_sc[...], impl, sched, cfg)
        acc_sc[...] = acc_sc[...] + contract(pr, v)


# ---------------------------------------------------------------------------
# GQA decode
# ---------------------------------------------------------------------------
def _gqa_kernel(tbl_ref, kl_ref, q_ref, k_ref, v_ref, *rest,
                block_len: int, scale: float, impl: str, sched: MRSchedule,
                cfg: FixedConfig, kv_dtype, kv_quant_spec=None):
    # quantized pools add two scale refs between the pools and the output
    if kv_quant_spec is not None:
        ks_ref, vs_ref, o_ref, m_sc, l_sc, acc_sc = rest
    else:
        o_ref, m_sc, l_sc, acc_sc = rest
    b, p, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((p == 0) & (c == 0))
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    klen = kl_ref[b]
    base = c * block_len

    @pl.when(base < klen)                       # dead chunks: skip compute
    def _():
        q = q_ref[0].astype(jnp.float32)                        # (KH,G,hd)
        if kv_quant_spec is None:
            k = k_ref[0].astype(kv_dtype).astype(jnp.float32)   # (L,KH,hd)
            v = v_ref[0].astype(kv_dtype).astype(jnp.float32)
        else:
            # the kv_dtype cast seam as a real dequant stage: this block's
            # integer codes x its per-head scale, on the CORDIC linear-
            # rotation multiply — elementwise on exactly the (code, scale)
            # pairs the gather oracle dequantizes, so rounding matches
            from repro.core import kv_quant as kvq

            k = kvq.dequantize(k_ref[0], kv_quant_spec,
                               ks_ref[0]).astype(kv_dtype).astype(jnp.float32)
            v = kvq.dequantize(v_ref[0], kv_quant_spec,
                               vs_ref[0]).astype(kv_dtype).astype(jnp.float32)
        s = jnp.einsum("hgd,lhd->hgl", q, k,
                       preferred_element_type=jnp.float32) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(pos < klen, s, NEG_INF)
        _pass_update(
            s, v, p, impl, sched, cfg, m_sc, l_sc, acc_sc,
            lambda pr, vb: jnp.einsum("hgl,lhd->hgd", pr, vb,
                                      preferred_element_type=jnp.float32))

    if impl in (None, "exact"):
        o_ref[...] = (acc_sc[...] / l_sc[...])[None]
    else:
        o_ref[...] = acc_sc[...][None]      # pass 2 accumulates normalized p


def gqa_decode(q, k_pool, v_pool, tables, k_len, *, scale: float,
               softmax_impl: str = "exact", kv_dtype=None,
               kv_quant: str = "none",
               k_scale_pool=None, v_scale_pool=None,
               sched: MRSchedule = PAPER_SCHEDULE,
               cfg: FixedConfig = PAPER_FIXED,
               interpret: bool = False) -> jax.Array:
    """Paged GQA decode attend: one query row per slot against its own
    live KV blocks, walked through the block table.

    q:       (B, KH, G, hd) post-RoPE grouped queries (any float dtype)
    k_pool:  (N, L, KH, hd) global key pool   (block 0 = scratch)
    v_pool:  (N, L, KH, hd) global value pool
    tables:  (B, M) int32 per-row block tables (entries past the live
             count point at scratch block 0)
    k_len:   (B,) int32 valid key count per row; must be >= 1 (the decode
             step writes its new element before attending)
    kv_dtype: storage dtype the gather path would cast K/V to (x.dtype in
             models.attention) — applied per block so both paths attend
             identically-rounded K/V.  Validated float (canonical_kv_dtype).
    kv_quant: "none" | "int8" | "q2_14" (core/kv_quant.py).  When set, the
             pools hold integer codes, ``k_scale_pool``/``v_scale_pool``
             carry the (N, 1, KH, 1) f32 per-block-per-head scales, and
             each grid step dequantizes its block in VMEM via the CORDIC
             linear-rotation multiply before scoring.

    Returns (B, KH, G, hd) f32 attention outputs.
    """
    from repro.core import kv_quant as kvq

    B, KH, G, hd = q.shape
    N, L = k_pool.shape[:2]
    M = tables.shape[1]
    spec = kvq.spec_for(kv_quant)
    if (spec is not None) != (k_scale_pool is not None
                              and v_scale_pool is not None):
        # checked before kv_dtype resolution: an integer pool with the
        # scale pools but no kv_quant should name the real mismatch, not
        # fall through to the float-kv_dtype error below
        raise ValueError(
            "kv_quant and the scale pools come together: kv_quant="
            f"{kv_quant!r} with k_scale_pool "
            f"{'set' if k_scale_pool is not None else 'missing'}")
    kv_dtype = canonical_kv_dtype(kv_dtype)
    if kv_dtype is None:
        kv_dtype = (jnp.dtype(jnp.float32) if spec is not None
                    else canonical_kv_dtype(k_pool.dtype))

    in_specs = [
        pl.BlockSpec((1, KH, G, hd),
                     lambda b, p, c, t, kl: (b, 0, 0, 0)),
        pl.BlockSpec((1, L, KH, hd),
                     lambda b, p, c, t, kl: (t[b, c], 0, 0, 0)),
        pl.BlockSpec((1, L, KH, hd),
                     lambda b, p, c, t, kl: (t[b, c], 0, 0, 0)),
    ]
    operands = (tables, k_len, q, k_pool, v_pool)
    if spec is not None:
        # per-block scales ride the same table walk as their code blocks
        in_specs += [
            pl.BlockSpec((1, 1, KH, 1),
                         lambda b, p, c, t, kl: (t[b, c], 0, 0, 0)),
            pl.BlockSpec((1, 1, KH, 1),
                         lambda b, p, c, t, kl: (t[b, c], 0, 0, 0)),
        ]
        operands += (k_scale_pool, v_scale_pool)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, _num_passes(softmax_impl), M),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KH, G, hd),
                               lambda b, p, c, t, kl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, G, 1), jnp.float32),    # running max
            pltpu.VMEM((KH, G, 1), jnp.float32),    # running sum
            pltpu.VMEM((KH, G, hd), jnp.float32),   # value accumulator
        ],
    )
    kern = functools.partial(_gqa_kernel, block_len=L, scale=float(scale),
                             impl=softmax_impl, sched=sched, cfg=cfg,
                             kv_dtype=kv_dtype, kv_quant_spec=spec)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), jnp.float32),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# MLA decode (absorbed form)
# ---------------------------------------------------------------------------
def _mla_kernel(tbl_ref, kl_ref, qe_ref, qr_ref, c_ref, r_ref, o_ref,
                m_sc, l_sc, acc_sc, *, block_len: int, scale: float,
                impl: str, sched: MRSchedule, cfg: FixedConfig):
    b, p, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((p == 0) & (c == 0))
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    klen = kl_ref[b]
    base = c * block_len

    @pl.when(base < klen)
    def _():
        qe = qe_ref[0].astype(jnp.float32)                      # (H, R)
        qr = qr_ref[0].astype(jnp.float32)                      # (H, P)
        cc = c_ref[0].astype(jnp.float32)                       # (L, R)
        cr = r_ref[0].astype(jnp.float32)                       # (L, P)
        s = (jnp.einsum("hr,lr->hl", qe, cc,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("hp,lp->hl", qr, cr,
                          preferred_element_type=jnp.float32)) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < klen, s, NEG_INF)
        _pass_update(
            s, cc, p, impl, sched, cfg, m_sc, l_sc, acc_sc,
            lambda pr, vb: jnp.einsum("hl,lr->hr", pr, vb,
                                      preferred_element_type=jnp.float32))

    if impl in (None, "exact"):
        o_ref[...] = (acc_sc[...] / l_sc[...])[None]
    else:
        o_ref[...] = acc_sc[...][None]      # pass 2 accumulates normalized p


def mla_decode(q_eff, q_rope, c_pool, r_pool, tables, k_len, *, scale: float,
               softmax_impl: str = "exact",
               sched: MRSchedule = PAPER_SCHEDULE,
               cfg: FixedConfig = PAPER_FIXED,
               interpret: bool = False) -> jax.Array:
    """Paged absorbed-form MLA decode: scores against the compressed
    latent + shared rope key, output accumulated in the latent space.

    q_eff:  (B, H, R) absorbed queries (q_nope @ wk_b)
    q_rope: (B, H, P) rope-rotated query part
    c_pool: (N, L, R) compressed-latent pool    r_pool: (N, L, P) rope keys
    tables: (B, M) int32;  k_len: (B,) int32 (>= 1)

    Returns (B, H, R) f32 latent outputs (project with wv_b outside, as
    models.attention._mla_absorbed_decode does).
    """
    B, H, R = q_eff.shape
    P = q_rope.shape[-1]
    N, L = c_pool.shape[:2]
    M = tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, _num_passes(softmax_impl), M),
        in_specs=[
            pl.BlockSpec((1, H, R), lambda b, p, c, t, kl: (b, 0, 0)),
            pl.BlockSpec((1, H, P), lambda b, p, c, t, kl: (b, 0, 0)),
            pl.BlockSpec((1, L, R), lambda b, p, c, t, kl: (t[b, c], 0, 0)),
            pl.BlockSpec((1, L, P), lambda b, p, c, t, kl: (t[b, c], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, R), lambda b, p, c, t, kl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, R), jnp.float32),
        ],
    )
    kern = functools.partial(_mla_kernel, block_len=L, scale=float(scale),
                             impl=softmax_impl, sched=sched, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, R), jnp.float32),
        interpret=interpret,
    )(tables, k_len, q_eff, q_rope, c_pool, r_pool)
    return out


# ---------------------------------------------------------------------------
# Shard-local execution over a ("data","model") mesh
#
# pallas_call is opaque to the GSPMD partitioner — XLA cannot slice a
# kernel's grid or its scalar-prefetched block tables, so running these
# kernels on a sharded cache means wrapping them in shard_map over the
# model axis: each shard runs the SAME grid (slots x passes x table
# chunks) against its OWN head slice of the pools, with the block tables
# and lengths replicated (they are head-invariant host metadata — the
# whole point of KVPager staying shard-agnostic). The per-shard kernel is
# bitwise the single-device kernel on a narrower head axis, and head
# slices never interact inside attention, so no collective appears inside
# the wrapped region (check_rep=False: outputs are head-sharded, not
# replicated).
# ---------------------------------------------------------------------------
def shard_local_gqa(attend_fn, mesh, q, k_pool, v_pool, tables, k_len,
                    k_scale_pool=None, v_scale_pool=None):
    """Run a GQA paged-attend callable shard-locally over mesh axis "model".

    attend_fn: kernels.ops.paged_attend_gqa with kwargs bound (scale /
    softmax_impl / kv_dtype / kv_quant); q (B,KH,G,hd) and the pools
    (N,L,KH,hd) arrive KH-sharded, tables/k_len replicated; output is
    KH-sharded.  Quantized pools bring their (N,1,KH,1) scale pools, cut
    on the same KH dim — each shard dequantizes with exactly the scales
    the unsharded kernel would, so TP layouts stay token-identical.
    Caller guarantees KH % mesh.shape["model"] == 0.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    in_specs = [PS(None, "model", None, None),        # q (B, KH, G, hd)
                PS(None, None, "model", None),        # k_pool (N, L, KH, hd)
                PS(None, None, "model", None),        # v_pool
                PS(None, None),                       # tables (B, M)
                PS(None)]                             # k_len (B,)
    args = (q, k_pool, v_pool, tables, k_len)
    if k_scale_pool is not None:
        in_specs += [PS(None, None, "model", None)] * 2  # scales (N,1,KH,1)
        args += (k_scale_pool, v_scale_pool)
        fn = lambda q_, kp_, vp_, t_, kl_, ks_, vs_: attend_fn(
            q_, kp_, vp_, t_, kl_, k_scale_pool=ks_, v_scale_pool=vs_)
    else:
        fn = attend_fn

    return shard_map(
        fn, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=PS(None, "model", None, None),
        check_rep=False,
    )(*args)


def shard_local_mla(attend_fn, mesh, q_eff, q_rope, c_pool, r_pool, tables,
                    k_len):
    """Run an MLA paged-attend callable shard-locally over mesh axis
    "model".

    MLA's latent/rope pools carry no head axis — they are replicated and
    each shard walks the full latent with its own H slice of q_eff/q_rope
    (head-parallel over the absorbed queries). Output (B,H,R) is
    H-sharded. Caller guarantees H % mesh.shape["model"] == 0.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    return shard_map(
        attend_fn, mesh=mesh,
        in_specs=(PS(None, "model", None),            # q_eff (B, H, R)
                  PS(None, "model", None),            # q_rope (B, H, P)
                  PS(None, None, None),               # c_pool (N, L, R)
                  PS(None, None, None),               # r_pool (N, L, P)
                  PS(None, None),                     # tables (B, M)
                  PS(None)),                          # k_len (B,)
        out_specs=PS(None, "model", None),
        check_rep=False,
    )(q_eff, q_rope, c_pool, r_pool, tables, k_len)


# ---------------------------------------------------------------------------
# Transient working-set accounting (the metric benchmarks/serving.py gates)
# ---------------------------------------------------------------------------
def _dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def decode_transient_bytes(cfg, *, max_len: int, block_len: int,
                           impl: str, pool_dtype=jnp.float32,
                           kv_quant: str = "none") -> int:
    """Per-row transient working set of one paged decode attend, in bytes.

    "gather" materializes the full table gather — two (max_len, heads,
    dim)-shaped buffers per row (K and V, or latent + rope for MLA) — so
    it scales with ``max_len``.  "pallas" holds exactly the VMEM blocks of
    one grid step (q + one K/V block pair + the f32 scratch accumulators):
    a function of ``block_len`` only.  Derived from the same shapes the
    BlockSpecs above are built from, so this metric cannot drift from the
    kernel silently.

    kv_quant != "none" (GQA only): gathered/streamed K/V are integer codes
    in the format's lane width plus per-block f32 scales, and every read
    also materializes the dequantized f32 buffer — the transient trades a
    narrower gather for the dequant copy; the *resident* pool is where
    quantization wins (kv.quant.bytes_per_token).
    """
    from repro.core import kv_quant as kvq

    spec = kvq.spec_for(kv_quant)
    ib = _dtype_bytes(pool_dtype)
    if getattr(cfg, "mla", None) is not None:
        if spec is not None:
            raise ValueError("kv_quant applies to GQA paged pools only")
        H, R, P = cfg.num_heads, cfg.mla.kv_lora_rank, cfg.mla.qk_rope_dim
        if impl == "gather":
            return max_len * (R + P) * ib
        if impl == "pallas":
            q = H * (R + P) * ib
            kv = block_len * (R + P) * ib
            scratch = (H * 1 * 2 + H * R) * 4
            return q + kv + H * R * 4 + scratch
    else:
        from repro.models.attention import _padded_heads

        H, KH = _padded_heads(cfg)
        G, hd = H // KH, cfg.head_dim
        kv_ib = _dtype_bytes(spec.code_dtype) if spec is not None else ib
        nblk = -(-max_len // block_len)
        if impl == "gather":
            codes = 2 * max_len * KH * hd * kv_ib
            if spec is None:
                return codes
            scales = 2 * nblk * KH * 4
            dequant = 2 * max_len * KH * hd * 4
            return codes + scales + dequant
        if impl == "pallas":
            q = KH * G * hd * ib
            kv = 2 * block_len * KH * hd * kv_ib
            scratch = (KH * G * 2 + KH * G * hd) * 4
            extra = (2 * KH * 4 + 2 * block_len * KH * hd * 4
                     if spec is not None else 0)
            return q + kv + KH * G * hd * 4 + scratch + extra
    raise ValueError(f"unknown paged_attend_impl {impl!r}")
