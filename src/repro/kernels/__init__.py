# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""CORDIC Pallas kernels.

Every kernel has a pure-jnp oracle in ``ref.py`` and a public jit'd entry
in ``ops.py``; CPU runs interpret mode, TPU compiles via Mosaic. Which
datapath a model uses is selected per-config (``cfg.act_impl``,
``cfg.softmax_impl``, ``cfg.loss_impl``, ``cfg.kv_impl``,
``cfg.paged_attend_impl``) — the authoritative selection-matrix table
for all of them lives in ``docs/architecture.md``.
"""
