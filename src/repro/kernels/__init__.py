# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""CORDIC Pallas kernels and their selection matrix.

Every kernel has a pure-jnp oracle in ``ref.py`` and a public jit'd entry
in ``ops.py``; CPU runs interpret mode, TPU compiles via Mosaic.  Which
datapath a model uses is selected per-config:

========================  ======================  ===========================
config selector           value                   kernel / path
========================  ======================  ===========================
``cfg.act_impl``          ``exact``               jax.nn activations
                          ``cordic_float/fixed``  jnp engine datapaths
                          ``cordic_pallas``       cordic_act.py (sigmoid/
                                                  tanh/silu/exp/log/softplus/
                                                  elu/gelu_erf, fused
                                                  silu_mul, int sigmoid_q)
``cfg.softmax_impl``      ``exact``               jax.nn.softmax
                          ``cordic_fixed``        jnp Q2.14 softmax
                          ``cordic_pallas``       softmax_cordic.py fused
                                                  softmax_2d/log_softmax_2d
``cfg.loss_impl``         ``exact | cordic |      train/losses.py ->
                          cordic_pallas``         softmax_cordic.log_softmax
``cfg.paged_attend_impl`` ``gather``              models/attention.py
                                                  full-table gather attend
                          ``pallas``              paged_attention.py block-
                                                  walking decode kernels
                                                  (gqa_decode / mla_decode)
========================  ======================  ===========================
"""
