"""Public jit'd wrappers around the CORDIC Pallas kernels.

Shape-polymorphic (any rank), dtype-polymorphic (f32/bf16; int16/int32 for
the integer path), differentiable (custom_jvp from the primal output), and
backend-adaptive: on the CPU container the kernels run in interpret mode
(the kernel body executes in Python, bit-exactly); on TPU the same
pallas_call compiles via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cordic_act as K
from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE

_COLS = 1024


def _use_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _to_2d(x: jax.Array):
    n = x.size
    cols = min(_COLS, max(128, n)) if n >= 128 else max(n, 1)
    rows = -(-n // cols)
    pad = rows * cols - n
    flat = jnp.ravel(x)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, cols), n


def _from_2d(y2: jax.Array, n: int, shape, dtype):
    return jnp.ravel(y2)[:n].reshape(shape).astype(dtype)


def _elementwise(x: jax.Array, op: str, sched, cfg, max_doublings: int) -> jax.Array:
    x2, n = _to_2d(x)
    y2 = K.act_2d(x2, op, sched=sched, cfg=cfg, max_doublings=max_doublings,
                  interpret=_use_interpret())
    return _from_2d(y2, n, x.shape, x.dtype)


def _make_unary(op: str, deriv):
    @functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
    def f(x, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED, max_doublings=3):
        return _elementwise(x, op, sched, cfg, max_doublings)

    @f.defjvp
    def f_jvp(sched, cfg, max_doublings, primals, tangents):
        (x,), (dx,) = primals, tangents
        y = f(x, sched, cfg, max_doublings)
        return y, deriv(x, y) * dx

    return f


#: sigmoid with the paper's |x|<=1 clamp contract.
sigmoid = _make_unary("sigmoid", lambda x, s: s * (1.0 - s))
#: sigmoid with dyadic range extension to |x| <= 8.
sigmoid_wide = _make_unary("sigmoid_wide", lambda x, s: s * (1.0 - s))
#: tanh with the paper's |z|<=0.5 clamp contract.
tanh = _make_unary("tanh", lambda x, t: 1.0 - t * t)

# Engine-derived function kinds, each a dedicated kernel bit-identical to its
# jnp fixed-path twin in cordic_engine.functions; tangent coefficients come
# from the primal output (exp' = y; softplus' = sigma = 1 - e^-y;
# elu' = y + alpha = alpha e^x on the negative branch).
exp = _make_unary("exp", lambda x, y: y)
# log's forward floors x at 1e-30, so the primal is constant (flat) for
# x <= 0 — the tangent must be 0 there, not 1/clamp.
log = _make_unary("log", lambda x, y: jnp.where(x > 1e-30, 1.0 / x, 0.0))
softplus = _make_unary("softplus", lambda x, y: -jnp.expm1(-y))
elu = _make_unary("elu", lambda x, y: jnp.where(x > 0, 1.0, y + 1.0))
#: gelu'(x) = Phi(x) + x phi(x) — cheap closed form, exact to first order.
gelu_erf = _make_unary(
    "gelu_erf",
    lambda x, y: jax.scipy.stats.norm.cdf(x) + x * jax.scipy.stats.norm.pdf(x))


@functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
def silu(x, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED, max_doublings=3):
    """x * sigmoid(x), wide-range, fused in one kernel pass."""
    return _elementwise(x, "silu", sched, cfg, max_doublings)


@silu.defjvp
def _silu_jvp(sched, cfg, max_doublings, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = silu(x, sched, cfg, max_doublings)
    # silu'(x) = s(x) + x s'(x) = y/x + s(1-s)x ; use stable form via sigmoid
    s = sigmoid_wide(x, sched, cfg, max_doublings)
    return y, (s + x * s * (1.0 - s)) * dx


@functools.partial(jax.custom_jvp, nondiff_argnums=(2, 3, 4))
def silu_mul(gate, up, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED, max_doublings=3):
    """Fused SwiGLU combiner: up * gate * sigmoid(gate) (one VMEM pass).

    gate/up must have identical shapes (the two MLP projections).
    """
    assert gate.shape == up.shape
    g2, n = _to_2d(gate)
    u2, _ = _to_2d(up)
    y2 = K.silu_mul_2d(g2, u2, sched=sched, cfg=cfg, max_doublings=max_doublings,
                       interpret=_use_interpret())
    return _from_2d(y2, n, gate.shape, gate.dtype)


@silu_mul.defjvp
def _silu_mul_jvp(sched, cfg, max_doublings, primals, tangents):
    (g, u), (dg, du) = primals, tangents
    s = sigmoid_wide(g, sched, cfg, max_doublings)
    sg = g * s
    y = u * sg
    dsg = s + g * s * (1.0 - s)
    return y, u * dsg * dg + sg * du


@functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
def softmax(x, axis: int = -1, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED):
    """Fused CORDIC softmax (max-subtract + CORDIC-exp + LVC normalize).

    Any rank; reduces along `axis`. -inf/-1e30 masked lanes flush to 0,
    matching jax.nn.softmax semantics on padded attention rows.
    """
    from repro.kernels import softmax_cordic as SM

    xm = jnp.moveaxis(x, axis, -1)
    lead = xm.shape[:-1]
    c = xm.shape[-1]
    y2 = SM.softmax_2d(xm.reshape(-1, c).astype(jnp.float32),
                       sched=sched, cfg=cfg, interpret=_use_interpret())
    return jnp.moveaxis(y2.reshape(*lead, c).astype(x.dtype), -1, axis)


@softmax.defjvp
def _softmax_jvp(axis, sched, cfg, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = softmax(x, axis, sched, cfg)
    dy = y * (dx - jnp.sum(y * dx, axis=axis, keepdims=True))
    return y, dy


@functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
def log_softmax(x, axis: int = -1, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED):
    """Fused CORDIC log-softmax (max-subtract + CORDIC-exp + CORDIC-log).

    Any rank; reduces along `axis`. -inf/-1e30 masked lanes keep their
    hugely negative value, matching jax.nn.log_softmax on padded rows.
    This is the train-path kernel behind cfg.loss_impl="cordic_pallas".
    """
    from repro.kernels import softmax_cordic as SM

    xm = jnp.moveaxis(x, axis, -1)
    lead = xm.shape[:-1]
    c = xm.shape[-1]
    y2 = SM.log_softmax_2d(xm.reshape(-1, c).astype(jnp.float32),
                           sched=sched, cfg=cfg, interpret=_use_interpret())
    return jnp.moveaxis(y2.reshape(*lead, c).astype(x.dtype), -1, axis)


@log_softmax.defjvp
def _log_softmax_jvp(axis, sched, cfg, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = log_softmax(x, axis, sched, cfg)
    p = jnp.exp(y)
    return y, dx - jnp.sum(p * dx, axis=axis, keepdims=True)


def paged_attend_gqa(q, k_pool, v_pool, tables, k_len, *, scale,
                     softmax_impl: str = "exact", kv_dtype=None,
                     kv_quant: str = "none",
                     k_scale_pool=None, v_scale_pool=None,
                     sched=PAPER_SCHEDULE, cfg=PAPER_FIXED) -> jax.Array:
    """Block-walking paged GQA decode attend (kernels/paged_attention.py).

    Walks each row's live KV blocks through its block table — one block
    in VMEM per grid step, online softmax in f32 scratch — instead of
    gathering the full (max_len)-sized buffer.  Selected by
    ``cfg.paged_attend_impl="pallas"`` in models.attention.  With
    ``kv_quant`` set, the pools hold integer codes and the per-block
    scale pools ride along: each block dequantizes in VMEM via the
    CORDIC linear-rotation multiply (core/kv_quant.py).
    """
    from repro.kernels import paged_attention as PA

    return PA.gqa_decode(q, k_pool, v_pool, tables, k_len, scale=scale,
                         softmax_impl=softmax_impl, kv_dtype=kv_dtype,
                         kv_quant=kv_quant, k_scale_pool=k_scale_pool,
                         v_scale_pool=v_scale_pool,
                         sched=sched, cfg=cfg, interpret=_use_interpret())


def paged_attend_mla(q_eff, q_rope, c_pool, r_pool, tables, k_len, *, scale,
                     softmax_impl: str = "exact",
                     sched=PAPER_SCHEDULE, cfg=PAPER_FIXED) -> jax.Array:
    """Block-walking paged MLA decode attend (absorbed form); see
    paged_attend_gqa.  Returns latent outputs (B,H,R) f32."""
    from repro.kernels import paged_attention as PA

    return PA.mla_decode(q_eff, q_rope, c_pool, r_pool, tables, k_len,
                         scale=scale, softmax_impl=softmax_impl,
                         sched=sched, cfg=cfg, interpret=_use_interpret())


def sigmoid_q(x_q: jax.Array, sched=PAPER_SCHEDULE, cfg=PAPER_FIXED) -> jax.Array:
    """Integer path: Q2.14 codes in (int16/int32), Q2.14 codes out.

    The quantized-inference entry point — activations never leave the
    integer domain (no dequant/requant round trip).
    """
    x2, n = _to_2d(x_q)
    y2 = K.act_q_2d(x2, sched=sched, cfg=cfg, interpret=_use_interpret())
    return _from_2d(y2, n, x_q.shape, x_q.dtype)
