"""Pallas TPU kernel for the MR-HRC CORDIC activation pipeline.

TPU mapping of the paper's fully-pipelined FPGA datapath:

* the 26-stage shift-add pipeline is fully unrolled inside one grid cell —
  straight-line VPU code over an (block_rows, block_cols) tile of int32
  lanes (8x128 VREG granularity);
* HBM -> VMEM movement is expressed with an explicit BlockSpec; each element
  is loaded once and stored once (the kernel is elementwise, so the memory
  term is the roofline floor and the VPU op count — which mixed radix
  minimizes — is the compute term);
* all arithmetic is integer add/sub/compare/select/shift on Q2.14 codes,
  plus a float quantize/dequantize at the boundary. No transcendentals,
  no division, no MXU involvement — the TPU analogue of "zero DSP".

Fused variants (`silu`, `silu_mul`) keep the elementwise epilogue of SwiGLU
MLPs inside the same VMEM tile, saving an HBM round-trip per activation —
this is the framework-level payoff of having the activation as a kernel.

Beyond the sigmoid/tanh family the same tile runs the generalized-engine
function kinds (`exp`, `log`, `softplus`, `elu`, `gelu_erf`): hyperbolic
rotation for e^r, hyperbolic vectoring for the atanh-based log, with dyadic
range reduction and the 2^k scale as an exponent-field bitcast. Each is
bit-identical to its jnp fixed-path twin in cordic_engine.functions, which
the golden-vector conformance suite enforces per backend.

Validated bit-exactly against kernels/ref.py (the pure-jnp Q2.14 oracle) in
interpret mode; compiled path is exercised by the dry-run on the TPU target.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE
from repro.cordic_engine.schedule import HYP_VECTORING, hyp_vectoring_for

# ---------------------------------------------------------------------------
# In-kernel fixed-point pipeline (explicit, Mosaic-friendly ops only)
# ---------------------------------------------------------------------------

_I32 = jnp.int32
_LN2 = np.float32(math.log(2.0))
_INV_LN2 = np.float32(1.0 / math.log(2.0))
#: exp clamp: keeps 2^k inside normal f32 exponent range (== functions._EXP_CLIP).
_EXP_CLIP = np.float32(80.0)
_ERF_A = np.float32(0.147)
#: hyperbolic-vectoring schedule for the in-kernel log leg (j=1..14 with the
#: textbook convergence repeats) — the same iteration list the jnp fixed path
#: uses, so the kernels stay bit-identical to cordic_engine.functions.
_HYP_VEC_JS = HYP_VECTORING.r2_js


def _wrap16(v, bits: int):
    """Mask an int32 lane to `bits`-bit two's complement (add/and/sub)."""
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    return ((v + half) & mask) - half


def _shr(v, s: int, bits: int):
    """Arithmetic right shift with truncation, re-wrapped to the register width."""
    if s <= 0:
        return v
    return _wrap16(v >> s, bits)


def _coshsinh_q(zq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 MR-HRC rotation stage: zq (cfg.fmt angle codes) -> (cosh, sinh)
    codes. Shared by the tanh pipeline and the fused softmax-exp kernel
    (e^r = cosh r + sinh r). Bit-identical to core.cordic.mr_hrc_q."""
    bits = cfg.fmt.total_bits
    fb = cfg.fmt.frac_bits
    zbits = cfg.zfmt.total_bits
    zfb = cfg.zfmt.frac_bits

    # --- extend angle register ---------------------------------------------
    z = zq
    if cfg.z_guard:
        z = _wrap16(z << cfg.z_guard, zbits)

    x = jnp.full_like(zq, _I32(int(round(sched.x0 * (1 << fb)))))
    y = jnp.zeros_like(zq)

    # --- radix-2 HRC stage -------------------------------------------------
    for j in sched.r2_js:
        a = _I32(int(round(math.atanh(2.0 ** -j) * (1 << zfb))))
        pos = z >= 0
        xs = _shr(x, j, bits)
        ys = _shr(y, j, bits)
        x_n = jnp.where(pos, _wrap16(x + ys, bits), _wrap16(x - ys, bits))
        y_n = jnp.where(pos, _wrap16(y + xs, bits), _wrap16(y - xs, bits))
        z = jnp.where(pos, _wrap16(z - a, zbits), _wrap16(z + a, zbits))
        x, y = x_n, y_n

    # --- radix-4 HRC stage (SRT digit set {-2..2}) -------------------------
    for j in sched.r4_js:
        t05 = _I32(int(round(0.5 * 4.0 ** -j * (1 << zfb))))
        t15 = _I32(int(round(1.5 * 4.0 ** -j * (1 << zfb))))
        a1 = _I32(int(round(math.atanh(1.0 * 4.0 ** -j) * (1 << zfb))))
        a2 = _I32(int(round(math.atanh(2.0 * 4.0 ** -j) * (1 << zfb))))
        pos = z >= 0
        mag2 = (z >= t15) | (z < -t15)
        mag0 = (z < t05) & (z >= -t05)
        xs1 = _shr(x, 2 * j, bits)
        ys1 = _shr(y, 2 * j, bits)
        xs2 = _shr(x, 2 * j - 1, bits)
        ys2 = _shr(y, 2 * j - 1, bits)
        zero = jnp.zeros_like(x)
        dx = jnp.where(mag0, zero, jnp.where(mag2, ys2, ys1))
        dy = jnp.where(mag0, zero, jnp.where(mag2, xs2, xs1))
        da = jnp.where(mag0, zero, jnp.where(mag2, a2, a1))
        x = jnp.where(pos, _wrap16(x + dx, bits), _wrap16(x - dx, bits))
        y = jnp.where(pos, _wrap16(y + dy, bits), _wrap16(y - dy, bits))
        z = jnp.where(pos, _wrap16(z - da, zbits), _wrap16(z + da, zbits))

    return x, y


def _lvc_div_q(x, y, sched: MRSchedule, cfg: FixedConfig):
    """Radix-2 linear vectoring: y/x in cfg.zfmt codes (no guard-bit drop).

    Shared by the tanh pipeline (t = sinh/cosh) and the softmax kernel's
    normalization (p = e_i / sum). Bit-identical to core.cordic.r2_lvc_q.
    """
    bits = cfg.fmt.total_bits
    zbits = cfg.zfmt.total_bits
    zfb = cfg.zfmt.frac_bits
    t = jnp.zeros_like(y)
    for j in sched.lvc_js:
        pos = y >= 0
        xs = _shr(x, j, bits)
        step = _I32(1 << max(zfb - j, 0))
        y = jnp.where(pos, _wrap16(y - xs, bits), _wrap16(y + xs, bits))
        t = jnp.where(pos, _wrap16(t + step, zbits), _wrap16(t - step, zbits))
    return t


def _guard_drop(t, cfg: FixedConfig):
    """Requantize zfmt -> fmt (out_round="nearest" on the guard-bit drop)."""
    if cfg.z_guard:
        t = _wrap16((t + (1 << (cfg.z_guard - 1))) >> cfg.z_guard,
                    cfg.fmt.total_bits)
    return t


def _cordic_tanh_q(zq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 int32-lane tanh pipeline; bit-identical to core.cordic.tanh_mr_q.

    zq: int32 codes of the angle z in cfg.fmt, |z| <= 0.5. Returns int32
    codes of tanh(z) in cfg.fmt.
    """
    x, y = _coshsinh_q(zq, sched, cfg)
    return _guard_drop(_lvc_div_q(x, y, sched, cfg), cfg)


def _cordic_sigmoid_q(xq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 sigmoid: input shift, tanh core, output scale+offset.

    Bit-identical to core.cordic.sigmoid_mr_q.
    """
    bits = cfg.fmt.total_bits
    fb = cfg.fmt.frac_bits
    t = _cordic_tanh_q(_shr(xq, 1, bits), sched, cfg)
    # --- output stage: sigma = 1/2 + t/2 (round-to-nearest half) -----------
    half = _I32(1 << (fb - 1))
    t2 = _wrap16((t + 1) >> 1, bits)
    return _wrap16(half + t2, bits)


def _quantize_f(xf, fb: int, bits: int = 16):
    """float32 -> Q codes, round-to-nearest, saturating (boundary op)."""
    scaled = xf * np.float32(1 << fb)
    q = jnp.round(scaled).astype(_I32)
    lim = (1 << (bits - 1)) - 1
    return jnp.clip(q, -lim - 1, lim)


def _dequantize_f(q, fb: int):
    return q.astype(jnp.float32) * np.float32(1.0 / (1 << fb))


def _exp2_i32(k):
    """2^k for int32 k in [-126, 127] via the f32 exponent field (no exp2)."""
    return jax.lax.bitcast_convert_type(((k + 127) << 23).astype(jnp.int32),
                                        jnp.float32)


def _frexp_f(v):
    """(m, p) with v = m * 2^p, m in [0.5, 1) — exponent-field frexp.

    Valid for positive normal f32 (callers floor at 1e-30 >> FLT_MIN).
    Matches jnp.frexp bit-for-bit on that domain, including exact powers
    of two (1.0 -> (0.5, 1)).
    """
    e = (jax.lax.bitcast_convert_type(v, jnp.int32) >> 23) - 127
    m = v * _exp2_i32(-e) * np.float32(0.5)            # [1,2) -> [0.5,1), exact
    return m, e + 1


def _hyp_vector_q(x, y, cfg: FixedConfig, js=_HYP_VEC_JS):
    """Radix-2 hyperbolic vectoring: drives y -> 0, returns atanh(y0/x0)
    codes in cfg.zfmt. Bit-identical to cordic_engine.core.vector_q with the
    HYP_VECTORING schedule (same shift order, same where/add/sub structure).
    """
    bits = cfg.fmt.total_bits
    zbits = cfg.zfmt.total_bits
    zfb = cfg.zfmt.frac_bits
    z = jnp.zeros_like(y)
    for j in js:
        a = _I32(int(round(math.atanh(2.0 ** -j) * (1 << zfb))))
        plus = y < 0                                   # e = +1 branch
        xs = _shr(x, j, bits)
        ys = _shr(y, j, bits)
        x_n = jnp.where(plus, _wrap16(x + ys, bits), _wrap16(x - ys, bits))
        y_n = jnp.where(plus, _wrap16(y + xs, bits), _wrap16(y - xs, bits))
        z = jnp.where(plus, _wrap16(z - a, zbits), _wrap16(z + a, zbits))
        x, y = x_n, y_n
    return z


def _exp_q(xf, sched: MRSchedule, cfg: FixedConfig):
    """e^x over (-80, 80): dyadic reduction + Q2.14 cosh+sinh rotation.

    Bit-identical to cordic_engine.functions.exp_fixed (the 2^k scale is an
    exponent-field bitcast of the same exact power of two jnp.exp2 yields).
    """
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits
    x = jnp.clip(xf, -_EXP_CLIP, _EXP_CLIP)
    k = jnp.round(x * _INV_LN2)
    r = x - k * _LN2                                   # |r| <= ln2/2 < 0.35
    c, s = _coshsinh_q(_quantize_f(r, fb, bits), sched, cfg)
    eq = _wrap16(c + s, bits)                          # e^r in (0.70, 1.42)
    return _dequantize_f(eq, fb) * _exp2_i32(k.astype(_I32))


def _log_q(v, cfg: FixedConfig):
    """ln v for v > 0: exponent-field mantissa split + atanh identity.

    Bit-identical to cordic_engine.functions.log_fixed: the vectoring runs
    on (m+1, m-1) with m in [0.5, 1) — both inside the Q format, no
    division. The vectoring depth is sized to the format's fraction bits
    (j=1..14 with repeats for Q2.14, deeper for the wider profiles).
    """
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits
    zfb = cfg.zfmt.frac_bits
    js = _HYP_VEC_JS if fb == 14 else hyp_vectoring_for(fb).r2_js
    v = jnp.maximum(v, np.float32(1e-30))
    m, p = _frexp_f(v)
    num = _quantize_f(m - 1.0, fb, bits)               # in [-0.5, 0)
    den = _quantize_f(m + 1.0, fb, bits)               # in [1.5, 2)
    at = _dequantize_f(_hyp_vector_q(den, num, cfg, js), zfb)
    return 2.0 * at + p.astype(jnp.float32) * _LN2


def _erf_q(u, sched: MRSchedule, cfg: FixedConfig):
    """Exponential erf approximation with the CORDIC exp core (|err|<2.5e-4).

    The rational prefactor and sqrt are float boundary ops, mirroring
    cordic_engine.functions._erf_from_exp op-for-op.
    """
    u2 = u * u
    g = u2 * (np.float32(4.0 / math.pi) + _ERF_A * u2) / (1.0 + _ERF_A * u2)
    return jnp.sign(u) * jnp.sqrt(jnp.maximum(1.0 - _exp_q(-g, sched, cfg), 0.0))


def _wide_sigmoid_f(xf, sched: MRSchedule, cfg: FixedConfig, max_doublings: int):
    """Dyadic range extension around the Q2.14 core (|x| <= 2^k)."""
    ax = jnp.abs(xf)
    # k = number of halvings, chosen by compares (shift-add spirit)
    k = jnp.zeros_like(xf, dtype=_I32)
    for i in range(max_doublings):
        k = k + (ax > np.float32(2.0 ** i)).astype(_I32)
    scale = jnp.exp2(-k.astype(jnp.float32))
    xs = jnp.clip(xf * scale, -1.0, 1.0)
    s = _dequantize_f(_cordic_sigmoid_q(
        _quantize_f(xs, cfg.fmt.frac_bits, cfg.fmt.total_bits), sched, cfg),
        cfg.fmt.frac_bits)
    for i in range(max_doublings):
        s2 = s * s
        denom = s2 + (1.0 - s) * (1.0 - s)
        doubled = s2 / jnp.maximum(denom, np.float32(1e-12))
        s = jnp.where(k > i, doubled, s)
    return s


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------
def _act_kernel(x_ref, o_ref, *, op: str, sched: MRSchedule, cfg: FixedConfig,
                max_doublings: int):
    xf = x_ref[...].astype(jnp.float32)
    fb = cfg.fmt.frac_bits
    if op == "sigmoid":
        xq = _quantize_f(jnp.clip(xf, -1.0, 1.0), fb, cfg.fmt.total_bits)
        out = _dequantize_f(_cordic_sigmoid_q(xq, sched, cfg), fb)
    elif op == "tanh":
        # tanh(z), |z| <= 0.5 clamp: direct angle feed (no halving round trip)
        zq = _quantize_f(jnp.clip(xf, -0.5, 0.5), fb, cfg.fmt.total_bits)
        out = _dequantize_f(_cordic_tanh_q(zq, sched, cfg), fb)
    elif op == "sigmoid_wide":
        out = _wide_sigmoid_f(xf, sched, cfg, max_doublings)
    elif op == "silu":
        out = xf * _wide_sigmoid_f(xf, sched, cfg, max_doublings)
    elif op == "exp":
        out = _exp_q(xf, sched, cfg)
    elif op == "log":
        out = _log_q(xf, cfg)
    elif op == "softplus":
        # log(1 + e^x) = relu(x) + log(1 + e^-|x|) — both CORDIC legs
        e = _exp_q(-jnp.abs(xf), sched, cfg)
        out = jnp.maximum(xf, 0.0) + _log_q(1.0 + e, cfg)
    elif op == "elu":
        em1 = _exp_q(jnp.minimum(xf, 0.0), sched, cfg) - 1.0
        out = jnp.where(xf > 0, xf, em1)
    elif op == "gelu_erf":
        # exact-form GELU 0.5 x (1 + erf(x/sqrt2)) with CORDIC-exp erf
        out = 0.5 * xf * (1.0 + _erf_q(xf * np.float32(1.0 / math.sqrt(2.0)),
                                       sched, cfg))
    else:
        raise ValueError(op)
    o_ref[...] = out.astype(o_ref.dtype)


def _act_q_kernel(x_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig):
    """Integer-in/integer-out sigmoid (int16 Q2.14 codes end-to-end)."""
    xq = x_ref[...].astype(_I32)
    o_ref[...] = _cordic_sigmoid_q(xq, sched, cfg).astype(o_ref.dtype)


def _silu_mul_kernel(g_ref, u_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig,
                     max_doublings: int):
    """Fused SwiGLU gate: out = u * g * sigmoid(g) in one VMEM pass."""
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    s = _wide_sigmoid_f(g, sched, cfg, max_doublings)
    o_ref[...] = (u * g * s).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers with explicit VMEM BlockSpecs
# ---------------------------------------------------------------------------
#: Default VMEM tile: 256 sublane-groups x 1024 lanes of f32 = 1 MiB/tile;
#: with in/out + int32 x/y/z/t intermediates ~ 6 MiB live, comfortably inside
#: a v5e core's VMEM with double buffering.
DEFAULT_BLOCK = (256, 1024)


def _grid_and_specs(shape: Sequence[int], block):
    br = min(block[0], shape[0])
    bc = min(block[1], shape[1])
    # hardware alignment: sublane multiple of 8, lane multiple of 128
    br = max(8, (br // 8) * 8) if shape[0] >= 8 else shape[0]
    bc = max(128, (bc // 128) * 128) if shape[1] >= 128 else shape[1]
    grid = (pl.cdiv(shape[0], br), pl.cdiv(shape[1], bc))
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return grid, spec


def act_2d(x: jax.Array, op: str, *, sched: MRSchedule = PAPER_SCHEDULE,
           cfg: FixedConfig = PAPER_FIXED, max_doublings: int = 3,
           block=DEFAULT_BLOCK, interpret: bool = False) -> jax.Array:
    """Run the activation kernel over a 2D array."""
    grid, spec = _grid_and_specs(x.shape, block)
    kern = functools.partial(_act_kernel, op=op, sched=sched, cfg=cfg,
                             max_doublings=max_doublings)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x)


def act_q_2d(x_q: jax.Array, *, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED, block=DEFAULT_BLOCK,
             interpret: bool = False) -> jax.Array:
    """Integer (Q2.14 int16/int32 codes) sigmoid over a 2D array."""
    grid, spec = _grid_and_specs(x_q.shape, block)
    kern = functools.partial(_act_q_kernel, sched=sched, cfg=cfg)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x_q.shape, x_q.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x_q)


def silu_mul_2d(gate: jax.Array, up: jax.Array, *,
                sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
                max_doublings: int = 3, block=DEFAULT_BLOCK,
                interpret: bool = False) -> jax.Array:
    """Fused `up * silu(gate)` over 2D arrays of identical shape."""
    assert gate.shape == up.shape, (gate.shape, up.shape)
    grid, spec = _grid_and_specs(gate.shape, block)
    kern = functools.partial(_silu_mul_kernel, sched=sched, cfg=cfg,
                             max_doublings=max_doublings)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(gate.shape, gate.dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(gate, up)
