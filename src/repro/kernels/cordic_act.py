"""Pallas TPU kernel for the MR-HRC CORDIC activation pipeline.

TPU mapping of the paper's fully-pipelined FPGA datapath:

* the 26-stage shift-add pipeline is fully unrolled inside one grid cell —
  straight-line VPU code over an (block_rows, block_cols) tile of int32
  lanes (8x128 VREG granularity);
* HBM -> VMEM movement is expressed with an explicit BlockSpec; each element
  is loaded once and stored once (the kernel is elementwise, so the memory
  term is the roofline floor and the VPU op count — which mixed radix
  minimizes — is the compute term);
* all arithmetic is integer add/sub/compare/select/shift on Q2.14 codes,
  plus a float quantize/dequantize at the boundary. No transcendentals,
  no division, no MXU involvement — the TPU analogue of "zero DSP".

Fused variants (`silu`, `silu_mul`) keep the elementwise epilogue of SwiGLU
MLPs inside the same VMEM tile, saving an HBM round-trip per activation —
this is the framework-level payoff of having the activation as a kernel.

Validated bit-exactly against kernels/ref.py (the pure-jnp Q2.14 oracle) in
interpret mode; compiled path is exercised by the dry-run on the TPU target.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE

# ---------------------------------------------------------------------------
# In-kernel fixed-point pipeline (explicit, Mosaic-friendly ops only)
# ---------------------------------------------------------------------------

_I32 = jnp.int32


def _wrap16(v, bits: int):
    """Mask an int32 lane to `bits`-bit two's complement (add/and/sub)."""
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    return ((v + half) & mask) - half


def _shr(v, s: int, bits: int):
    """Arithmetic right shift with truncation, re-wrapped to the register width."""
    if s <= 0:
        return v
    return _wrap16(v >> s, bits)


def _coshsinh_q(zq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 MR-HRC rotation stage: zq (cfg.fmt angle codes) -> (cosh, sinh)
    codes. Shared by the tanh pipeline and the fused softmax-exp kernel
    (e^r = cosh r + sinh r). Bit-identical to core.cordic.mr_hrc_q."""
    bits = cfg.fmt.total_bits
    fb = cfg.fmt.frac_bits
    zbits = cfg.zfmt.total_bits
    zfb = cfg.zfmt.frac_bits

    # --- extend angle register ---------------------------------------------
    z = zq
    if cfg.z_guard:
        z = _wrap16(z << cfg.z_guard, zbits)

    x = jnp.full_like(zq, _I32(int(round(sched.x0 * (1 << fb)))))
    y = jnp.zeros_like(zq)

    # --- radix-2 HRC stage -------------------------------------------------
    for j in sched.r2_js:
        a = _I32(int(round(math.atanh(2.0 ** -j) * (1 << zfb))))
        pos = z >= 0
        xs = _shr(x, j, bits)
        ys = _shr(y, j, bits)
        x_n = jnp.where(pos, _wrap16(x + ys, bits), _wrap16(x - ys, bits))
        y_n = jnp.where(pos, _wrap16(y + xs, bits), _wrap16(y - xs, bits))
        z = jnp.where(pos, _wrap16(z - a, zbits), _wrap16(z + a, zbits))
        x, y = x_n, y_n

    # --- radix-4 HRC stage (SRT digit set {-2..2}) -------------------------
    for j in sched.r4_js:
        t05 = _I32(int(round(0.5 * 4.0 ** -j * (1 << zfb))))
        t15 = _I32(int(round(1.5 * 4.0 ** -j * (1 << zfb))))
        a1 = _I32(int(round(math.atanh(1.0 * 4.0 ** -j) * (1 << zfb))))
        a2 = _I32(int(round(math.atanh(2.0 * 4.0 ** -j) * (1 << zfb))))
        pos = z >= 0
        mag2 = (z >= t15) | (z < -t15)
        mag0 = (z < t05) & (z >= -t05)
        xs1 = _shr(x, 2 * j, bits)
        ys1 = _shr(y, 2 * j, bits)
        xs2 = _shr(x, 2 * j - 1, bits)
        ys2 = _shr(y, 2 * j - 1, bits)
        zero = jnp.zeros_like(x)
        dx = jnp.where(mag0, zero, jnp.where(mag2, ys2, ys1))
        dy = jnp.where(mag0, zero, jnp.where(mag2, xs2, xs1))
        da = jnp.where(mag0, zero, jnp.where(mag2, a2, a1))
        x = jnp.where(pos, _wrap16(x + dx, bits), _wrap16(x - dx, bits))
        y = jnp.where(pos, _wrap16(y + dy, bits), _wrap16(y - dy, bits))
        z = jnp.where(pos, _wrap16(z - da, zbits), _wrap16(z + da, zbits))

    return x, y


def _lvc_div_q(x, y, sched: MRSchedule, cfg: FixedConfig):
    """Radix-2 linear vectoring: y/x in cfg.zfmt codes (no guard-bit drop).

    Shared by the tanh pipeline (t = sinh/cosh) and the softmax kernel's
    normalization (p = e_i / sum). Bit-identical to core.cordic.r2_lvc_q.
    """
    bits = cfg.fmt.total_bits
    zbits = cfg.zfmt.total_bits
    zfb = cfg.zfmt.frac_bits
    t = jnp.zeros_like(y)
    for j in sched.lvc_js:
        pos = y >= 0
        xs = _shr(x, j, bits)
        step = _I32(1 << max(zfb - j, 0))
        y = jnp.where(pos, _wrap16(y - xs, bits), _wrap16(y + xs, bits))
        t = jnp.where(pos, _wrap16(t + step, zbits), _wrap16(t - step, zbits))
    return t


def _guard_drop(t, cfg: FixedConfig):
    """Requantize zfmt -> fmt (out_round="nearest" on the guard-bit drop)."""
    if cfg.z_guard:
        t = _wrap16((t + (1 << (cfg.z_guard - 1))) >> cfg.z_guard,
                    cfg.fmt.total_bits)
    return t


def _cordic_tanh_q(zq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 int32-lane tanh pipeline; bit-identical to core.cordic.tanh_mr_q.

    zq: int32 codes of the angle z in cfg.fmt, |z| <= 0.5. Returns int32
    codes of tanh(z) in cfg.fmt.
    """
    x, y = _coshsinh_q(zq, sched, cfg)
    return _guard_drop(_lvc_div_q(x, y, sched, cfg), cfg)


def _cordic_sigmoid_q(xq, sched: MRSchedule, cfg: FixedConfig):
    """Q2.14 sigmoid: input shift, tanh core, output scale+offset.

    Bit-identical to core.cordic.sigmoid_mr_q.
    """
    bits = cfg.fmt.total_bits
    fb = cfg.fmt.frac_bits
    t = _cordic_tanh_q(_shr(xq, 1, bits), sched, cfg)
    # --- output stage: sigma = 1/2 + t/2 (round-to-nearest half) -----------
    half = _I32(1 << (fb - 1))
    t2 = _wrap16((t + 1) >> 1, bits)
    return _wrap16(half + t2, bits)


def _quantize_f(xf, fb: int):
    """float32 -> Q codes, round-to-nearest, saturating (boundary op)."""
    scaled = xf * np.float32(1 << fb)
    q = jnp.round(scaled).astype(_I32)
    lim = (1 << 15) - 1
    return jnp.clip(q, -lim - 1, lim)


def _dequantize_f(q, fb: int):
    return q.astype(jnp.float32) * np.float32(1.0 / (1 << fb))


def _wide_sigmoid_f(xf, sched: MRSchedule, cfg: FixedConfig, max_doublings: int):
    """Dyadic range extension around the Q2.14 core (|x| <= 2^k)."""
    ax = jnp.abs(xf)
    # k = number of halvings, chosen by compares (shift-add spirit)
    k = jnp.zeros_like(xf, dtype=_I32)
    for i in range(max_doublings):
        k = k + (ax > np.float32(2.0 ** i)).astype(_I32)
    scale = jnp.exp2(-k.astype(jnp.float32))
    xs = jnp.clip(xf * scale, -1.0, 1.0)
    s = _dequantize_f(_cordic_sigmoid_q(_quantize_f(xs, cfg.fmt.frac_bits), sched, cfg),
                      cfg.fmt.frac_bits)
    for i in range(max_doublings):
        s2 = s * s
        denom = s2 + (1.0 - s) * (1.0 - s)
        doubled = s2 / jnp.maximum(denom, np.float32(1e-12))
        s = jnp.where(k > i, doubled, s)
    return s


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------
def _act_kernel(x_ref, o_ref, *, op: str, sched: MRSchedule, cfg: FixedConfig,
                max_doublings: int):
    xf = x_ref[...].astype(jnp.float32)
    fb = cfg.fmt.frac_bits
    if op == "sigmoid":
        xq = _quantize_f(jnp.clip(xf, -1.0, 1.0), fb)
        out = _dequantize_f(_cordic_sigmoid_q(xq, sched, cfg), fb)
    elif op == "tanh":
        # tanh(z), |z| <= 0.5 clamp: direct angle feed (no halving round trip)
        zq = _quantize_f(jnp.clip(xf, -0.5, 0.5), fb)
        out = _dequantize_f(_cordic_tanh_q(zq, sched, cfg), fb)
    elif op == "sigmoid_wide":
        out = _wide_sigmoid_f(xf, sched, cfg, max_doublings)
    elif op == "silu":
        out = xf * _wide_sigmoid_f(xf, sched, cfg, max_doublings)
    else:
        raise ValueError(op)
    o_ref[...] = out.astype(o_ref.dtype)


def _act_q_kernel(x_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig):
    """Integer-in/integer-out sigmoid (int16 Q2.14 codes end-to-end)."""
    xq = x_ref[...].astype(_I32)
    o_ref[...] = _cordic_sigmoid_q(xq, sched, cfg).astype(o_ref.dtype)


def _silu_mul_kernel(g_ref, u_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig,
                     max_doublings: int):
    """Fused SwiGLU gate: out = u * g * sigmoid(g) in one VMEM pass."""
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    s = _wide_sigmoid_f(g, sched, cfg, max_doublings)
    o_ref[...] = (u * g * s).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers with explicit VMEM BlockSpecs
# ---------------------------------------------------------------------------
#: Default VMEM tile: 256 sublane-groups x 1024 lanes of f32 = 1 MiB/tile;
#: with in/out + int32 x/y/z/t intermediates ~ 6 MiB live, comfortably inside
#: a v5e core's VMEM with double buffering.
DEFAULT_BLOCK = (256, 1024)


def _grid_and_specs(shape: Sequence[int], block):
    br = min(block[0], shape[0])
    bc = min(block[1], shape[1])
    # hardware alignment: sublane multiple of 8, lane multiple of 128
    br = max(8, (br // 8) * 8) if shape[0] >= 8 else shape[0]
    bc = max(128, (bc // 128) * 128) if shape[1] >= 128 else shape[1]
    grid = (pl.cdiv(shape[0], br), pl.cdiv(shape[1], bc))
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return grid, spec


def act_2d(x: jax.Array, op: str, *, sched: MRSchedule = PAPER_SCHEDULE,
           cfg: FixedConfig = PAPER_FIXED, max_doublings: int = 3,
           block=DEFAULT_BLOCK, interpret: bool = False) -> jax.Array:
    """Run the activation kernel over a 2D array."""
    grid, spec = _grid_and_specs(x.shape, block)
    kern = functools.partial(_act_kernel, op=op, sched=sched, cfg=cfg,
                             max_doublings=max_doublings)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x)


def act_q_2d(x_q: jax.Array, *, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED, block=DEFAULT_BLOCK,
             interpret: bool = False) -> jax.Array:
    """Integer (Q2.14 int16/int32 codes) sigmoid over a 2D array."""
    grid, spec = _grid_and_specs(x_q.shape, block)
    kern = functools.partial(_act_q_kernel, sched=sched, cfg=cfg)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x_q.shape, x_q.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x_q)


def silu_mul_2d(gate: jax.Array, up: jax.Array, *,
                sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
                max_doublings: int = 3, block=DEFAULT_BLOCK,
                interpret: bool = False) -> jax.Array:
    """Fused `up * silu(gate)` over 2D arrays of identical shape."""
    assert gate.shape == up.shape, (gate.shape, up.shape)
    grid, spec = _grid_and_specs(gate.shape, block)
    kern = functools.partial(_silu_mul_kernel, sched=sched, cfg=cfg,
                             max_doublings=max_doublings)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(gate.shape, gate.dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(gate, up)
