"""Pure-jnp oracle for the CORDIC activation kernels.

The oracle is the *bit-accurate* fixed-point pipeline from repro.core.cordic
(which is itself validated against the paper's claims), evaluated with plain
jnp ops — no pallas. Kernel tests assert the pallas output is bit-identical
on the integer path and exactly equal on the float path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fp
from repro.core import sigmoid as S
from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE


def sigmoid_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """Paper pipeline, clamp contract (|x| <= 1)."""
    return S.sigmoid_cordic_fixed(x, sched, cfg, clamp=True)


def tanh_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    return S.tanh_cordic_fixed(x, sched, cfg, clamp=True)


def silu_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """x * sigmoid(x) with the wide-range sigmoid (pre-activations exceed 1)."""
    return x * S.sigmoid_cordic_wide(x, sched, cfg)


def sigmoid_wide_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                     cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    return S.sigmoid_cordic_wide(x, sched, cfg)


def sigmoid_q_ref(x_q: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                  cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """Integer-in/integer-out oracle (Q2.14 codes)."""
    from repro.core.cordic import sigmoid_mr_q

    return sigmoid_mr_q(x_q, sched, cfg)


# ---------------------------------------------------------------------------
# Paged-attention decode references: the full-table *gather* computation as
# an oracle, built FROM the production functions in models.attention
# (_pool_gather + _attend_rows / _mla_absorbed_decode) rather than a
# re-implementation — so the oracle cannot silently drift from the path it
# represents.  The Pallas block-walking kernels must agree with these to
# f32 round-off on attention outputs and bit-exactly on the resulting
# argmax/token decisions.
# ---------------------------------------------------------------------------
def paged_attend_gqa_ref(q, k_pool, v_pool, tables, k_len, *, scale,
                         softmax_impl: str = "exact", kv_dtype=None,
                         kv_quant: str = "none",
                         k_scale_pool=None, v_scale_pool=None):
    """Gather-path oracle for kernels.paged_attention.gqa_decode.

    q (B,KH,G,hd); pools (N,L,KH,hd); tables (B,M); k_len (B,).
    Returns (B,KH,G,hd) f32 — _pool_gather + _attend_rows exactly as
    models.attention._gqa_paged_apply's gather decode runs them (the
    decode query sits at position k_len - 1, making the causal mask
    equivalent to the plain length mask).

    With ``kv_quant`` set the gather dequantizes through the SAME
    production helper the engine's gather attend uses
    (attention._pool_gather_dequant -> kv_quant.dequantize, the CORDIC
    linear-rotation multiply) — the oracle stays bit-exact against the
    serving path by construction, and the Pallas kernel must reproduce
    its token decisions.
    """
    from repro.core import kv_quant as kvq
    from repro.kernels.paged_attention import canonical_kv_dtype
    from repro.models import attention as A  # lazy: avoid import cycle

    spec = kvq.spec_for(kv_quant)
    kv_dtype = canonical_kv_dtype(kv_dtype)
    if kv_dtype is None:
        kv_dtype = (jnp.dtype(jnp.float32) if spec is not None
                    else canonical_kv_dtype(k_pool.dtype))
    if spec is None:
        kf = A._pool_gather(k_pool, tables).astype(kv_dtype)
        vf = A._pool_gather(v_pool, tables).astype(kv_dtype)
    else:
        kf = A._pool_gather_dequant(k_pool, k_scale_pool, tables,
                                    spec).astype(kv_dtype)
        vf = A._pool_gather_dequant(v_pool, v_scale_pool, tables,
                                    spec).astype(kv_dtype)
    o = A._attend_rows(q[:, None], kf, vf, (k_len - 1)[:, None], k_len,
                       scale, "f32", softmax_impl)
    return o[:, 0]


def paged_attend_mla_ref(q_eff, q_rope, c_pool, r_pool, tables, k_len, *,
                         scale, softmax_impl: str = "exact"):
    """Gather-path oracle for kernels.paged_attention.mla_decode.

    q_eff (B,H,R), q_rope (B,H,P); pools (N,L,R)/(N,L,P); returns the
    latent output (B,H,R) f32.  Runs the production
    _mla_absorbed_decode with identity wk_b/wv_b so the already-absorbed
    query passes through unchanged and the latent output comes back
    unprojected — the score/mask/normalize math is the real path's.
    """
    from repro.models import attention as A  # lazy: avoid import cycle

    B, H, R = q_eff.shape
    cc = A._pool_gather(c_pool, tables)
    cr = A._pool_gather(r_pool, tables)
    T = cc.shape[1]
    eye = jnp.broadcast_to(jnp.eye(R, dtype=q_eff.dtype)[:, None, :],
                           (R, H, R))
    valid = (jnp.arange(T)[None, :] < k_len[:, None])[:, None, None, :]
    o = A._mla_absorbed_decode(q_eff[:, None], q_rope[:, None], cc, cr,
                               eye, eye, scale, valid, "f32", softmax_impl)
    return o[:, 0]
