"""Pure-jnp oracle for the CORDIC activation kernels.

The oracle is the *bit-accurate* fixed-point pipeline from repro.core.cordic
(which is itself validated against the paper's claims), evaluated with plain
jnp ops — no pallas. Kernel tests assert the pallas output is bit-identical
on the integer path and exactly equal on the float path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fp
from repro.core import sigmoid as S
from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE


def sigmoid_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """Paper pipeline, clamp contract (|x| <= 1)."""
    return S.sigmoid_cordic_fixed(x, sched, cfg, clamp=True)


def tanh_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    return S.tanh_cordic_fixed(x, sched, cfg, clamp=True)


def silu_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
             cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """x * sigmoid(x) with the wide-range sigmoid (pre-activations exceed 1)."""
    return x * S.sigmoid_cordic_wide(x, sched, cfg)


def sigmoid_wide_ref(x: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                     cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    return S.sigmoid_cordic_wide(x, sched, cfg)


def sigmoid_q_ref(x_q: jax.Array, sched: MRSchedule = PAPER_SCHEDULE,
                  cfg: FixedConfig = PAPER_FIXED) -> jax.Array:
    """Integer-in/integer-out oracle (Q2.14 codes)."""
    from repro.core.cordic import sigmoid_mr_q

    return sigmoid_mr_q(x_q, sched, cfg)
