"""Fused CORDIC softmax / log-softmax Pallas kernels: max-subtract +
CORDIC-exp + (linear-vectoring divide | hyperbolic-vectoring log) in a
single VMEM pass.

TPU mapping of softmax with the paper's shift-add arithmetic:

    u_i = x_i - max(x)                    (VPU max-reduce + subtract)
    u_i = k_i ln2 + r_i, |r_i| <= ln2/2   (dyadic reduction; k_i <= 0)
    e_i = (cosh r_i + sinh r_i) * 2^k_i   (MR-HRC rotation, Q2.14; the 2^k_i
                                           scale is an exponent-field bitcast,
                                           not a transcendental)
    S   = sum_i e_i = m * 2^p, m in [1,2) (exponent-field frexp)
    p_i = ((e_i/2) / m) * 2^(k_i - p + 1) (R2-LVC division, Q2.14)

The whole row lives in one VMEM block (the grid tiles rows only), so the
max/sum reductions and both CORDIC sweeps touch HBM exactly once per
element.  No transcendentals, no hardware divide: exp and the normalization
are the same shift-add stages as the sigmoid pipeline, reused from
``cordic_act`` (`_coshsinh_q`, `_lvc_div_q`).

Numerics: the Q2.14 core gives ~1e-3 pointwise error (validated against
jax.nn.softmax within 1e-2 max-abs in tests). Lanes below e^-20 of the max
(incl. -inf masked attention positions) flush to exactly 0.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE
from repro.kernels.cordic_act import (
    _I32,
    _coshsinh_q,
    _dequantize_f,
    _exp2_i32,
    _guard_drop,
    _log_q,
    _lvc_div_q,
    _quantize_f,
    _shr,
    _wrap16,
)

_LN2 = np.float32(math.log(2.0))
_INV_LN2 = np.float32(1.0 / math.log(2.0))
#: lanes more than ~e^-20 below the row max flush to exactly zero
#: (2^-29 < half a Q2.14 ULP relative to any row sum).
_DEAD_CUTOFF = np.float32(-20.0)
_MIN_K = np.float32(-30.0)


def _softmax_kernel(x_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig,
                    n_valid: int):
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits

    xf = x_ref[...].astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, xf.shape, 1)
    live = col < n_valid
    xf = jnp.where(live, xf, np.float32(-1e30))

    # --- max-subtract + dyadic reduction -----------------------------------
    m = jnp.max(xf, axis=-1, keepdims=True)
    u = xf - m                                          # <= 0
    dead = (~live) | (u < _DEAD_CUTOFF)
    k = jnp.maximum(jnp.floor(u * _INV_LN2 + 0.5), _MIN_K)
    r = jnp.where(dead, 0.0, u - k * _LN2)              # |r| <= ln2/2

    # --- CORDIC exp: e^r = cosh r + sinh r (Q2.14 rotation stage) ----------
    c, s = _coshsinh_q(_quantize_f(r, fb, bits), sched, cfg)  # fmt registers
    eq = _wrap16(c + s, bits)                           # e^r in (0.70, 1.42)
    ki = k.astype(_I32)
    ef = jnp.where(dead, 0.0, _dequantize_f(eq, fb) * _exp2_i32(ki))

    # --- sum + exponent-field frexp: S = mS * 2^p, mS in [1, 2) ------------
    ssum = jnp.sum(ef, axis=-1, keepdims=True)
    p = (jax.lax.bitcast_convert_type(ssum, jnp.int32) >> 23) - 127
    ms = ssum * _exp2_i32(-p)
    mq = jnp.broadcast_to(_quantize_f(ms, fb, bits), eq.shape)

    # --- R2-LVC normalization: (e^r / 2) / mS, ratio in (0.175, 0.71) ------
    t = _lvc_div_q(mq, _shr(eq, 1, bits), sched, cfg)   # zfmt quotient codes
    tf = _dequantize_f(_guard_drop(t, cfg), fb)         # no-op when z_guard=0
    out = tf * _exp2_i32(ki - p + 1)
    o_ref[...] = jnp.where(dead, 0.0, out).astype(o_ref.dtype)


def _log_softmax_kernel(x_ref, o_ref, *, sched: MRSchedule, cfg: FixedConfig,
                        n_valid: int):
    """Fused CORDIC log-softmax: y_i = u_i - ln(sum_j e^{u_j}).

    Shares the max-subtract + CORDIC-exp pass with the softmax kernel; the
    normalization swaps the R2-LVC division for the hyperbolic-vectoring log
    leg (ln S = 2 atanh((m-1)/(m+1)) + p ln2 on the sum's mantissa). Masked
    lanes (-inf / -1e30) keep their hugely negative u, matching
    jax.nn.log_softmax semantics on padded attention rows.
    """
    fb = cfg.fmt.frac_bits
    bits = cfg.fmt.total_bits

    xf = x_ref[...].astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, xf.shape, 1)
    live = col < n_valid
    xf = jnp.where(live, xf, np.float32(-1e30))

    # --- max-subtract + dyadic reduction (same pass as the softmax kernel) --
    m = jnp.max(xf, axis=-1, keepdims=True)
    u = xf - m                                          # <= 0
    dead = (~live) | (u < _DEAD_CUTOFF)
    k = jnp.maximum(jnp.floor(u * _INV_LN2 + 0.5), _MIN_K)
    r = jnp.where(dead, 0.0, u - k * _LN2)              # |r| <= ln2/2

    # --- CORDIC exp for the row sum ----------------------------------------
    c, s = _coshsinh_q(_quantize_f(r, fb, bits), sched, cfg)
    eq = _wrap16(c + s, bits)
    ef = jnp.where(dead, 0.0, _dequantize_f(eq, fb) * _exp2_i32(k.astype(_I32)))
    ssum = jnp.sum(ef, axis=-1, keepdims=True)          # in [1, cols)

    # --- hyperbolic-vectoring log of the sum -------------------------------
    lns = _log_q(ssum, cfg)
    o_ref[...] = (u - lns).astype(o_ref.dtype)


def _row_block(rows: int, cols_p: int, target_bytes: int = 1 << 20) -> int:
    """Rows per block: whole rows only, ~1 MiB of f32 input per tile."""
    br = max(1, target_bytes // (4 * cols_p))
    br = min(br, rows)
    if rows >= 8:
        br = max(8, (br // 8) * 8)
    return br


def _rowwise_call(x: jax.Array, body, sched: MRSchedule, cfg: FixedConfig,
                  interpret: bool) -> jax.Array:
    """Pad columns to the 128-lane boundary and run a whole-row kernel."""
    rows, cols = x.shape
    cols_p = max(128, -(-cols // 128) * 128)
    if cols_p != cols:
        pad = jnp.full((rows, cols_p - cols), np.float32(-1e30), x.dtype)
        x = jnp.concatenate([x, pad], axis=1)
    br = _row_block(rows, cols_p)
    grid = (pl.cdiv(rows, br),)
    spec = pl.BlockSpec((br, cols_p), lambda i: (i, 0))
    kern = functools.partial(body, sched=sched, cfg=cfg, n_valid=cols)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((rows, cols_p), x.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x)
    return out[:, :cols]


def softmax_2d(x: jax.Array, *, sched: MRSchedule = PAPER_SCHEDULE,
               cfg: FixedConfig = PAPER_FIXED, interpret: bool = False) -> jax.Array:
    """Fused CORDIC softmax over the last axis of a 2D array.

    Columns are padded to the 128-lane boundary; padded lanes are masked
    inside the kernel (they contribute exactly 0 to the row sum).
    """
    return _rowwise_call(x, _softmax_kernel, sched, cfg, interpret)


def log_softmax_2d(x: jax.Array, *, sched: MRSchedule = PAPER_SCHEDULE,
                   cfg: FixedConfig = PAPER_FIXED,
                   interpret: bool = False) -> jax.Array:
    """Fused CORDIC log-softmax over the last axis of a 2D array."""
    return _rowwise_call(x, _log_softmax_kernel, sched, cfg, interpret)
