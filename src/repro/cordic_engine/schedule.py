"""Iteration schedules for the generalized mixed-radix CORDIC engine.

Two schedule types live here:

* ``MRSchedule`` — the paper's bundled pipeline schedule (radix-2 HRC +
  radix-4 HRC rotation stages followed by the R2-LVC division stage). It is
  the historical type every paper-facing module imports from
  ``repro.core.cordic``; that module now just re-exports it from here.
* ``CordicSchedule`` — the generalization: one *single-stage* schedule for a
  mode-parameterized CORDIC sweep (``mode`` in {circular, linear,
  hyperbolic}), with a radix-2 iteration list (repeats allowed — the
  textbook hyperbolic j=4/j=13 repetitions are just repeated entries) and an
  optional radix-4 tail (hyperbolic rotation only, the paper's trick).

The per-iteration "angle" is mode-dependent:

    circular    alpha_j = atan(2^-j)       gain_j = sqrt(1 + 2^-2j)
    linear      alpha_j = 2^-j             gain_j = 1
    hyperbolic  alpha_j = atanh(2^-j)      gain_j = sqrt(1 - 2^-2j)

Convergence ranges are the usual sums of the remaining angles; the
properties below compute them so callers can assert domain contracts.
"""
from __future__ import annotations

import dataclasses
import math

CIRCULAR = "circular"
LINEAR = "linear"
HYPERBOLIC = "hyperbolic"
MODES = (CIRCULAR, LINEAR, HYPERBOLIC)

ROTATION = "rotation"
VECTORING = "vectoring"
DIRECTIONS = (ROTATION, VECTORING)


def angle_r2(mode: str, j: int) -> float:
    """The elementary rotation angle alpha_j for a radix-2 iteration."""
    if mode == CIRCULAR:
        return math.atan(2.0 ** (-j))
    if mode == LINEAR:
        return 2.0 ** (-j)
    if mode == HYPERBOLIC:
        return math.atanh(2.0 ** (-j))
    raise ValueError(f"unknown mode {mode!r}")


def angle_r4(mode: str, j: int, mag: int) -> float:
    """Radix-4 angle for digit magnitude `mag` in {1, 2} (hyperbolic only)."""
    if mode != HYPERBOLIC:
        raise NotImplementedError("radix-4 stages are hyperbolic-only")
    return math.atanh(mag * 4.0 ** (-j))


# --------------------------------------------------------------------------
# The generalized single-stage schedule
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CordicSchedule:
    """One CORDIC sweep: mode + radix-2 iterations (+ optional radix-4 tail).

    ``r2_js`` may contain repeated indices (hyperbolic convergence repeats).
    ``r4_js`` is only legal for hyperbolic mode (SRT digit set {-2..2}).
    """

    mode: str
    r2_js: tuple
    r4_js: tuple = ()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.r4_js and self.mode != HYPERBOLIC:
            raise ValueError("radix-4 stages require hyperbolic mode")

    @property
    def gain(self) -> float:
        """Cumulative radix-2 stage gain K (radix-4 tail is scale-free)."""
        p = 1.0
        for j in self.r2_js:
            if self.mode == CIRCULAR:
                p *= math.sqrt(1.0 + 2.0 ** (-2 * j))
            elif self.mode == HYPERBOLIC:
                p *= math.sqrt(1.0 - 2.0 ** (-2 * j))
        return p

    @property
    def x0(self) -> float:
        """Initial x that folds the gain away (rotation-mode unit start)."""
        return 1.0 / self.gain

    @property
    def angle_range(self) -> float:
        """Max convergent |z0| (rotation) / |y0/x0| accumulation (vectoring)."""
        r = sum(angle_r2(self.mode, j) for j in self.r2_js)
        r += sum(angle_r4(self.mode, j, 2) for j in self.r4_js)
        return r

    @property
    def resolution(self) -> float:
        """Smallest elementary angle — the terminal residual scale."""
        last = min(angle_r2(self.mode, j) for j in self.r2_js)
        if self.r4_js:
            last = min(last, angle_r4(self.mode, max(self.r4_js), 1))
        return last

    def num_iterations(self) -> int:
        return len(self.r2_js) + len(self.r4_js)


def _hyp_vectoring_js(first: int = 1, last: int = 14) -> tuple:
    """Textbook hyperbolic schedule with the convergence repeats (4, 13, 40…)."""
    js = []
    for j in range(first, last + 1):
        js.append(j)
        if j in (4, 13, 40):
            js.append(j)
    return tuple(js)


#: Paper rotation schedule: R2-HRC j=2..9 then R4-HRC j=4..7 (gap-free by SRT).
HYP_ROTATION = CordicSchedule(HYPERBOLIC, tuple(range(2, 10)), tuple(range(4, 8)))
#: Hyperbolic vectoring for atanh/log: j=1..14 with repeats at 4 and 13.
HYP_VECTORING = CordicSchedule(HYPERBOLIC, _hyp_vectoring_js())
#: Linear vectoring (division) to 2^-14: j=1..14 (the paper's R2-LVC).
LIN_VECTORING = CordicSchedule(LINEAR, tuple(range(1, 15)))
#: Linear rotation (multiplication): the SAME stage list as the R2-LVC
#: divide, run in rotation direction so y accumulates x * z0 for
#: |z0| < sum 2^-j. Aliased, not copied — tuning the linear stage list can
#: never split the divide and multiply datapaths.
LIN_ROTATION = LIN_VECTORING
#: Circular rotation for sin/cos: j=0..13, range sum atan(2^-j) ~ 1.743 > pi/4.
CIRC_ROTATION = CordicSchedule(CIRCULAR, tuple(range(0, 14)))


# --------------------------------------------------------------------------
# Format-sized schedules (Q2.20 / Q2.29 accuracy studies)
# --------------------------------------------------------------------------
def hyp_rotation_for(frac_bits: int) -> CordicSchedule:
    """Paper-style mixed-radix rotation sized to a frac_bits datapath:
    the fixed R2 prologue j=2..9 (residual ~6.1e-3, inside the R4 admissible
    range) and an R4 tail extended until the smallest elementary angle
    reaches the format resolution (j up to ceil(frac_bits/2))."""
    return CordicSchedule(HYPERBOLIC, tuple(range(2, 10)),
                          tuple(range(4, (frac_bits + 1) // 2 + 1)))


def hyp_vectoring_for(frac_bits: int) -> CordicSchedule:
    """Hyperbolic vectoring j=1..frac_bits with the textbook repeats."""
    return CordicSchedule(HYPERBOLIC, _hyp_vectoring_js(1, frac_bits))


def lin_vectoring_for(frac_bits: int) -> CordicSchedule:
    """Linear vectoring to 2^-frac_bits (one digit per fraction bit)."""
    return CordicSchedule(LINEAR, tuple(range(1, frac_bits + 1)))


#: Linear rotation (multiply) sizing: same stages as the divide, by design.
lin_rotation_for = lin_vectoring_for


def mr_schedule_for(frac_bits: int) -> MRSchedule:
    """The bundled sigmoid/tanh pipeline schedule sized to frac_bits."""
    return MRSchedule(r2_js=tuple(range(2, 10)),
                      r4_js=tuple(range(4, (frac_bits + 1) // 2 + 1)),
                      lvc_js=tuple(range(1, frac_bits + 1)))


# --------------------------------------------------------------------------
# The paper's bundled pipeline schedule (moved verbatim from core/cordic.py)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MRSchedule:
    """Iteration schedule for the MR-HRC + R2-LVC pipeline.

    The defaults are exactly the paper's: radix-2 j=2..9, radix-4 j=4..7,
    and (the paper leaves LVC unspecified) LVC j=1..14 for a 16-bit result.
    """

    r2_js: tuple = tuple(range(2, 10))
    r4_js: tuple = tuple(range(4, 8))
    lvc_js: tuple = tuple(range(1, 15))

    @property
    def r2_gain(self) -> float:
        """K_h — the constant radix-2 stage gain, folded into x0 = 1/K_h."""
        p = 1.0
        for j in self.r2_js:
            p *= math.sqrt(1.0 - 2.0 ** (-2 * j))
        return p

    @property
    def x0(self) -> float:
        return 1.0 / self.r2_gain

    @property
    def r2_range(self) -> float:
        """Convergence range of the radix-2 stage (paper eq. (5))."""
        return sum(math.atanh(2.0 ** (-j)) for j in self.r2_js)

    @property
    def r4_range(self) -> float:
        """Admissible input range of the radix-4 stage (paper eq. (6))."""
        return sum(math.atanh(2.0 * 4.0 ** (-j)) for j in self.r4_js)

    @property
    def r4_gain_bounds(self) -> tuple:
        """(min, max) cumulative radix-4 gain over all digit sequences."""
        lo = 1.0
        for j in self.r4_js:
            lo *= math.sqrt(1.0 - 4.0 * 4.0 ** (-2 * j))
        return lo, 1.0

    def num_iterations(self) -> int:
        return len(self.r2_js) + len(self.r4_js) + len(self.lvc_js)

    # ---- bridges into the generalized engine ------------------------------
    @property
    def rotation(self) -> CordicSchedule:
        """The hyperbolic-rotation half as a generalized schedule."""
        return CordicSchedule(HYPERBOLIC, self.r2_js, self.r4_js)

    @property
    def division(self) -> CordicSchedule:
        """The linear-vectoring half as a generalized schedule."""
        return CordicSchedule(LINEAR, self.lvc_js)


PAPER_SCHEDULE = MRSchedule()

#: Pure radix-2 baseline ("conventional R2-HRC"): same accuracy floor needs
#: j=2..14 *with* the textbook repetition of j=4 and j=13 for gap-free
#: convergence (repeats make the per-step convergence inequality hold).
R2_BASELINE_SCHEDULE = MRSchedule(
    r2_js=(2, 3, 4, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 13, 14),
    r4_js=(),
    lvc_js=tuple(range(1, 15)),
)
