"""Generalized mixed-radix CORDIC engine.

The paper's MR-HRC sigmoid pipeline is one point in a
(mode x direction x schedule) design space; this package factors the
machinery so every point is reachable:

    schedule.py  — CordicSchedule (circular/linear/hyperbolic, mixed radix,
                   repeats) + the paper's bundled MRSchedule
    core.py      — the unified iteration engine, float + bit-accurate Q2.14
    functions.py — exp, log, atanh, divide, reciprocal, sin/cos, softplus,
                   elu, erf, gelu — each with dyadic range reduction

``repro.core.cordic`` re-exports the paper specialization (bit-identical to
the seed implementation); ``repro.kernels.softmax_cordic`` fuses the exp +
linear-vectoring legs into one Pallas softmax kernel.
"""
from repro.cordic_engine.schedule import (  # noqa: F401
    CIRC_ROTATION,
    CIRCULAR,
    HYP_ROTATION,
    HYP_VECTORING,
    HYPERBOLIC,
    LIN_VECTORING,
    LINEAR,
    MRSchedule,
    PAPER_SCHEDULE,
    R2_BASELINE_SCHEDULE,
    ROTATION,
    VECTORING,
    CordicSchedule,
)
from repro.cordic_engine.core import (  # noqa: F401
    FixedConfig,
    PAPER_FIXED,
    rotate_f,
    rotate_q,
    sweep_f,
    sweep_q,
    vector_f,
    vector_q,
)
from repro.cordic_engine import functions  # noqa: F401
