"""Generalized mixed-radix CORDIC engine.

The paper's MR-HRC sigmoid pipeline is one point in a
(mode x direction x schedule) design space; this package factors the
machinery so every point is reachable:

    schedule.py  — CordicSchedule (circular/linear/hyperbolic, mixed radix,
                   repeats) + the paper's bundled MRSchedule + format-sized
                   variants (``*_for(frac_bits)``) for the Q2.20/Q2.29 study
    core.py      — the unified iteration engine, float + bit-accurate fixed
                   point (Q2.14 default; wider formats via FORMAT_PROFILES)
    functions.py — exp, log, atanh, divide, reciprocal, sin/cos, softplus,
                   elu, erf, gelu, softmax, log_softmax — each with dyadic
                   range reduction

``repro.core.cordic`` re-exports the paper specialization (bit-identical to
the seed implementation); ``repro.kernels`` compiles the same datapaths as
Pallas kernels, enforced bit-exact by tests/test_golden_vectors.py.

Selection matrix — how model configs reach the engine
-----------------------------------------------------

Every nonlinearity in the LM substrate is config-selectable between the
XLA transcendental reference and the CORDIC datapaths:

=================  =======================  ===================================
config knob        values                   what it switches
=================  =======================  ===================================
``act_impl``       ``exact``                jax.nn / jnp lowering
(ModelConfig /     ``cordic_float``         CORDIC algorithm in f32
``get_activation`` ``cordic_fixed``         bit-accurate Q2.14, pure jnp int32
 impl arg)         ``cordic_pallas``        Pallas kernels (sigmoid/tanh/silu
                                            + dedicated exp/softplus/elu/
                                            gelu_erf/log kernels)
``softmax_impl``   ``exact``                jax.nn.softmax attention rows
                   ``cordic_fixed``         functions.softmax (jnp fixed)
                   ``cordic_pallas``        fused softmax kernel (CORDIC-exp
                                            + R2-LVC normalize, one VMEM pass)
``loss_impl``      ``exact``                jax.nn.log_softmax cross entropy
                   ``cordic``               functions.log_softmax (CORDIC exp
                                            + hyperbolic-vectoring log)
                   ``cordic_pallas``        fused log-softmax kernel
=================  =======================  ===================================

All three CORDIC loss/softmax paths differentiate through output-derived
rules: activations via custom_jvp from the primal, the cross-entropy loss
via a custom_vjp whose backward is the analytic softmax-minus-onehot form
(repro.train.losses) — so training stability matches the exact baseline.
Wider-format evaluation (accuracy ladder) goes through
``functions.FORMAT_PROFILES["q2_14" | "q2_20" | "q2_29"]``.
"""
from repro.cordic_engine.schedule import (  # noqa: F401
    CIRC_ROTATION,
    CIRCULAR,
    HYP_ROTATION,
    HYP_VECTORING,
    HYPERBOLIC,
    LIN_ROTATION,
    LIN_VECTORING,
    LINEAR,
    MRSchedule,
    PAPER_SCHEDULE,
    R2_BASELINE_SCHEDULE,
    ROTATION,
    VECTORING,
    CordicSchedule,
    hyp_rotation_for,
    hyp_vectoring_for,
    lin_rotation_for,
    lin_vectoring_for,
    mr_schedule_for,
)
from repro.cordic_engine.core import (  # noqa: F401
    FixedConfig,
    PAPER_FIXED,
    rotate_f,
    rotate_q,
    sweep_f,
    sweep_q,
    vector_f,
    vector_q,
)
from repro.cordic_engine import functions  # noqa: F401
