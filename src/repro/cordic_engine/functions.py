"""CORDIC function library: transcendental-free evaluators derived from the
generalized engine, each as float-in/float-out with dyadic range reduction.

Every function comes in two datapaths mirroring the sigmoid pipeline:

    *_float  — the CORDIC algorithm in f32 (algorithmic error only),
    *_fixed  — bit-accurate Q2.14 core (paper-style 16-bit datapath) with
               float-only boundary ops (quantize/dequantize, dyadic 2^k
               scaling via exp2, frexp mantissa extraction).

Derivations (mode x direction -> function):

    hyperbolic rotation   cosh z, sinh z            ->  exp z = cosh + sinh
    hyperbolic vectoring  atanh(y/x)                ->  log m = 2 atanh((m-1)/(m+1))
    linear vectoring      y/x                       ->  divide, reciprocal
    linear rotation       y = x * z                 ->  multiply
    circular rotation     cos z, sin z

Range reduction:

    exp:    x = k ln2 + r, |r| <= ln2/2; e^x = 2^k (cosh r + sinh r)
    log:    x = m 2^p, m in [0.5, 1);   ln x = 2 atanh((m-1)/(m+1)) + p ln2
    divide: y/x = (m_y/m_x) 2^(p_y-p_x), mantissa ratio in (0.5, 2)
    multiply: a b = (m_a m_b) 2^(p_a+p_b), mantissa product in [0.25, 1)
    sincos: t = n (pi/2) + r, |r| <= pi/4; quadrant swap/negate by n mod 4

Composites: softplus = relu(x) + log(1 + exp(-|x|)); elu from exp;
erf via the exponential approximation erf(u)^2 ~ 1 - exp(-u^2 (4/pi + a u^2)
/ (1 + a u^2)) (a = 0.147, |err| < 2.5e-4), giving an erf-based GELU.

Differentiable wrappers (custom_jvp from the primal output, like the
sigmoid path) are installed by ``repro.core.activations.get_activation``;
the raw forwards here are deliberately jvp-free so callers can pick.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fp
from repro.cordic_engine import core as eng
from repro.cordic_engine.core import FixedConfig, PAPER_FIXED
from repro.cordic_engine.schedule import (
    CIRC_ROTATION,
    HYP_ROTATION,
    HYP_VECTORING,
    LIN_ROTATION,
    LIN_VECTORING,
    ROTATION,
    CordicSchedule,
    MRSchedule,
    hyp_rotation_for,
    hyp_vectoring_for,
    lin_vectoring_for,
    mr_schedule_for,
)

_LN2 = 0.6931471805599453
_HALF_PI = math.pi / 2.0
#: exp clamp: keeps 2^k inside normal f32 exponent range.
_EXP_CLIP = 80.0
_ERF_A = 0.147


# --------------------------------------------------------------------------
# Format profiles: a datapath format bundled with schedules sized to its
# resolution (the Q2.20/Q2.29 accuracy-study configurations)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FormatProfile:
    """Everything needed to run the function library at one Q format:
    the FixedConfig plus rotation/vectoring/division schedules whose
    iteration depth matches the format's fraction bits."""

    name: str
    cfg: FixedConfig
    rotation: CordicSchedule       # exp / cosh+sinh
    vectoring: CordicSchedule      # atanh / log
    division: CordicSchedule       # divide / reciprocal
    pipeline: MRSchedule           # bundled sigmoid/tanh schedule

    @classmethod
    def for_format(cls, name: str, fmt: fp.QFormat) -> "FormatProfile":
        fb = fmt.frac_bits
        return cls(name=name, cfg=FixedConfig(fmt=fmt),
                   rotation=hyp_rotation_for(fb),
                   vectoring=hyp_vectoring_for(fb),
                   division=lin_vectoring_for(fb),
                   pipeline=mr_schedule_for(fb))


#: The accuracy-study ladder: the paper's 16-bit format and two wider
#: internal formats (schedule depth grows with the fraction bits).
FORMAT_PROFILES = {
    "q2_14": FormatProfile.for_format("q2_14", fp.Q2_14),
    "q2_20": FormatProfile.for_format("q2_20", fp.Q2_20),
    "q2_29": FormatProfile.for_format("q2_29", fp.Q2_29),
}


def _f32(x):
    return jnp.asarray(x, jnp.float32) if not hasattr(x, "dtype") else x


# --------------------------------------------------------------------------
# exp (hyperbolic rotation: e^r = cosh r + sinh r)
# --------------------------------------------------------------------------
def coshsinh_fixed(r, sched: CordicSchedule = HYP_ROTATION,
                   cfg: FixedConfig = PAPER_FIXED, clamp: bool = True):
    """(cosh r, sinh r) for |r| <= 0.5 on the Q2.14 datapath."""
    if clamp:
        r = jnp.clip(r, -0.5, 0.5)
    rq = fp.quantize(r, cfg.fmt)
    c, s, _ = eng.rotate_q(rq, sched, cfg)
    return fp.dequantize(c, cfg.fmt), fp.dequantize(s, cfg.fmt)


def coshsinh_float(r, sched: CordicSchedule = HYP_ROTATION, clamp: bool = True):
    if clamp:
        r = jnp.clip(r, -0.5, 0.5)
    c, s, _ = eng.rotate_f(r, sched)
    return c, s


def exp_fixed(x, sched: CordicSchedule = HYP_ROTATION,
              cfg: FixedConfig = PAPER_FIXED):
    """e^x over (-80, 80): dyadic reduction + Q2.14 cosh+sinh core.

    The only non-shift-add ops are the boundary float multiply by 2^k and
    the quantize/dequantize — the TPU analogue of the paper's "zero DSP"
    datapath with a float wrapper.
    """
    x = jnp.clip(_f32(x), -_EXP_CLIP, _EXP_CLIP)
    k = jnp.round(x * np.float32(1.0 / _LN2))
    r = x - k * np.float32(_LN2)                       # |r| <= ln2/2 < 0.35
    rq = fp.quantize(r, cfg.fmt)
    c, s, _ = eng.rotate_q(rq, sched, cfg)
    eq = fp.add(c, s, cfg.fmt)                         # e^r in (0.70, 1.42)
    return fp.dequantize(eq, cfg.fmt) * jnp.exp2(k)


def exp_float(x, sched: CordicSchedule = HYP_ROTATION):
    x = jnp.clip(_f32(x), -_EXP_CLIP, _EXP_CLIP)
    k = jnp.round(x * np.float32(1.0 / _LN2))
    r = x - k * np.float32(_LN2)
    c, s, _ = eng.rotate_f(r, sched)
    return (c + s) * jnp.exp2(k)


# --------------------------------------------------------------------------
# atanh / log (hyperbolic vectoring)
# --------------------------------------------------------------------------
def atanh_fixed(t, sched: CordicSchedule = HYP_VECTORING,
                cfg: FixedConfig = PAPER_FIXED, clamp: bool = True):
    """atanh(t) for |t| <= 0.8 (clamped) via hyperbolic vectoring."""
    if clamp:
        t = jnp.clip(_f32(t), -0.8, 0.8)
    one = fp.quantize(jnp.ones_like(t), cfg.fmt)
    tq = fp.quantize(t, cfg.fmt)
    z = eng.vector_q(one, tq, sched, cfg)
    return fp.dequantize(z, cfg.zfmt)


def atanh_float(t, sched: CordicSchedule = HYP_VECTORING, clamp: bool = True):
    if clamp:
        t = jnp.clip(_f32(t), -0.8, 0.8)
    return eng.vector_f(jnp.ones_like(t), t, sched)


def log_fixed(x, sched: CordicSchedule = HYP_VECTORING,
              cfg: FixedConfig = PAPER_FIXED):
    """ln x for x > 0: mantissa/exponent split + atanh identity.

    x = m 2^p with m in [0.5, 1): ln x = 2 atanh((m-1)/(m+1)) + p ln2.
    The vectoring runs on (x0, y0) = (m+1, m-1) — both inside Q2.14 —
    so no division is ever materialized.
    """
    x = jnp.maximum(_f32(x), np.float32(1e-30))
    m, p = jnp.frexp(x)                                # m in [0.5, 1)
    num = fp.quantize(m - 1.0, cfg.fmt)                # in [-0.5, 0)
    den = fp.quantize(m + 1.0, cfg.fmt)                # in [1.5, 2)
    z = eng.vector_q(den, num, sched, cfg)
    at = fp.dequantize(z, cfg.zfmt)
    return 2.0 * at + p.astype(jnp.float32) * np.float32(_LN2)


def log_float(x, sched: CordicSchedule = HYP_VECTORING):
    x = jnp.maximum(_f32(x), np.float32(1e-30))
    m, p = jnp.frexp(x)
    at = eng.vector_f(m + 1.0, m - 1.0, sched)
    return 2.0 * at + p.astype(jnp.float32) * np.float32(_LN2)


# --------------------------------------------------------------------------
# division (linear vectoring)
# --------------------------------------------------------------------------
def divide_fixed(y, x, sched: CordicSchedule = LIN_VECTORING,
                 cfg: FixedConfig = PAPER_FIXED):
    """y/x for finite nonzero x via linear vectoring on frexp mantissas.

    The LVC z accumulator can only reach sum(2^-j) = 1 - 2^-14, so the
    mantissa ratio is normalized *below one*: with m_y, m_x in [0.5, 1),
    halve m_y exactly when m_y >= m_x (one compare + dyadic shift):

        y/x = ((m_y / 2^h) / m_x) 2^(p_y - p_x + h),  ratio in [0.5, 1)

    which keeps the truncation-bias-to-quotient amplification at its
    minimum. x == 0 or y == 0 returns 0 (sign(0) kills the quotient).
    """
    y, x = _f32(y), _f32(x)
    sign = jnp.sign(y) * jnp.sign(x)
    my, py = jnp.frexp(jnp.abs(y))
    mx, px = jnp.frexp(jnp.abs(x))
    h = (my >= mx).astype(jnp.int32)
    num = fp.quantize(jnp.where(h == 1, my * 0.5, my), cfg.fmt)
    den = fp.quantize(jnp.maximum(mx, np.float32(0.5)), cfg.fmt)
    z = eng.vector_q(den, num, sched, cfg)
    q = fp.dequantize(z, cfg.zfmt)
    return sign * q * jnp.exp2((py - px + h).astype(jnp.float32))


def divide_float(y, x, sched: CordicSchedule = LIN_VECTORING):
    y, x = _f32(y), _f32(x)
    sign = jnp.sign(y) * jnp.sign(x)
    my, py = jnp.frexp(jnp.abs(y))
    mx, px = jnp.frexp(jnp.abs(x))
    h = (my >= mx).astype(jnp.int32)
    q = eng.vector_f(jnp.maximum(mx, np.float32(0.5)),
                     jnp.where(h == 1, my * 0.5, my), sched)
    return sign * q * jnp.exp2((py - px + h).astype(jnp.float32))


def reciprocal_fixed(x, sched: CordicSchedule = LIN_VECTORING,
                     cfg: FixedConfig = PAPER_FIXED):
    return divide_fixed(jnp.ones_like(_f32(x)), x, sched, cfg)


def reciprocal_float(x, sched: CordicSchedule = LIN_VECTORING):
    return divide_float(jnp.ones_like(_f32(x)), x, sched)


# --------------------------------------------------------------------------
# multiplication (linear rotation)
# --------------------------------------------------------------------------
def multiply_fixed(a, b, sched: CordicSchedule = LIN_ROTATION,
                   cfg: FixedConfig = PAPER_FIXED):
    """a*b via linear rotation (y accumulates x * z0) on frexp mantissas.

    Both operands reduce to m 2^p with m in [0.5, 1): the multiplicand
    mantissa sits in the (linear-mode constant) x register, the multiplier
    mantissa is the rotation angle z0 — inside the schedule's convergence
    range sum(2^-j) = 1 - 2^-14 — and the product m_a m_b in [0.25, 1)
    lands inside Q2.14 with no overflow:

        a b = (m_a m_b) 2^(p_a + p_b)

    The only non-shift-add ops are the frexp/exp2 boundary, exactly like
    divide. A zero operand returns 0 (sign(0) kills the product).
    """
    a, b = jnp.broadcast_arrays(_f32(a), _f32(b))
    sign = jnp.sign(a) * jnp.sign(b)
    ma, pa = jnp.frexp(jnp.abs(a))
    mb, pb = jnp.frexp(jnp.abs(b))
    xq = fp.quantize(jnp.maximum(ma, np.float32(0.5)), cfg.fmt)
    zq = fp.quantize(jnp.maximum(mb, np.float32(0.5)), cfg.zfmt)
    _, y, _ = eng.sweep_q(xq, jnp.zeros_like(xq), zq, sched, ROTATION, cfg)
    prod = fp.dequantize(y, cfg.fmt)
    return sign * prod * jnp.exp2((pa + pb).astype(jnp.float32))


def multiply_float(a, b, sched: CordicSchedule = LIN_ROTATION):
    a, b = jnp.broadcast_arrays(_f32(a), _f32(b))
    sign = jnp.sign(a) * jnp.sign(b)
    ma, pa = jnp.frexp(jnp.abs(a))
    mb, pb = jnp.frexp(jnp.abs(b))
    _, y, _ = eng.sweep_f(jnp.maximum(ma, np.float32(0.5)),
                          jnp.zeros_like(ma),
                          jnp.maximum(mb, np.float32(0.5)), sched, ROTATION)
    return sign * y * jnp.exp2((pa + pb).astype(jnp.float32))


# --------------------------------------------------------------------------
# sin / cos (circular rotation)
# --------------------------------------------------------------------------
def _quadrant_fix(c, s, quad):
    cos = jnp.select([quad == 0, quad == 1, quad == 2], [c, -s, -c], s)
    sin = jnp.select([quad == 0, quad == 1, quad == 2], [s, c, -s], -c)
    return sin, cos


def sincos_fixed(t, sched: CordicSchedule = CIRC_ROTATION,
                 cfg: FixedConfig = PAPER_FIXED):
    """(sin t, cos t): reduce to |r| <= pi/4, rotate, quadrant-correct."""
    t = _f32(t)
    n = jnp.round(t * np.float32(1.0 / _HALF_PI))
    r = t - n * np.float32(_HALF_PI)
    quad = jnp.mod(n, 4.0).astype(jnp.int32)
    rq = fp.quantize(r, cfg.fmt)
    c, s, _ = eng.rotate_q(rq, sched, cfg)
    return _quadrant_fix(fp.dequantize(c, cfg.fmt), fp.dequantize(s, cfg.fmt), quad)


def sincos_float(t, sched: CordicSchedule = CIRC_ROTATION):
    t = _f32(t)
    n = jnp.round(t * np.float32(1.0 / _HALF_PI))
    r = t - n * np.float32(_HALF_PI)
    quad = jnp.mod(n, 4.0).astype(jnp.int32)
    c, s, _ = eng.rotate_f(r, sched)
    return _quadrant_fix(c, s, quad)


def sin_fixed(t, cfg: FixedConfig = PAPER_FIXED):
    return sincos_fixed(t, cfg=cfg)[0]


def cos_fixed(t, cfg: FixedConfig = PAPER_FIXED):
    return sincos_fixed(t, cfg=cfg)[1]


def sin_float(t):
    return sincos_float(t)[0]


def cos_float(t):
    return sincos_float(t)[1]


# --------------------------------------------------------------------------
# Composite activations
# --------------------------------------------------------------------------
def softplus_fixed(x, cfg: FixedConfig = PAPER_FIXED):
    """log(1 + e^x) = relu(x) + log(1 + e^-|x|) — both CORDIC legs."""
    x = _f32(x)
    e = exp_fixed(-jnp.abs(x), cfg=cfg)                # in (0, 1]
    return jnp.maximum(x, 0.0) + log_fixed(1.0 + e, cfg=cfg)


def softplus_float(x):
    x = _f32(x)
    e = exp_float(-jnp.abs(x))
    return jnp.maximum(x, 0.0) + log_float(1.0 + e)


def elu_fixed(x, alpha: float = 1.0, cfg: FixedConfig = PAPER_FIXED):
    x = _f32(x)
    em1 = exp_fixed(jnp.minimum(x, 0.0), cfg=cfg) - 1.0
    return jnp.where(x > 0, x, np.float32(alpha) * em1)


def elu_float(x, alpha: float = 1.0):
    x = _f32(x)
    em1 = exp_float(jnp.minimum(x, 0.0)) - 1.0
    return jnp.where(x > 0, x, np.float32(alpha) * em1)


def _erf_from_exp(u, exp_fn):
    """Exponential erf approximation (|err| < 2.5e-4); sqrt is a boundary op."""
    u = _f32(u)
    u2 = u * u
    g = u2 * (np.float32(4.0 / math.pi) + np.float32(_ERF_A) * u2) \
        / (1.0 + np.float32(_ERF_A) * u2)
    return jnp.sign(u) * jnp.sqrt(jnp.maximum(1.0 - exp_fn(-g), 0.0))


def erf_fixed(u, cfg: FixedConfig = PAPER_FIXED):
    return _erf_from_exp(u, lambda v: exp_fixed(v, cfg=cfg))


def erf_float(u):
    return _erf_from_exp(u, exp_float)


def gelu_erf_fixed(x, cfg: FixedConfig = PAPER_FIXED):
    """Exact-form GELU 0.5 x (1 + erf(x/sqrt2)) with CORDIC-exp erf."""
    x = _f32(x)
    return 0.5 * x * (1.0 + erf_fixed(x * np.float32(1.0 / math.sqrt(2.0)), cfg))


def gelu_erf_float(x):
    x = _f32(x)
    return 0.5 * x * (1.0 + erf_float(x * np.float32(1.0 / math.sqrt(2.0))))


# --------------------------------------------------------------------------
# softmax (CORDIC exp + linear-vectoring normalization) — jnp reference for
# the fused Pallas kernel in repro.kernels.softmax_cordic
# --------------------------------------------------------------------------
def softmax_fixed(x, axis: int = -1, cfg: FixedConfig = PAPER_FIXED):
    """softmax along `axis`: max-subtract, CORDIC exp, LVC division.

    Fully masked lanes (<= -1e30 after max-subtract) decay to 0 through the
    exp clamp, matching jax.nn.softmax on padded attention rows up to the
    engine's ~1e-3 pointwise error. Raw forward — differentiating through
    the quantize/frexp boundary ops gives garbage; use ``softmax`` below.
    """
    x = _f32(x)
    u = x - jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = exp_fixed(u, cfg=cfg)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return divide_fixed(e, s, cfg=cfg)


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def softmax(x, axis: int = -1):
    """Differentiable CORDIC softmax (jnp fixed path): the analytic softmax
    tangent dy = y*(dx - sum(y dx)) from the primal output, like the
    sigmoid/tanh activation wrappers."""
    return softmax_fixed(x, axis=axis)


@softmax.defjvp
def _softmax_jvp(axis, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = softmax(x, axis)
    return y, y * (dx - jnp.sum(y * dx, axis=axis, keepdims=True))


# --------------------------------------------------------------------------
# log-softmax (CORDIC exp for the sum + hyperbolic-vectoring log leg) —
# jnp reference for the fused Pallas kernel in repro.kernels.softmax_cordic
# and the datapath behind the cfg.loss_impl="cordic" training loss.
# --------------------------------------------------------------------------
def log_softmax_fixed(x, axis: int = -1, cfg: FixedConfig = PAPER_FIXED):
    """log-softmax along `axis`: max-subtract, CORDIC exp, CORDIC log.

        u_i = x_i - max(x)
        y_i = u_i - ln(sum_j e^{u_j})

    The sum's log runs through the engine's hyperbolic-vectoring leg
    (ln S = 2 atanh((m-1)/(m+1)) + p ln2 on the frexp mantissa) — the same
    shift-add core as atanh, no transcendental. The subtraction u_i - ln S
    is a float boundary op, exactly like the dyadic 2^k scaling in exp.

    Raw forward — use ``log_softmax`` below for a differentiable wrapper.
    """
    x = _f32(x)
    u = x - jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = exp_fixed(u, cfg=cfg)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return u - log_fixed(s, cfg=cfg)


def log_softmax_float(x, axis: int = -1):
    """Float-datapath CORDIC log-softmax (algorithmic error only)."""
    x = _f32(x)
    u = x - jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = exp_float(u)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return u - log_float(s)


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def log_softmax(x, axis: int = -1):
    """Differentiable CORDIC log-softmax (jnp fixed path): the analytic
    tangent dy = dx - p * sum(dx) with p = exp(y) from the primal output."""
    return log_softmax_fixed(x, axis=axis)


@log_softmax.defjvp
def _log_softmax_jvp(axis, primals, tangents):
    (x,), (dx,) = primals, tangents
    y = log_softmax(x, axis)
    p = jnp.exp(y)
    return y, dx - jnp.sum(p * dx, axis=axis, keepdims=True)
