"""Mode-parameterized CORDIC core: one iteration engine for all six
(mode x direction) combinations, in float and bit-accurate fixed point.

The unified iteration (direction factor e, mode factor m_x):

    x' = x + m_x * e * y * 2^-j        m_x = -1 circular, 0 linear, +1 hyperbolic
    y' = y +       e * x * 2^-j
    z' = z -       e * alpha_j(mode)

    rotation:   e = sign(z)   (drive z -> 0; rotates (x, y) by z0)
    vectoring:  e = -sign(y)  (drive y -> 0; accumulates z += f(y0/x0))

Specialized to (hyperbolic, rotation) + (linear, vectoring) with the paper's
schedules, the fixed-point sweeps below are *op-for-op identical* to the
seed implementation that used to live in ``repro.core.cordic`` (same shift
order, same where/add/sub structure, same ROM quantization) — so the paper
pipeline built on top of this engine is bit-identical to the original,
enforced over all 2^16 input codes in tests/test_cordic_engine.py.

Fixed-point sweeps carry values in int32 lanes masked to ``cfg.fmt`` after
every op (see repro.core.fixed_point); the z/angle register may be widened
by ``cfg.z_guard`` fraction bits.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fp
from repro.core.fixed_point import Q2_14, QFormat
from repro.cordic_engine import schedule as sch
from repro.cordic_engine.schedule import (
    CIRCULAR,
    HYPERBOLIC,
    LINEAR,
    ROTATION,
    VECTORING,
    CordicSchedule,
    angle_r2,
    angle_r4,
)


# --------------------------------------------------------------------------
# Datapath quantization config (moved verbatim from core/cordic.py)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FixedConfig:
    """Datapath quantization config.

    ``fmt``        — x/y register format (the paper's 16-bit Q2.14).
    ``z_guard``    — extra fraction bits on the z (angle) register. 0 keeps
                     the strict 16-bit paper datapath; a few guard bits on the
                     angle accumulator is a standard, cheap HW refinement
                     (one slightly wider adder) studied in the accuracy bench.
    ``shift_round``— rounding of datapath right-shifts: "trunc" is what a
                     plain two's-complement `>>` does (the paper's adder-only
                     datapath); "nearest" costs one extra adder per stage.
    ``out_round``  — rounding of the final output requantization.
    """

    fmt: QFormat = Q2_14
    z_guard: int = 0
    shift_round: str = "trunc"
    out_round: str = "nearest"

    @property
    def zfmt(self) -> QFormat:
        if self.z_guard == 0:
            return self.fmt
        return QFormat(
            total_bits=self.fmt.total_bits + self.z_guard,
            frac_bits=self.fmt.frac_bits + self.z_guard,
        )


PAPER_FIXED = FixedConfig()


# --------------------------------------------------------------------------
# Float sweeps
# --------------------------------------------------------------------------
def radix2_sweep_f(x, y, z, js, mode: str, direction: str):
    """Generic radix-2 CORDIC iterations in float. Returns (x, y, z)."""
    for j in js:
        a = angle_r2(mode, j)
        f = 2.0 ** (-j)
        if direction == ROTATION:
            e = jnp.where(z >= 0, 1.0, -1.0).astype(y.dtype)
        else:
            e = jnp.where(y >= 0, -1.0, 1.0).astype(y.dtype)
        if mode == HYPERBOLIC:
            x_n = x + e * y * f
        elif mode == CIRCULAR:
            x_n = x - e * y * f
        else:
            x_n = x
        x, y, z = x_n, y + e * x * f, z - e * a
    return x, y, z


def _r4_digit_f(z, j):
    """SRT-style radix-4 digit selection on w = 4^j z (paper eq. (8))."""
    w = z * (4.0 ** j)
    return jnp.where(
        w >= 1.5, 2.0,
        jnp.where(w >= 0.5, 1.0, jnp.where(w >= -0.5, 0.0, jnp.where(w >= -1.5, -1.0, -2.0))),
    ).astype(z.dtype)


def radix4_sweep_f(x, y, z, js, mode: str = HYPERBOLIC, direction: str = ROTATION):
    """Radix-4 hyperbolic rotation iterations, digit set {-2,-1,0,1,2}.

    Started at j>=4 the cumulative gain is within 2^-14 of 1 (scale-free).
    """
    if mode != HYPERBOLIC or direction != ROTATION:
        raise NotImplementedError("radix-4 sweep: hyperbolic rotation only")
    for j in js:
        s = _r4_digit_f(z, j)
        mag = jnp.abs(s)
        # atanh(s*4^-j) for s in {-2..2}; exploit oddness.
        a = jnp.sign(s) * jnp.where(
            mag == 2.0, angle_r4(mode, j, 2), jnp.where(mag == 1.0, angle_r4(mode, j, 1), 0.0)
        ).astype(z.dtype)
        f = s * (4.0 ** (-j))
        x, y, z = x + f * y, y + f * x, z - a
    return x, y, z


def sweep_f(x, y, z, sched: CordicSchedule, direction: str):
    """Full float sweep: radix-2 stage then (hyperbolic-only) radix-4 tail."""
    x, y, z = radix2_sweep_f(x, y, z, sched.r2_js, sched.mode, direction)
    if sched.r4_js:
        x, y, z = radix4_sweep_f(x, y, z, sched.r4_js, sched.mode, direction)
    return x, y, z


# --------------------------------------------------------------------------
# Fixed-point sweeps
# --------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _q_angles_r2(mode: str, js: tuple, zfmt: QFormat):
    """Pre-quantized radix-2 angle ROM in the z format.

    Linear mode uses the exact power-of-two step the hardware would wire
    (`1 << (frac - j)`, floored at 1) — identical to the seed R2-LVC."""
    if mode == LINEAR:
        return tuple(np.int32(1 << max(zfmt.frac_bits - j, 0)) for j in js)
    return tuple(fp.const(angle_r2(mode, j), zfmt) for j in js)


@lru_cache(maxsize=None)
def _q_r4_consts(mode: str, js: tuple, zfmt: QFormat):
    """Radix-4 ROM: atanh tables + SRT digit-selection thresholds."""
    a1 = tuple(fp.const(angle_r4(mode, j, 1), zfmt) for j in js)
    a2 = tuple(fp.const(angle_r4(mode, j, 2), zfmt) for j in js)
    thr05 = tuple(fp.const(0.5 * 4.0 ** (-j), zfmt) for j in js)
    thr15 = tuple(fp.const(1.5 * 4.0 ** (-j), zfmt) for j in js)
    return a1, a2, thr05, thr15


def radix2_sweep_q(x, y, z, js, mode: str, direction: str, cfg: FixedConfig):
    """Generic radix-2 fixed-point sweep. x/y in cfg.fmt, z in cfg.zfmt."""
    f, zf, rnd = cfg.fmt, cfg.zfmt, cfg.shift_round
    angles = _q_angles_r2(mode, tuple(js), zf)
    for i, j in enumerate(js):
        a = angles[i]
        # `plus` selects the e = +1 branch of the unified iteration.
        plus = (z >= 0) if direction == ROTATION else (y < 0)
        xs = fp.shr(x, j, f, rounding=rnd)
        if mode != LINEAR:
            ys = fp.shr(y, j, f, rounding=rnd)
            if mode == HYPERBOLIC:
                x_n = jnp.where(plus, fp.add(x, ys, f), fp.sub(x, ys, f))
            else:
                x_n = jnp.where(plus, fp.sub(x, ys, f), fp.add(x, ys, f))
        else:
            x_n = x
        y_n = jnp.where(plus, fp.add(y, xs, f), fp.sub(y, xs, f))
        z = jnp.where(plus, fp.sub(z, a, zf), fp.add(z, a, zf))
        x, y = x_n, y_n
    return x, y, z


def radix4_sweep_q(x, y, z, js, mode: str, direction: str, cfg: FixedConfig):
    """Fixed-point radix-4 hyperbolic rotation with SRT digit selection.

    The digit compare is done directly on z against pre-scaled thresholds
    (0.5*4^-j, 1.5*4^-j) — equivalent to comparing 4^j z against +-0.5/+-1.5
    but without the left shift that could overflow the 16-bit register.
    """
    if mode != HYPERBOLIC or direction != ROTATION:
        raise NotImplementedError("radix-4 sweep: hyperbolic rotation only")
    f, zf, rnd = cfg.fmt, cfg.zfmt, cfg.shift_round
    a1s, a2s, t05s, t15s = _q_r4_consts(mode, tuple(js), zf)
    for i, j in enumerate(js):
        t05, t15 = t05s[i], t15s[i]
        a1, a2 = a1s[i], a2s[i]
        # sigma in {-2,-1,0,1,2}
        mag2 = (z >= t15) | (z < -t15)                    # |sigma| == 2
        mag0 = (z < t05) & (z >= -t05)                    # sigma == 0
        pos = z >= 0
        # |sigma|*4^-j multiplies => shift by 2j (|s|=1) or 2j-1 (|s|=2).
        xs1 = fp.shr(x, 2 * j, f, rounding=rnd)
        ys1 = fp.shr(y, 2 * j, f, rounding=rnd)
        xs2 = fp.shr(x, 2 * j - 1, f, rounding=rnd)
        ys2 = fp.shr(y, 2 * j - 1, f, rounding=rnd)
        dx = jnp.where(mag0, 0, jnp.where(mag2, ys2, ys1))
        dy = jnp.where(mag0, 0, jnp.where(mag2, xs2, xs1))
        da = jnp.where(mag0, 0, jnp.where(mag2, a2, a1))
        x = jnp.where(pos, fp.add(x, dx, f), fp.sub(x, dx, f))
        y = jnp.where(pos, fp.add(y, dy, f), fp.sub(y, dy, f))
        z = jnp.where(pos, fp.sub(z, da, zf), fp.add(z, da, zf))
    return x, y, z


def sweep_q(x, y, z, sched: CordicSchedule, direction: str, cfg: FixedConfig):
    """Full fixed-point sweep: radix-2 then (hyperbolic-only) radix-4 tail."""
    x, y, z = radix2_sweep_q(x, y, z, sched.r2_js, sched.mode, direction, cfg)
    if sched.r4_js:
        x, y, z = radix4_sweep_q(x, y, z, sched.r4_js, sched.mode, direction, cfg)
    return x, y, z


# --------------------------------------------------------------------------
# Canonical entry points (unit starts, guard-bit handling)
# --------------------------------------------------------------------------
def rotate_q(z_q, sched: CordicSchedule, cfg: FixedConfig = PAPER_FIXED):
    """Rotation from the gain-folded unit start: x0 = 1/K, y0 = 0.

    ``z_q`` is the angle in cfg.fmt codes. Returns (x, y, residual-z) with
    x/y in cfg.fmt codes and z in cfg.zfmt codes:
        hyperbolic: (cosh z, sinh z)   circular: (cos z, sin z)
    """
    x = jnp.full_like(z_q, jnp.int32(fp.const(sched.x0, cfg.fmt)))
    y = jnp.zeros_like(z_q)
    z = z_q << cfg.z_guard if cfg.z_guard else z_q  # extend angle register
    return sweep_q(x, y, z, sched, ROTATION, cfg)


def vector_q(x_q, y_q, sched: CordicSchedule, cfg: FixedConfig = PAPER_FIXED):
    """Vectoring from (x_q, y_q): drives y -> 0, returns the z accumulator
    in cfg.zfmt codes (linear: y0/x0; hyperbolic: atanh(y0/x0))."""
    z = jnp.zeros_like(y_q)
    _, _, z = sweep_q(x_q, y_q, z, sched, VECTORING, cfg)
    return z


def rotate_f(z, sched: CordicSchedule):
    """Float rotation from the unit start. Returns (x, y, residual)."""
    x = jnp.full_like(z, sched.x0)
    y = jnp.zeros_like(z)
    return sweep_f(x, y, z, sched, ROTATION)


def vector_f(x, y, sched: CordicSchedule):
    """Float vectoring: returns the accumulated z (y driven to 0)."""
    z = jnp.zeros_like(y)
    _, _, z = sweep_f(x, y, z, sched, VECTORING)
    return z
