"""Host-side metrics registry: counters, gauges, log-bucketed histograms.

Everything here is plain-Python host state — no jax arrays, no tracing, no
device transfers. Metric objects are created lazily through a
``MetricsRegistry`` (get-or-create by name, type-checked) and read back as
a JSON-serializable snapshot, so a serving process can expose its whole
observability surface with one ``registry.snapshot()`` call.

``Histogram`` is log-bucketed: observations land in geometric buckets
``(lo*g^(k-1), lo*g^k]`` with growth factor ``g`` (default 1.07), so a
quantile readout is accurate to ~``sqrt(g)-1`` (≈3.5%) relative error at
O(1) memory per decade regardless of sample count — the standard latency-
histogram trade (HdrHistogram / Prometheus style). Count/sum/min/max are
tracked exactly; ``quantile(q)`` walks the cumulative bucket counts and
returns the geometric midpoint of the target bucket, clamped to the exact
observed [min, max].

The ``NULL_REGISTRY`` singleton implements the same surface as no-ops, so
instrumented code paths can write ``registry.counter(name).inc()``
unconditionally and stay off-by-default-cheap (one attribute call, no
branching, no clock reads) when observability is disabled.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Tuple


class Counter:
    """Monotonically increasing count (events, tokens, clipped elements)."""

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "unit": self.unit, "value": self.value}


class Gauge:
    """Point-in-time level (queue depth, pool occupancy); tracks the
    high-water mark since construction alongside the last set value."""

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.last: float = 0.0
        self.peak: float = float("-inf")
        self._sum = 0.0
        self._n = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.last = v
        if v > self.peak:
            self.peak = v
        self._sum += v
        self._n += 1

    @property
    def mean(self) -> float:
        """Mean over every set() call (an *event*-weighted mean, not a
        time-weighted one — callers that set once per engine step get a
        per-step mean)."""
        return self._sum / self._n if self._n else 0.0

    def snapshot(self) -> dict:
        return {"type": "gauge", "unit": self.unit, "last": self.last,
                "peak": self.peak if self._n else 0.0,
                "mean": self.mean, "sets": self._n}


class Histogram:
    """Log-bucketed distribution with quantile readout.

    ``lo`` is the resolution floor: every observation <= lo (including 0
    and any stray negative) lands in bucket 0, so the default 1e-3 keeps
    sub-microsecond jitter from minting thousands of useless buckets when
    observing milliseconds.
    """

    #: quantiles included in snapshot()
    SNAPSHOT_QS = (0.50, 0.90, 0.99)

    def __init__(self, name: str, unit: str = "", growth: float = 1.07,
                 lo: float = 1e-3):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if lo <= 0.0:
            raise ValueError(f"lo must be positive, got {lo}")
        self.name = name
        self.unit = unit
        self.growth = growth
        self.lo = lo
        self._lg = math.log(growth)
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        # bucket k covers (lo * g^(k-1), lo * g^k]
        return max(1, math.ceil(math.log(v / self.lo) / self._lg - 1e-12))

    def _midpoint(self, idx: int) -> float:
        if idx == 0:
            return self.lo
        return self.lo * self.growth ** (idx - 0.5)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        idx = self._index(v)
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]: cumulative walk over the
        sorted buckets, geometric bucket midpoint, clamped to the exactly
        tracked [min, max]. NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return float("nan")
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        target = q * self.count
        seen = 0
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if seen >= target:
                if idx == 0:
                    # the underflow bucket spans (-inf, lo]; min is the
                    # only exact statistic available for it
                    return self.min
                return min(max(self._midpoint(idx), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def snapshot(self) -> dict:
        out = {"type": "histogram", "unit": self.unit, "count": self.count,
               "sum": self.sum,
               "min": self.min if self.count else None,
               "max": self.max if self.count else None,
               "mean": self.mean if self.count else None}
        for q in self.SNAPSHOT_QS:
            out[f"p{int(q * 100)}"] = (self.quantile(q) if self.count
                                       else None)
        return out


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Re-requesting a name returns the existing instance; requesting it as a
    different type raises (a silently shadowed metric is a lost metric).
    Thread-safe at registration granularity — individual metric updates are
    plain attribute writes under the GIL, which is the precision host-side
    serving telemetry needs.
    """

    enabled = True

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get(name, Counter, unit=unit)

    def gauge(self, name: str, unit: str = "") -> Gauge:
        return self._get(name, Gauge, unit=unit)

    def histogram(self, name: str, unit: str = "", growth: float = 1.07,
                  lo: float = 1e-3) -> Histogram:
        return self._get(name, Histogram, unit=unit, growth=growth, lo=lo)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str):
        """The registered metric, or None (read-only lookup)."""
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """JSON-serializable view of every registered metric."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"metrics": self.snapshot()}, f, indent=2,
                      sort_keys=True)


# --------------------------------------------------------------------------
# Null (disabled) implementations — the off-by-default path. One shared
# instance per type: no allocation, no clock reads, no dict lookups on the
# hot path beyond the registry call itself.
# --------------------------------------------------------------------------
class _NullCounter:
    name = unit = ""
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


class _NullGauge:
    name = unit = ""
    last = peak = mean = 0.0

    def set(self, v: float) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


class _NullHistogram:
    name = unit = ""
    count = 0
    sum = 0.0
    min = max = mean = float("nan")

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return float("nan")

    def snapshot(self) -> dict:
        return {}


class _NullRegistry:
    enabled = False
    _COUNTER = _NullCounter()
    _GAUGE = _NullGauge()
    _HISTOGRAM = _NullHistogram()

    def counter(self, name: str, unit: str = "") -> _NullCounter:
        return self._COUNTER

    def gauge(self, name: str, unit: str = "") -> _NullGauge:
        return self._GAUGE

    def histogram(self, name: str, unit: str = "", growth: float = 1.07,
                  lo: float = 1e-3) -> _NullHistogram:
        return self._HISTOGRAM

    def names(self) -> List[str]:
        return []

    def get(self, name: str):
        return None

    def snapshot(self) -> dict:
        return {}

    def to_json(self, path: str) -> None:
        raise RuntimeError("cannot export the null registry; construct a "
                           "real Observability/MetricsRegistry first")


#: Shared disabled registry — what un-instrumented engines write into.
NULL_REGISTRY = _NullRegistry()
