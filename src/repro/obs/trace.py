"""Chrome-trace (Perfetto-loadable) event recorder for the serving path.

Records two families of events against one monotonic clock:

* **request lifecycle** — per-request instants/spans on a per-request
  track: ``enqueue`` → ``admit`` → ``prefill`` (span) → ``first_token`` →
  one ``token`` instant per decode step → ``finish``;
* **engine phases** — per-``step()`` spans on the shared engine track:
  ``admit`` / ``dispatch`` / ``host_sync`` / ``sample_copy``, plus
  ``compile`` instants and ``queue_depth`` / ``batch_occupancy`` counter
  tracks.

Export follows the Trace Event Format JSON-object flavor
(``{"traceEvents": [...], "displayTimeUnit": "ms"}``) with ``ph`` in
{"X" complete, "i" instant, "C" counter, "M" metadata}: timestamps are
microseconds, every logical track gets an integer ``tid`` plus a
``thread_name`` metadata record, so ``ui.perfetto.dev`` (or
``chrome://tracing``) loads the file directly and shows one lane per
request under the engine lanes.

Recording is append-to-a-Python-list cheap and entirely host-side; the
recorder never touches jax. Construct it through
``repro.obs.Observability(trace=True)`` so all timestamps share the
observability clock origin.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

#: the single process id all serve-engine tracks live under
_PID = 1
#: ph values this recorder emits (the schema tests pin this set)
PHASES = ("X", "i", "C", "M")


class TraceRecorder:
    """Append-only Chrome-trace event buffer with named logical tracks."""

    def __init__(self, process_name: str = "serve-engine"):
        self.events: List[dict] = []
        self._tids: Dict[str, int] = {}
        self.events.append({
            "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
            "ts": 0.0, "args": {"name": process_name},
        })

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids)
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                "ts": 0.0, "args": {"name": track},
            })
        return tid

    # -- event emitters -----------------------------------------------------
    def complete(self, name: str, ts_us: float, dur_us: float,
                 track: str = "engine",
                 args: Optional[dict] = None) -> None:
        """A span: ``ph="X"`` with explicit duration (both microseconds)."""
        ev = {"name": name, "ph": "X", "pid": _PID, "tid": self._tid(track),
              "ts": float(ts_us), "dur": max(0.0, float(dur_us))}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_us: float, track: str = "engine",
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": "i", "pid": _PID,
              "tid": self._tid(track), "ts": float(ts_us), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_us: float, values: Dict[str, float],
                track: str = "engine") -> None:
        """A counter-track sample (``ph="C"``): Perfetto renders one
        stacked area lane per key in ``values``."""
        self.events.append({
            "name": name, "ph": "C", "pid": _PID, "tid": self._tid(track),
            "ts": float(ts_us),
            "args": {k: float(v) for k, v in values.items()},
        })

    # -- export -------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    # -- readback (tests / analysis) ----------------------------------------
    def track_events(self, track: str) -> List[dict]:
        """Non-metadata events on one named track, in recording order."""
        tid = self._tids.get(track)
        if tid is None:
            return []
        return [e for e in self.events
                if e["tid"] == tid and e["ph"] != "M"]


def validate_chrome_trace(doc: dict) -> None:
    """Raise ValueError unless ``doc`` is a well-formed JSON-object-flavor
    Chrome trace as this module emits it (the schema the tests and the CI
    artifact gate rely on): a ``traceEvents`` list whose entries carry
    name/ph/ts/pid/tid, ``ph`` drawn from the emitted set, non-negative
    ``dur`` on complete events, and JSON-serializable throughout."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(evs):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing {key!r}: {ev}")
        if ev["ph"] not in PHASES:
            raise ValueError(f"event {i} has unknown ph {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} ts must be a non-negative number")
        if ev["ph"] == "X" and (not isinstance(ev.get("dur"), (int, float))
                                or ev["dur"] < 0):
            raise ValueError(f"complete event {i} needs non-negative dur")
        if ev["ph"] == "C" and not isinstance(ev.get("args"), dict):
            raise ValueError(f"counter event {i} needs an args dict")
    json.dumps(doc)  # serializability is part of the contract
