"""Serving observability: metrics registry, request-lifecycle tracing, and
fixed-point saturation accounting — all host-side, all off by default.

``Observability`` is the handle an engine (or benchmark) is constructed
with. It bundles

* ``metrics`` — a :class:`repro.obs.metrics.MetricsRegistry` of counters /
  gauges / log-bucketed histograms with p50/p90/p99 readout and JSON
  snapshot export;
* ``trace`` — an optional :class:`repro.obs.trace.TraceRecorder` emitting
  Chrome-trace (Perfetto-loadable) request-lifecycle and engine-phase
  events against the same monotonic clock;
* ``phase(name)`` — a context manager timing one engine phase into both
  (histogram ``engine.phase.<name>_ms`` + an "X" span on the engine
  track).

``NULL`` is the disabled singleton: identical surface, no clock reads, no
allocation — instrumented code writes through it unconditionally, which is
what keeps observability *off-by-default-cheap* and the emitted tokens
bit-identical with observability on or off (nothing here ever touches jax
or a traced value; see tests/test_obs.py for the enforced contract).

Saturation accounting closes the loop with the paper's overflow-free-Q2.14
claim: :func:`repro.core.fixed_point.set_saturation_observer` feeds every
*eager* quantize clip into the registry (tracer inputs are skipped — no
metric state is ever traced into a jitted function), and
:func:`saturation_audit` sweeps named tensors across the
``FORMAT_PROFILES`` ladder to report would-clip counts per format.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_REGISTRY)
from repro.obs.trace import TraceRecorder, validate_chrome_trace

__all__ = [
    "Observability", "NULL", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_REGISTRY", "TraceRecorder",
    "validate_chrome_trace", "observe_saturation", "saturation_audit",
]


class _PhaseSpan:
    """Times one engine phase into a histogram and (optionally) the trace.
    Re-entered per use; allocation-free reuse is not worth the aliasing
    risk at one object per phase per step."""

    __slots__ = ("_obs", "_name", "_hist", "_t0")

    def __init__(self, obs: "Observability", name: str, hist):
        self._obs = obs
        self._name = name
        self._hist = hist

    def __enter__(self):
        self._t0 = self._obs.now()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = self._obs.now()
        dt = t1 - self._t0
        self._hist.observe(dt * 1e3)
        if self._obs.trace is not None:
            self._obs.trace.complete(self._name, self._t0 * 1e6, dt * 1e6)
        return False


class Observability:
    """Live observability handle: a metrics registry + optional tracer
    sharing one clock origin (``now()`` is seconds since construction)."""

    enabled = True

    def __init__(self, *, trace: bool = False,
                 process_name: str = "serve-engine"):
        self._clock = time.perf_counter
        self._t0 = self._clock()
        self.metrics = MetricsRegistry()
        self.trace: Optional[TraceRecorder] = (
            TraceRecorder(process_name) if trace else None)

    @property
    def epoch(self) -> float:
        """Absolute ``time.perf_counter()`` value of this handle's clock
        origin. The engine stamps request lifecycle times with the absolute
        clock (so stamps survive a later attach_obs); ``t_abs - epoch``
        converts one to this handle's trace timeline."""
        return self._t0

    def now(self) -> float:
        """Seconds since this handle was constructed (monotonic)."""
        return self._clock() - self._t0

    def now_us(self) -> float:
        return self.now() * 1e6

    def phase(self, name: str) -> _PhaseSpan:
        """``with obs.phase("dispatch"): ...`` — records the wall time into
        histogram ``engine.phase.<name>_ms`` and an engine-track span."""
        return _PhaseSpan(
            self, name,
            self.metrics.histogram(f"engine.phase.{name}_ms", unit="ms"))

    def request_event(self, stage: str, rid: int,
                      args: Optional[dict] = None) -> None:
        """Lifecycle instant on the request's own trace track (no-op
        without tracing; the metric side of lifecycle events lives in the
        engine's histograms)."""
        if self.trace is not None:
            self.trace.instant(stage, self.now_us(), track=f"req {rid}",
                               args=args)

    def request_span(self, stage: str, rid: int, t0_s: float,
                     args: Optional[dict] = None) -> None:
        """Lifecycle span [t0_s, now] on the request's trace track."""
        if self.trace is not None:
            self.trace.complete(stage, t0_s * 1e6,
                                (self.now() - t0_s) * 1e6,
                                track=f"req {rid}", args=args)


class _NullPhase:
    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_PHASE = _NullPhase()


class _NullObservability:
    """Disabled observability: same surface, zero work. ``metrics`` is the
    shared null registry, ``trace`` is None, clocks read 0.0."""

    enabled = False
    metrics = NULL_REGISTRY
    trace = None
    epoch = 0.0

    def now(self) -> float:
        return 0.0

    def now_us(self) -> float:
        return 0.0

    def phase(self, name: str) -> _NullPhase:
        return _NULL_PHASE

    def request_event(self, stage: str, rid: int,
                      args: Optional[dict] = None) -> None:
        pass

    def request_span(self, stage: str, rid: int, t0_s: float,
                     args: Optional[dict] = None) -> None:
        pass


#: Shared disabled handle; `ServeEngine(obs=None)` resolves to this.
NULL = _NullObservability()


# --------------------------------------------------------------------------
# Fixed-point saturation accounting
# --------------------------------------------------------------------------
@contextlib.contextmanager
def observe_saturation(registry: MetricsRegistry):
    """While active, every *eager* ``fixed_point.quantize`` call feeds its
    clip count into ``registry``:

        fixed_point.saturation.clips{fmt=Q2.14}     (clipped elements)
        fixed_point.saturation.elements{fmt=Q2.14}  (elements quantized)

    Calls inside a jit trace are ignored by construction (the observer
    never sees tracers — see fixed_point._note_saturation), so attaching
    this changes neither compile counts nor any traced computation. The
    previous observer is restored on exit (scopes nest)."""
    from repro.core import fixed_point as fp

    def _observer(fmt: str, clipped: int, total: int) -> None:
        registry.counter(f"fixed_point.saturation.clips{{fmt={fmt}}}",
                         unit="elements").inc(clipped)
        registry.counter(f"fixed_point.saturation.elements{{fmt={fmt}}}",
                         unit="elements").inc(total)

    prev = fp.set_saturation_observer(_observer)
    try:
        yield registry
    finally:
        fp.set_saturation_observer(prev)


def saturation_audit(tensors: Dict[str, Any],
                     registry: Optional[MetricsRegistry] = None,
                     profiles: Optional[Dict[str, Any]] = None) -> dict:
    """Would-this-clip sweep: quantize every named tensor into every format
    profile's storage format (eagerly, on host) and report the clip counts

        {profile: {tensor: {"clipped": int, "total": int, "frac": float}}}

    — the software analogue of the paper's overflow-free-Q2.14 argument,
    and the telemetry ROADMAP item 5 (quantized KV formats) selects on.
    Counts are also fed into ``registry`` when one is given.
    """
    import numpy as np

    from repro.core import fixed_point as fp

    if profiles is None:
        from repro.cordic_engine.functions import FORMAT_PROFILES
        profiles = FORMAT_PROFILES

    out: Dict[str, Dict[str, dict]] = {}
    for pname, prof in sorted(profiles.items()):
        fmt = prof.cfg.fmt
        per = out[pname] = {}
        for tname, arr in sorted(tensors.items()):
            x = np.asarray(arr, np.float64).ravel()
            scaled = np.round(x * float(fmt.scale))
            clipped = int(np.sum((scaled > fmt.max_int)
                                 | (scaled < fmt.min_int)))
            total = int(x.size)
            per[tname] = {"clipped": clipped, "total": total,
                          "frac": clipped / total if total else 0.0}
            if registry is not None:
                registry.counter(
                    f"fixed_point.saturation.clips{{fmt={fmt}}}",
                    unit="elements").inc(clipped)
                registry.counter(
                    f"fixed_point.saturation.elements{{fmt={fmt}}}",
                    unit="elements").inc(total)
    return out
