"""Bit-accurate fixed-point (Q-format) arithmetic emulated in int32 JAX lanes.

The paper's datapath is a 16-bit two's-complement fixed-point pipeline. All
datapath values lie in (-2, 2) (max magnitude is cosh(0.5)/K_h < 1.2), so we
use Q2.14: 1 sign bit, 1 integer bit, 14 fraction bits; resolution 2^-14.

We carry values in int32 lanes (TPU VPU native width) and mask back to 16-bit
two's complement after every arithmetic op, which makes the emulation
*bit-exact* with respect to a 16-bit hardware register file, including
wraparound semantics. Within the paper's input domain wraparound never
triggers (asserted by property tests), but the masking keeps us honest.

Shifts use arithmetic right shift with truncation (what `>>>` does on a
two's-complement register) by default; round-to-nearest is available for the
output stage.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QFormat:
    """A signed two's-complement fixed-point format with `total_bits` storage
    and `frac_bits` fractional bits."""

    total_bits: int = 16
    frac_bits: int = 14

    @property
    def int_bits(self) -> int:  # excluding sign
        return self.total_bits - self.frac_bits - 1

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def min_int(self) -> int:
        return -(1 << (self.total_bits - 1))

    @property
    def max_int(self) -> int:
        return (1 << (self.total_bits - 1)) - 1

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    def __str__(self) -> str:  # e.g. Q2.14
        return f"Q{self.int_bits + 1}.{self.frac_bits}"


#: The paper's 16-bit format.
Q2_14 = QFormat(total_bits=16, frac_bits=14)
#: Wider internal formats used for sensitivity studies.
Q2_20 = QFormat(total_bits=22, frac_bits=20)
Q2_29 = QFormat(total_bits=31, frac_bits=29)


#: Optional host-side saturation observer: ``callable(fmt_str, clipped,
#: total)`` invoked by eager `quantize` calls whose input would clip at the
#: format boundary — the software analogue of the paper's overflow-free
#: Q2.14 claim, surfaced as serving telemetry by repro.obs. None (the
#: default) costs one `is None` check; tracer inputs are always skipped,
#: so no Python metric state is ever traced into a jitted function and
#: attaching an observer can never add a compile.
_SAT_OBSERVER = None


def set_saturation_observer(observer):
    """Install (or clear, with None) the saturation observer; returns the
    previous one so scopes can nest (see repro.obs.observe_saturation)."""
    global _SAT_OBSERVER
    prev = _SAT_OBSERVER
    _SAT_OBSERVER = observer
    return prev


def _note_saturation(scaled, fmt: QFormat) -> None:
    """Count boundary clips of an *eager* quantize. ``scaled`` is the
    rounded float code before the saturate; comparing pre-cast floats keeps
    the count exact even for values far outside int32."""
    if _SAT_OBSERVER is None or isinstance(scaled, jax.core.Tracer):
        return
    clipped = int(jnp.sum((scaled > fmt.max_int) | (scaled < fmt.min_int)))
    _SAT_OBSERVER(str(fmt), clipped, int(scaled.size))


def wrap(v: jax.Array, fmt: QFormat) -> jax.Array:
    """Mask an int32 lane back to `fmt.total_bits` two's complement."""
    n = fmt.total_bits
    mask = (1 << n) - 1
    half = 1 << (n - 1)
    return ((v + half) & mask) - half


def sat(v: jax.Array, fmt: QFormat) -> jax.Array:
    """Saturate instead of wrapping (used at quantization boundaries)."""
    return jnp.clip(v, fmt.min_int, fmt.max_int)


def quantize(x: jax.Array, fmt: QFormat = Q2_14, rounding: str = "nearest") -> jax.Array:
    """float -> fixed-point integer code (int32 lane), saturating."""
    scaled = x * float(fmt.scale)
    if rounding == "nearest":
        q = jnp.round(scaled)
    elif rounding == "floor":
        q = jnp.floor(scaled)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    _note_saturation(q, fmt)
    return sat(q.astype(jnp.int32), fmt)


def dequantize(v: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """fixed-point integer code -> float32."""
    return v.astype(jnp.float32) * np.float32(fmt.resolution)


def const(x: float, fmt: QFormat = Q2_14) -> np.int32:
    """Quantize a python scalar to an int32 constant (round-to-nearest)."""
    q = int(np.round(x * fmt.scale))
    q = max(fmt.min_int, min(fmt.max_int, q))
    return np.int32(q)


def add(a: jax.Array, b: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    return wrap(a + b, fmt)


def sub(a: jax.Array, b: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    return wrap(a - b, fmt)


def shr(v: jax.Array, s: int, fmt: QFormat = Q2_14, rounding: str = "trunc") -> jax.Array:
    """Arithmetic right shift by a *static* amount.

    "trunc" matches a plain two's-complement `>> s` (floor); "nearest" adds
    the half-ULP bias first (one extra adder in hardware).
    """
    if s == 0:
        return v
    if rounding == "nearest":
        v = v + (1 << (s - 1))
    return wrap(v >> s, fmt)


def shl(v: jax.Array, s: int, fmt: QFormat = Q2_14) -> jax.Array:
    """Left shift (wrapping, as hardware would)."""
    if s == 0:
        return v
    return wrap(v << s, fmt)


def requantize(v: jax.Array, src: QFormat, dst: QFormat, rounding: str = "trunc") -> jax.Array:
    """Convert between Q formats (shift of the binary point)."""
    ds = src.frac_bits - dst.frac_bits
    if ds >= 0:
        out = shr(v, ds, dst, rounding=rounding) if ds else v
    else:
        out = v << (-ds)
    return wrap(out, dst)
