"""Core: the paper's MR-HRC + R2-LVC CORDIC sigmoid and the activation registry.

Re-exports are lazy (PEP 562): ``repro.core.cordic`` now builds on
``repro.cordic_engine``, which itself needs ``repro.core.fixed_point`` — an
eager import here would close that cycle before either side finishes.
"""
_CORDIC_EXPORTS = (
    "FixedConfig", "MRSchedule", "PAPER_FIXED", "PAPER_SCHEDULE",
    "R2_BASELINE_SCHEDULE", "sigmoid_fixed", "sigmoid_mr_f", "tanh_fixed",
    "tanh_mr_f",
)


def __getattr__(name):
    if name in _CORDIC_EXPORTS:
        from repro.core import cordic

        return getattr(cordic, name)
    if name == "get_activation":
        from repro.core.activations import get_activation

        return get_activation
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
