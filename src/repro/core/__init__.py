"""Core: the paper's MR-HRC + R2-LVC CORDIC sigmoid and the activation registry."""
from repro.core.cordic import (  # noqa: F401
    FixedConfig,
    MRSchedule,
    PAPER_FIXED,
    PAPER_SCHEDULE,
    R2_BASELINE_SCHEDULE,
    sigmoid_fixed,
    sigmoid_mr_f,
    tanh_fixed,
    tanh_mr_f,
)
from repro.core.activations import get_activation  # noqa: F401
