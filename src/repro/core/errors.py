"""Error-analysis utilities used by tests and the accuracy benchmark."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def error_stats(fn, ref_fn, lo: float, hi: float, n: int = 20001) -> dict:
    """MAE / max-abs / RMS error of `fn` vs `ref_fn` on a uniform grid."""
    x = jnp.linspace(lo, hi, n, dtype=jnp.float32)
    y = np.asarray(fn(x), dtype=np.float64)
    r = np.asarray(ref_fn(x), dtype=np.float64)
    e = np.abs(y - r)
    return dict(mae=float(e.mean()), max=float(e.max()),
                rms=float(np.sqrt((e * e).mean())), n=n, lo=lo, hi=hi)


def ulp(err: float, frac_bits: int = 14) -> float:
    """Express an absolute error in output ULPs of a Qx.frac format."""
    return err * (1 << frac_bits)
