"""Pluggable activation registry — the bridge between the paper's CORDIC
evaluator and the LM substrate.

Every model in this framework obtains its nonlinearities from
``get_activation(kind, impl)`` so the MR-HRC pipeline is a first-class,
config-selectable feature:

    impl = "exact"         : jnp/XLA transcendental lowering (float reference)
    impl = "cordic_float"  : MR-HRC algorithm in f32 (no quantization)
    impl = "cordic_fixed"  : bit-accurate Q2.14 (paper-faithful), pure jnp int32
    impl = "cordic_pallas" : Pallas TPU kernel of the Q2.14 pipeline

Quantized/iterative forwards are wrapped in ``jax.custom_jvp`` computing the
analytic derivative from the primal *output* (sigma' = s(1-s),
tanh' = 1 - t^2), so training through the hardware activation is exact to
first order and needs no extra evaluation.

Range handling: the paper's contract is |x| <= 1 (sigmoid) / |z| <= 0.5
(tanh). In-network pre-activations exceed that, so network-facing wrappers
use ``range_mode``:
    "clamp"  — saturate into the paper domain (paper-faithful),
    "reduce" — dyadic argument reduction to |x| <= 8 (beyond-paper, default
               for model configs; see core/sigmoid.sigmoid_cordic_wide).

Beyond the sigmoid/tanh family, the generalized engine
(repro.cordic_engine) contributes "exp", "softplus", "elu", and "gelu_erf"
kinds — all shift-add CORDIC cores with dyadic range reduction built in.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import sigmoid as S
from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE

ACT_IMPLS = ("exact", "cordic_float", "cordic_fixed", "cordic_pallas")
RANGE_MODES = ("clamp", "reduce")


def _with_sigmoid_jvp(fwd: Callable) -> Callable:
    @jax.custom_jvp
    def f(x):
        return fwd(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        s = f(x)
        return s, (s * (1.0 - s)) * dx

    return f


def _with_tanh_jvp(fwd: Callable) -> Callable:
    @jax.custom_jvp
    def f(x):
        return fwd(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        t = f(x)
        return t, (1.0 - t * t) * dx

    return f


def _sigmoid_fwd(impl: str, range_mode: str, sched: MRSchedule, cfg: FixedConfig):
    if impl == "exact":
        return jax.nn.sigmoid
    if impl == "cordic_float":
        if range_mode == "clamp":
            return lambda x: S.sigmoid_cordic_float(x, sched)
        # float algorithm with dyadic reduction: reuse wide path but float core
        return lambda x: S.sigmoid_cordic_wide(x, sched, cfg)
    if impl == "cordic_fixed":
        if range_mode == "clamp":
            return lambda x: S.sigmoid_cordic_fixed(x, sched, cfg)
        return lambda x: S.sigmoid_cordic_wide(x, sched, cfg)
    if impl == "cordic_pallas":
        from repro.kernels import ops as kops  # lazy: kernels optional at import

        if range_mode == "clamp":
            return lambda x: kops.sigmoid(x)
        return lambda x: kops.sigmoid_wide(x)
    raise ValueError(f"unknown activation impl {impl!r}")


def _tanh_fwd(impl: str, range_mode: str, sched: MRSchedule, cfg: FixedConfig):
    if impl == "exact":
        return jnp.tanh
    # tanh(z) = 2*sigmoid(2z) - 1 handles range via the sigmoid path.
    sig = _sigmoid_fwd(impl, range_mode, sched, cfg)
    if impl in ("cordic_float", "cordic_fixed", "cordic_pallas") and range_mode == "clamp":
        if impl == "cordic_float":
            return lambda z: S.tanh_cordic_float(z, sched)
        if impl == "cordic_fixed":
            return lambda z: S.tanh_cordic_fixed(z, sched, cfg)
        from repro.kernels import ops as kops

        return lambda z: kops.tanh(z)
    return lambda z: 2.0 * sig(2.0 * z) - 1.0


def _with_output_jvp(fwd: Callable, tangent_from_primal: Callable) -> Callable:
    """custom_jvp computing the tangent coefficient from (x, primal y)."""
    @jax.custom_jvp
    def f(x):
        return fwd(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        y = f(x)
        return y, tangent_from_primal(x, y) * dx

    return f


def _engine_fwd(kind: str, impl: str, cfg: FixedConfig):
    """Forward fn for the engine-derived kinds (exp/softplus/elu/gelu_erf).

    ``cordic_pallas`` runs the dedicated Pallas kernels in
    ``repro.kernels.ops`` — bit-identical to the jnp fixed path (enforced by
    the golden-vector conformance suite), but fused into one VMEM pass.
    """
    from repro.cordic_engine import functions as F

    if impl == "cordic_pallas":
        from repro.core.cordic import PAPER_SCHEDULE
        from repro.kernels import ops as kops  # lazy: kernels optional at import

        ktable = {"exp": kops.exp, "softplus": kops.softplus,
                  "elu": kops.elu, "gelu_erf": kops.gelu_erf}
        # bind cfg positionally (custom_jvp nondiff args) so non-default
        # formats are honored like the jnp paths
        return lambda x, _k=ktable[kind]: _k(x, PAPER_SCHEDULE, cfg)
    table = {
        "exp": (jnp.exp, F.exp_float, lambda x: F.exp_fixed(x, cfg=cfg)),
        "softplus": (jax.nn.softplus, F.softplus_float,
                     lambda x: F.softplus_fixed(x, cfg=cfg)),
        "elu": (jax.nn.elu, F.elu_float, lambda x: F.elu_fixed(x, cfg=cfg)),
        "gelu_erf": (partial(jax.nn.gelu, approximate=False), F.gelu_erf_float,
                     lambda x: F.gelu_erf_fixed(x, cfg=cfg)),
    }
    exact, flt, fxd = table[kind]
    if impl == "exact":
        return exact
    return fxd if impl == "cordic_fixed" else flt


#: tangent coefficients from (x, primal) for the engine-derived kinds.
_ENGINE_JVPS = {
    "exp": lambda x, y: y,
    "softplus": lambda x, y: -jnp.expm1(-y),            # sigma(x) = 1 - e^-y
    "elu": lambda x, y: jnp.where(x > 0, 1.0, y + 1.0),  # y + alpha = alpha e^x
    # gelu'(x) = Phi(x) + x phi(x); cheap closed form, exact to first order
    "gelu_erf": lambda x, y: jax.scipy.stats.norm.cdf(x)
    + x * jax.scipy.stats.norm.pdf(x),
}


def get_activation(kind: str, impl: str = "exact", range_mode: str = "reduce",
                   sched: MRSchedule = PAPER_SCHEDULE,
                   cfg: FixedConfig = PAPER_FIXED) -> Callable:
    """Return a differentiable activation fn of the requested kind/impl.

    kind in {"sigmoid", "tanh", "silu", "gelu_tanh", "relu", "gelu",
             "exp", "softplus", "elu", "gelu_erf"} — the last four are
    derived from the generalized engine (repro.cordic_engine.functions).
    """
    if impl not in ACT_IMPLS:
        raise ValueError(f"impl {impl!r} not in {ACT_IMPLS}")
    if range_mode not in RANGE_MODES:
        raise ValueError(f"range_mode {range_mode!r} not in {RANGE_MODES}")

    if kind == "relu":
        return jax.nn.relu
    if kind == "gelu":
        return jax.nn.gelu

    if kind in _ENGINE_JVPS:
        fwd = _engine_fwd(kind, impl, cfg)
        if impl in ("exact", "cordic_pallas"):
            # exact is jax-native; the pallas ops carry their own custom_jvp
            # with the same output-derived rules — don't wrap twice
            return fwd
        return _with_output_jvp(fwd, _ENGINE_JVPS[kind])

    if kind == "sigmoid":
        fwd = _sigmoid_fwd(impl, range_mode, sched, cfg)
        return fwd if impl == "exact" else _with_sigmoid_jvp(fwd)
    if kind == "tanh":
        fwd = _tanh_fwd(impl, range_mode, sched, cfg)
        return fwd if impl == "exact" else _with_tanh_jvp(fwd)
    if kind == "silu":
        if impl == "exact":
            return jax.nn.silu
        sig = _with_sigmoid_jvp(_sigmoid_fwd(impl, range_mode, sched, cfg))
        return lambda x: x * sig(x)
    if kind == "gelu_tanh":
        # GELU(x) ~= 0.5 x (1 + tanh(sqrt(2/pi)(x + 0.044715 x^3)))
        if impl == "exact":
            return partial(jax.nn.gelu, approximate=True)
        th = _with_tanh_jvp(_tanh_fwd(impl, range_mode, sched, cfg))
        c = 0.7978845608028654
        return lambda x: 0.5 * x * (1.0 + th(c * (x + 0.044715 * x * x * x)))
    raise ValueError(f"unknown activation kind {kind!r}")
