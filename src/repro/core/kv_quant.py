"""Quantized paged-KV formats: per-block scaled fixed-point storage with a
CORDIC linear-rotation dequant.

The serve engine's paged KV pool is the single largest memory consumer, and
the paper's thesis — a narrow fixed-point datapath serves nonlinear math at
full accuracy when formats are assigned per-op with explicit saturation
handling — applies to it directly. ``cfg.kv_quant`` selects the storage
format of the K/V block pools:

    "none"  — full-width pools (the pre-quantization plane, byte-for-byte)
    "int8"  — Q8.0 codes in int8 lanes: 4x fewer pool bytes than f32
    "q2_14" — the paper's 16-bit Q2.14 format in int16 lanes: 2x fewer

Quantization is *block-scaled*: every pool block carries one f32 scale per
kv head (shape ``(num_blocks, 1, KH, 1)``, broadcastable against the
``(num_blocks, block_len, KH, hd)`` code pool), chosen from the block's
per-head amax so in-range writes never clip — saturation can only come from
an explicitly chosen scale, and `fixed_point.quantize` counts it when it
does (the ``fixed_point.saturation.clips{fmt=...}`` telemetry). Scales are
per-head so the tensor-parallel kv-heads cut shards them with the pools:
each shard computes exactly the scales the unsharded engine would, which is
what keeps TP=1/TP=2 token streams bit-identical under quantization.

Dequantization is the CORDIC **linear-rotation multiply**
(cordic_engine.functions.multiply_float): code * resolution gives the
block-relative value, and the scale multiply runs as a frexp-reduced
shift-add sweep — the same transcendental-free datapath as the rest of the
pipeline, elementwise and deterministic, so the gather attend, the Pallas
block-walking kernel, and every TP shard dequantize to bit-identical
floats.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fp
from repro.cordic_engine import functions as fn

#: cfg.kv_quant values the engine accepts.
KV_QUANT_IMPLS = ("none", "int8", "q2_14")

#: int8 KV codes: 1 sign bit + 7 integer bits, no fraction — the scale
#: carries all the dynamic range (prints as "Q8.0" in saturation metrics).
INT8 = fp.QFormat(total_bits=8, frac_bits=0)

#: positive floor for amax-derived scales: an all-zero block quantizes to
#: all-zero codes instead of dividing by zero.
_TINY = np.float32(np.finfo(np.float32).tiny)


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """One quantized-KV storage format: the Q format the codes live in and
    the integer lane dtype the pool stores them as."""

    name: str
    fmt: fp.QFormat
    code_dtype: Any

    @property
    def fmt_max(self) -> float:
        """Largest representable magnitude of the format (code max_int in
        value units); amax-derived scales map a block's amax here."""
        return self.fmt.max_int * self.fmt.resolution

    @property
    def code_bits(self) -> int:
        """Storage bits per pool element (the lane width, not fmt bits)."""
        return jnp.dtype(self.code_dtype).itemsize * 8


_SPECS = {
    "int8": KVQuantSpec("int8", INT8, jnp.int8),
    "q2_14": KVQuantSpec("q2_14", fp.Q2_14, jnp.int16),
}


def spec_for(name: Optional[str]) -> Optional[KVQuantSpec]:
    """The KVQuantSpec for a cfg.kv_quant value; None for "none"/None.

    Unknown names raise at init time (the engine calls this before any
    pool is built) rather than silently serving unquantized.
    """
    if name is None or name == "none":
        return None
    spec = _SPECS.get(name)
    if spec is None:
        raise ValueError(f"unknown kv_quant {name!r}; expected one of "
                         f"{KV_QUANT_IMPLS}")
    return spec


def scale_for_amax(amax: jax.Array, spec: KVQuantSpec) -> jax.Array:
    """amax -> f32 scale mapping the amax onto the format's max code
    (floored at tiny so all-zero inputs stay divisible)."""
    return jnp.maximum(amax.astype(jnp.float32) / np.float32(spec.fmt_max),
                       _TINY)


def block_scale(x: jax.Array, spec: KVQuantSpec) -> jax.Array:
    """Per-block-per-head scale for ``(..., L, KH, hd)`` blocks: amax over
    the position and feature axes, head axis kept — returns
    ``(..., 1, KH, 1)`` f32, broadcastable against the block."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1),
                   keepdims=True)
    return scale_for_amax(amax, spec)


def quantize(x: jax.Array, spec: KVQuantSpec, scale: jax.Array) -> jax.Array:
    """float K/V -> integer codes in the spec's lane dtype.

    Runs through fixed_point.quantize, so eager calls feed the saturation
    observer (``fixed_point.saturation.clips{fmt=...}``) when a value
    exceeds ``scale * fmt_max`` — with amax-derived scales that never
    happens; an explicitly pinned scale makes clipping a measured metric.
    """
    return fp.quantize(x / scale, spec.fmt).astype(spec.code_dtype)


def dequantize(codes: jax.Array, spec: KVQuantSpec,
               scale: jax.Array) -> jax.Array:
    """Integer codes + scale -> f32, the scale multiply on the CORDIC
    linear-rotation datapath (shift-add sweep over frexp mantissas).

    Elementwise and deterministic: the gather attend, the Pallas kernel's
    per-chunk VMEM step, and every TP shard call this on identical
    (code, scale) pairs and get bit-identical floats back.
    """
    return fn.multiply_float(fp.dequantize(codes, spec.fmt),
                             jnp.asarray(scale, jnp.float32))
