"""Mixed-Radix Hyperbolic Rotation CORDIC (MR-HRC) + Radix-2 Linear Vectoring
CORDIC (R2-LVC) — the paper's core contribution.

Pipeline (paper Fig. 2):

    z = x/2  -->  [ MR-HRC: R2-HRC j=2..9, then R4-HRC j=4..7 ]  --> (cosh z, sinh z)
             -->  [ R2-LVC j=1..14 ]  --> tanh z = sinh/cosh
             -->  sigmoid(x) = 1/2 + 1/2 * tanh z

Two parallel implementations are provided for every stage:

* ``*_f``    — float (f32/f64) reference of the *algorithm* (no quantization),
* ``*_q``    — bit-accurate fixed-point (Q2.14 by default) matching a 16-bit
               two's-complement hardware datapath, including shift truncation.

All loops are unrolled over *static* schedules (8 + 4 + 14 = 26 iterations),
so everything traces to straight-line HLO — exactly how the fully-pipelined
hardware is laid out, one adder stage per iteration.

Convergence facts (verified in tests/test_cordic_properties.py):

* R2-HRC range, j=2..9:      sum atanh(2^-j)           = 0.504210  >= 0.5
* R2-HRC worst residual:     ~6.1e-3 (paper: 0.0061 — the no-repeat gaps;
                             the with-repeat textbook bound would be 1.95e-3)
* R4-HRC admissible start:   sum atanh(2*4^-j), j=4..7 = 0.010374  >= 6.1e-3
* R4-HRC gain:               prod sqrt(1 - s^2 4^-2j) in [1 - 2^-14, 1]  (scale-free)
* R2-LVC domain:             |y/x| <= 2;  here |tanh(0.5)| ~= 0.462
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fp
from repro.core.fixed_point import Q2_14, QFormat


# --------------------------------------------------------------------------
# Schedules & constants
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MRSchedule:
    """Iteration schedule for the MR-HRC + R2-LVC pipeline.

    The defaults are exactly the paper's: radix-2 j=2..9, radix-4 j=4..7,
    and (the paper leaves LVC unspecified) LVC j=1..14 for a 16-bit result.
    """

    r2_js: tuple = tuple(range(2, 10))
    r4_js: tuple = tuple(range(4, 8))
    lvc_js: tuple = tuple(range(1, 15))

    @property
    def r2_gain(self) -> float:
        """K_h — the constant radix-2 stage gain, folded into x0 = 1/K_h."""
        p = 1.0
        for j in self.r2_js:
            p *= math.sqrt(1.0 - 2.0 ** (-2 * j))
        return p

    @property
    def x0(self) -> float:
        return 1.0 / self.r2_gain

    @property
    def r2_range(self) -> float:
        """Convergence range of the radix-2 stage (paper eq. (5))."""
        return sum(math.atanh(2.0 ** (-j)) for j in self.r2_js)

    @property
    def r4_range(self) -> float:
        """Admissible input range of the radix-4 stage (paper eq. (6))."""
        return sum(math.atanh(2.0 * 4.0 ** (-j)) for j in self.r4_js)

    @property
    def r4_gain_bounds(self) -> tuple:
        """(min, max) cumulative radix-4 gain over all digit sequences."""
        lo = 1.0
        for j in self.r4_js:
            lo *= math.sqrt(1.0 - 4.0 * 4.0 ** (-2 * j))
        return lo, 1.0

    def num_iterations(self) -> int:
        return len(self.r2_js) + len(self.r4_js) + len(self.lvc_js)


PAPER_SCHEDULE = MRSchedule()

#: Pure radix-2 baseline ("conventional R2-HRC"): same accuracy floor needs
#: j=2..14 *with* the textbook repetition of j=4 and j=13 for gap-free
#: convergence (repeats make the per-step convergence inequality hold).
R2_BASELINE_SCHEDULE = MRSchedule(
    r2_js=(2, 3, 4, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 13, 14),
    r4_js=(),
    lvc_js=tuple(range(1, 15)),
)


def _atanh_r2(j: int) -> float:
    return math.atanh(2.0 ** (-j))


def _atanh_r4(j: int, mag: int) -> float:
    return math.atanh(mag * 4.0 ** (-j))


# --------------------------------------------------------------------------
# Float reference implementations (algorithmic fidelity, no quantization)
# --------------------------------------------------------------------------
def r2_hrc_f(x, y, z, js) -> tuple:
    """Radix-2 hyperbolic rotation iterations (d = sign(z), never 0)."""
    for j in js:
        a = _atanh_r2(j)
        d = jnp.where(z >= 0, 1.0, -1.0).astype(x.dtype)
        x, y, z = (
            x + d * y * (2.0 ** (-j)),
            y + d * x * (2.0 ** (-j)),
            z - d * a,
        )
    return x, y, z


def _r4_digit_f(z, j):
    """SRT-style radix-4 digit selection on w = 4^j z (paper eq. (8))."""
    w = z * (4.0 ** j)
    return jnp.where(
        w >= 1.5, 2.0,
        jnp.where(w >= 0.5, 1.0, jnp.where(w >= -0.5, 0.0, jnp.where(w >= -1.5, -1.0, -2.0))),
    ).astype(z.dtype)


def r4_hrc_f(x, y, z, js) -> tuple:
    """Radix-4 hyperbolic rotation iterations, digit set {-2,-1,0,1,2}.

    Started at j>=4 the cumulative gain is within 2^-14 of 1 (scale-free).
    """
    for j in js:
        s = _r4_digit_f(z, j)
        mag = jnp.abs(s)
        # atanh(s*4^-j) for s in {-2..2}; exploit oddness.
        a = jnp.sign(s) * jnp.where(
            mag == 2.0, _atanh_r4(j, 2), jnp.where(mag == 1.0, _atanh_r4(j, 1), 0.0)
        ).astype(z.dtype)
        f = s * (4.0 ** (-j))
        x, y, z = x + f * y, y + f * x, z - a
    return x, y, z


def mr_hrc_f(z, sched: MRSchedule = PAPER_SCHEDULE) -> tuple:
    """Mixed-radix HRC: returns (cosh z, sinh z, residual angle)."""
    x = jnp.full_like(z, sched.x0)
    y = jnp.zeros_like(z)
    x, y, z = r2_hrc_f(x, y, z, sched.r2_js)
    x, y, z = r4_hrc_f(x, y, z, sched.r4_js)
    return x, y, z


def r2_lvc_f(x, y, js) -> jax.Array:
    """Radix-2 linear vectoring: drives y -> 0, accumulating z -> y0/x0.

    Valid for |y0/x0| <= 2 and x0 > 0 (cosh is always positive here).
    """
    z = jnp.zeros_like(y)
    for j in js:
        d = jnp.where(y >= 0, 1.0, -1.0).astype(y.dtype)
        y, z = y - d * x * (2.0 ** (-j)), z + d * (2.0 ** (-j))
    return z


def tanh_mr_f(z, sched: MRSchedule = PAPER_SCHEDULE) -> jax.Array:
    """tanh(z) for |z| <= 0.5 via MR-HRC + R2-LVC (float)."""
    c, s, _ = mr_hrc_f(z, sched)
    return r2_lvc_f(c, s, sched.lvc_js)


def sigmoid_mr_f(x, sched: MRSchedule = PAPER_SCHEDULE) -> jax.Array:
    """sigmoid(x) for |x| <= 1 via the paper pipeline (float)."""
    t = tanh_mr_f(x * 0.5, sched)
    return 0.5 + 0.5 * t


# --------------------------------------------------------------------------
# Fixed-point (bit-accurate) implementations
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FixedConfig:
    """Datapath quantization config.

    ``fmt``        — x/y register format (the paper's 16-bit Q2.14).
    ``z_guard``    — extra fraction bits on the z (angle) register. 0 keeps
                     the strict 16-bit paper datapath; a few guard bits on the
                     angle accumulator is a standard, cheap HW refinement
                     (one slightly wider adder) studied in the accuracy bench.
    ``shift_round``— rounding of datapath right-shifts: "trunc" is what a
                     plain two's-complement `>>` does (the paper's adder-only
                     datapath); "nearest" costs one extra adder per stage.
    ``out_round``  — rounding of the final output requantization.
    """

    fmt: QFormat = Q2_14
    z_guard: int = 0
    shift_round: str = "trunc"
    out_round: str = "nearest"

    @property
    def zfmt(self) -> QFormat:
        if self.z_guard == 0:
            return self.fmt
        return QFormat(
            total_bits=self.fmt.total_bits + self.z_guard,
            frac_bits=self.fmt.frac_bits + self.z_guard,
        )


PAPER_FIXED = FixedConfig()


@lru_cache(maxsize=None)
def _q_constants(sched: MRSchedule, cfg: FixedConfig):
    """Pre-quantized ROM constants (atanh tables, thresholds, x0)."""
    zf = cfg.zfmt
    r2_atanh = tuple(fp.const(_atanh_r2(j), zf) for j in sched.r2_js)
    r4_atanh1 = tuple(fp.const(_atanh_r4(j, 1), zf) for j in sched.r4_js)
    r4_atanh2 = tuple(fp.const(_atanh_r4(j, 2), zf) for j in sched.r4_js)
    # Digit-selection thresholds 0.5*4^-j / 1.5*4^-j, in the z format.
    thr05 = tuple(fp.const(0.5 * 4.0 ** (-j), zf) for j in sched.r4_js)
    thr15 = tuple(fp.const(1.5 * 4.0 ** (-j), zf) for j in sched.r4_js)
    x0 = fp.const(sched.x0, cfg.fmt)
    return dict(r2_atanh=r2_atanh, r4_atanh1=r4_atanh1, r4_atanh2=r4_atanh2,
                thr05=thr05, thr15=thr15, x0=x0)


def r2_hrc_q(x, y, z, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point radix-2 HRC. x/y in cfg.fmt, z in cfg.zfmt (int32 lanes)."""
    k = _q_constants(sched, cfg)
    f, zf, rnd = cfg.fmt, cfg.zfmt, cfg.shift_round
    for i, j in enumerate(sched.r2_js):
        d_pos = z >= 0
        xs = fp.shr(x, j, f, rounding=rnd)
        ys = fp.shr(y, j, f, rounding=rnd)
        a = k["r2_atanh"][i]
        x, y = (
            jnp.where(d_pos, fp.add(x, ys, f), fp.sub(x, ys, f)),
            jnp.where(d_pos, fp.add(y, xs, f), fp.sub(y, xs, f)),
        )
        z = jnp.where(d_pos, fp.sub(z, a, zf), fp.add(z, a, zf))
    return x, y, z


def r4_hrc_q(x, y, z, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point radix-4 HRC with SRT digit selection.

    The digit compare is done directly on z against pre-scaled thresholds
    (0.5*4^-j, 1.5*4^-j) — equivalent to comparing 4^j z against +-0.5/+-1.5
    but without the left shift that could overflow the 16-bit register.
    """
    k = _q_constants(sched, cfg)
    f, zf, rnd = cfg.fmt, cfg.zfmt, cfg.shift_round
    for i, j in enumerate(sched.r4_js):
        t05, t15 = k["thr05"][i], k["thr15"][i]
        a1, a2 = k["r4_atanh1"][i], k["r4_atanh2"][i]
        # sigma in {-2,-1,0,1,2}
        mag2 = (z >= t15) | (z < -t15)                    # |sigma| == 2
        mag0 = (z < t05) & (z >= -t05)                    # sigma == 0
        pos = z >= 0
        # |sigma|*4^-j multiplies => shift by 2j (|s|=1) or 2j-1 (|s|=2).
        xs1 = fp.shr(x, 2 * j, f, rounding=rnd)
        ys1 = fp.shr(y, 2 * j, f, rounding=rnd)
        xs2 = fp.shr(x, 2 * j - 1, f, rounding=rnd)
        ys2 = fp.shr(y, 2 * j - 1, f, rounding=rnd)
        dx = jnp.where(mag0, 0, jnp.where(mag2, ys2, ys1))
        dy = jnp.where(mag0, 0, jnp.where(mag2, xs2, xs1))
        da = jnp.where(mag0, 0, jnp.where(mag2, a2, a1))
        x = jnp.where(pos, fp.add(x, dx, f), fp.sub(x, dx, f))
        y = jnp.where(pos, fp.add(y, dy, f), fp.sub(y, dy, f))
        z = jnp.where(pos, fp.sub(z, da, zf), fp.add(z, da, zf))
    return x, y, z


def mr_hrc_q(z_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point MR-HRC. ``z_q`` is the angle in cfg.fmt codes (int32 lane).

    Returns (cosh_q, sinh_q, residual_q[z-format]).
    """
    k = _q_constants(sched, cfg)
    x = jnp.full_like(z_q, jnp.int32(k["x0"]))
    y = jnp.zeros_like(z_q)
    z = z_q << cfg.z_guard if cfg.z_guard else z_q  # extend angle register
    x, y, z = r2_hrc_q(x, y, z, sched, cfg)
    x, y, z = r4_hrc_q(x, y, z, sched, cfg)
    return x, y, z


def r2_lvc_q(x, y, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point linear vectoring. Result z in cfg.zfmt codes."""
    f, zf, rnd = cfg.fmt, cfg.zfmt, cfg.shift_round
    z = jnp.zeros_like(y)
    if cfg.z_guard:
        z = z << 0  # stays int32 lane; z-format is wider only logically
    for j in sched.lvc_js:
        d_pos = y >= 0
        xs = fp.shr(x, j, f, rounding=rnd)
        step = jnp.int32(1) << max(cfg.zfmt.frac_bits - j, 0)
        y = jnp.where(d_pos, fp.sub(y, xs, f), fp.add(y, xs, f))
        z = jnp.where(d_pos, fp.add(z, step, zf), fp.sub(z, step, zf))
    return z


def tanh_mr_q(z_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point tanh(z) for |z| <= 0.5. In/out: cfg.fmt codes."""
    c, s, _ = mr_hrc_q(z_q, sched, cfg)
    t = r2_lvc_q(c, s, sched, cfg)
    # Requantize z-format -> datapath format (only if guard bits in use).
    if cfg.z_guard:
        t = fp.shr(t, cfg.z_guard, cfg.fmt, rounding=cfg.out_round)
    return t


def sigmoid_mr_q(x_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point sigmoid(x) for |x| <= 1. In/out: cfg.fmt codes.

    sigma = 1/2 + 1/2 * tanh(x/2): both the input halving and the output
    scale are single right-shifts; the offset is one add of a constant.
    """
    z = fp.shr(x_q, 1, cfg.fmt, rounding=cfg.shift_round)     # x/2
    t = tanh_mr_q(z, sched, cfg)
    half = jnp.int32(1 << (cfg.fmt.frac_bits - 1))            # 0.5 in fmt
    t2 = fp.shr(t, 1, cfg.fmt, rounding=cfg.out_round)        # tanh/2
    return fp.add(half, t2, cfg.fmt)


# --------------------------------------------------------------------------
# Public float-in/float-out fixed-point wrappers
# --------------------------------------------------------------------------
def sigmoid_fixed(x, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
                  clamp: bool = True):
    """float -> Q2.14 -> MR-HRC sigmoid -> float. Domain |x| <= 1 (clamped)."""
    if clamp:
        x = jnp.clip(x, -1.0, 1.0)
    xq = fp.quantize(x, cfg.fmt)
    yq = sigmoid_mr_q(xq, sched, cfg)
    return fp.dequantize(yq, cfg.fmt).astype(x.dtype)


def tanh_fixed(z, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
               clamp: bool = True):
    """float -> Q2.14 -> MR-HRC tanh -> float. Domain |z| <= 0.5 (clamped)."""
    if clamp:
        z = jnp.clip(z, -0.5, 0.5)
    zq = fp.quantize(z, cfg.fmt)
    tq = tanh_mr_q(zq, sched, cfg)
    return fp.dequantize(tq, cfg.fmt).astype(z.dtype)


# --------------------------------------------------------------------------
# Introspection helpers (tests & benchmarks)
# --------------------------------------------------------------------------
def r2_residual_f(z, sched: MRSchedule = PAPER_SCHEDULE):
    """|residual angle| after the radix-2 stage only (float)."""
    x = jnp.full_like(z, sched.x0)
    y = jnp.zeros_like(z)
    _, _, zr = r2_hrc_f(x, y, z, sched.r2_js)
    return jnp.abs(zr)


def shift_add_op_count(sched: MRSchedule = PAPER_SCHEDULE) -> dict:
    """Static resource model: adds/shifts/compares per evaluation (Table-1 analog).

    Counts follow the architecture figures: R2-HRC stages use 3 adders
    (x, y, z) and 2 barrel-less fixed shifts; R4-HRC adds the 5-way digit
    mux (2 compares); LVC uses 2 adders + 1 shift; the output stage is one
    add + two shifts; input stage one shift.
    """
    n_r2, n_r4, n_lvc = len(sched.r2_js), len(sched.r4_js), len(sched.lvc_js)
    adds = 3 * n_r2 + 3 * n_r4 + 2 * n_lvc + 1
    shifts = 2 * n_r2 + 2 * n_r4 + 1 * n_lvc + 3
    compares = 1 * n_r2 + 4 * n_r4 + 1 * n_lvc
    rom_bits = (n_r2 + 2 * n_r4) * 16
    return dict(adds=adds, shifts=shifts, compares=compares,
                rom_bits=rom_bits, iterations=sched.num_iterations(),
                multipliers=0, dividers=0, dsp=0)
