"""The paper's sigmoid pipeline, specialized from the generalized CORDIC
engine in ``repro.cordic_engine``.

This module used to *be* the implementation; it is now a thin facade that
instantiates the mode-parameterized engine with the paper's schedule:

    z = x/2  -->  [ MR-HRC: hyperbolic rotation, R2 j=2..9 + R4 j=4..7 ]
             -->  (cosh z, sinh z)
             -->  [ R2-LVC: linear vectoring, j=1..14 ]  -->  tanh z
             -->  sigmoid(x) = 1/2 + 1/2 * tanh z

Everything below delegates to ``cordic_engine.core`` (the generic radix-2 /
radix-4 sweeps) and is **bit-identical** to the original seed implementation
— enforced over all 2^16 input codes against the independent Pallas
transcription in ``kernels/cordic_act.py`` (tests/test_cordic_engine.py).

For the general machinery (circular/linear modes, exp, log, division,
sin/cos, softplus/elu/gelu) see ``repro.cordic_engine``; schedules and the
``FixedConfig`` datapath config also live there and are re-exported here
for backward compatibility.

Convergence facts (verified in tests/test_cordic_properties.py):

* R2-HRC range, j=2..9:      sum atanh(2^-j)           = 0.504210  >= 0.5
* R2-HRC worst residual:     ~6.1e-3 (paper: 0.0061 — the no-repeat gaps;
                             the with-repeat textbook bound would be 1.95e-3)
* R4-HRC admissible start:   sum atanh(2*4^-j), j=4..7 = 0.010374  >= 6.1e-3
* R4-HRC gain:               prod sqrt(1 - s^2 4^-2j) in [1 - 2^-14, 1]  (scale-free)
* R2-LVC domain:             |y/x| <= 2;  here |tanh(0.5)| ~= 0.462
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fp
from repro.cordic_engine import core as eng
from repro.cordic_engine.core import FixedConfig, PAPER_FIXED  # noqa: F401
from repro.cordic_engine.schedule import (  # noqa: F401
    HYPERBOLIC,
    LINEAR,
    ROTATION,
    VECTORING,
    MRSchedule,
    PAPER_SCHEDULE,
    R2_BASELINE_SCHEDULE,
    CordicSchedule,
)


#: SRT digit selection (float), kept under its historical name for tests.
_r4_digit_f = eng._r4_digit_f


# --------------------------------------------------------------------------
# Float reference implementations (engine specializations)
# --------------------------------------------------------------------------
def r2_hrc_f(x, y, z, js) -> tuple:
    """Radix-2 hyperbolic rotation iterations (d = sign(z), never 0)."""
    return eng.radix2_sweep_f(x, y, z, js, HYPERBOLIC, ROTATION)


def r4_hrc_f(x, y, z, js) -> tuple:
    """Radix-4 hyperbolic rotation iterations, digit set {-2,-1,0,1,2}."""
    return eng.radix4_sweep_f(x, y, z, js)


def mr_hrc_f(z, sched: MRSchedule = PAPER_SCHEDULE) -> tuple:
    """Mixed-radix HRC: returns (cosh z, sinh z, residual angle)."""
    return eng.rotate_f(z, sched.rotation)


def r2_lvc_f(x, y, js) -> jax.Array:
    """Radix-2 linear vectoring: drives y -> 0, accumulating z -> y0/x0."""
    return eng.vector_f(x, y, CordicSchedule(LINEAR, tuple(js)))


def tanh_mr_f(z, sched: MRSchedule = PAPER_SCHEDULE) -> jax.Array:
    """tanh(z) for |z| <= 0.5 via MR-HRC + R2-LVC (float)."""
    c, s, _ = mr_hrc_f(z, sched)
    return r2_lvc_f(c, s, sched.lvc_js)


def sigmoid_mr_f(x, sched: MRSchedule = PAPER_SCHEDULE) -> jax.Array:
    """sigmoid(x) for |x| <= 1 via the paper pipeline (float)."""
    t = tanh_mr_f(x * 0.5, sched)
    return 0.5 + 0.5 * t


# --------------------------------------------------------------------------
# Fixed-point (bit-accurate) implementations
# --------------------------------------------------------------------------
def r2_hrc_q(x, y, z, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point radix-2 HRC. x/y in cfg.fmt, z in cfg.zfmt (int32 lanes)."""
    return eng.radix2_sweep_q(x, y, z, sched.r2_js, HYPERBOLIC, ROTATION, cfg)


def r4_hrc_q(x, y, z, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point radix-4 HRC with SRT digit selection."""
    return eng.radix4_sweep_q(x, y, z, sched.r4_js, HYPERBOLIC, ROTATION, cfg)


def mr_hrc_q(z_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point MR-HRC. ``z_q`` is the angle in cfg.fmt codes (int32 lane).

    Returns (cosh_q, sinh_q, residual_q[z-format]).
    """
    return eng.rotate_q(z_q, sched.rotation, cfg)


def r2_lvc_q(x, y, sched: MRSchedule, cfg: FixedConfig):
    """Fixed-point linear vectoring. Result z in cfg.zfmt codes."""
    return eng.vector_q(x, y, sched.division, cfg)


def tanh_mr_q(z_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point tanh(z) for |z| <= 0.5. In/out: cfg.fmt codes."""
    c, s, _ = mr_hrc_q(z_q, sched, cfg)
    t = r2_lvc_q(c, s, sched, cfg)
    # Requantize z-format -> datapath format (only if guard bits in use).
    if cfg.z_guard:
        t = fp.shr(t, cfg.z_guard, cfg.fmt, rounding=cfg.out_round)
    return t


def sigmoid_mr_q(x_q, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED):
    """Fixed-point sigmoid(x) for |x| <= 1. In/out: cfg.fmt codes.

    sigma = 1/2 + 1/2 * tanh(x/2): both the input halving and the output
    scale are single right-shifts; the offset is one add of a constant.
    """
    z = fp.shr(x_q, 1, cfg.fmt, rounding=cfg.shift_round)     # x/2
    t = tanh_mr_q(z, sched, cfg)
    half = jnp.int32(1 << (cfg.fmt.frac_bits - 1))            # 0.5 in fmt
    t2 = fp.shr(t, 1, cfg.fmt, rounding=cfg.out_round)        # tanh/2
    return fp.add(half, t2, cfg.fmt)


# --------------------------------------------------------------------------
# Public float-in/float-out fixed-point wrappers
# --------------------------------------------------------------------------
def sigmoid_fixed(x, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
                  clamp: bool = True):
    """float -> Q2.14 -> MR-HRC sigmoid -> float. Domain |x| <= 1 (clamped)."""
    if clamp:
        x = jnp.clip(x, -1.0, 1.0)
    xq = fp.quantize(x, cfg.fmt)
    yq = sigmoid_mr_q(xq, sched, cfg)
    return fp.dequantize(yq, cfg.fmt).astype(x.dtype)


def tanh_fixed(z, sched: MRSchedule = PAPER_SCHEDULE, cfg: FixedConfig = PAPER_FIXED,
               clamp: bool = True):
    """float -> Q2.14 -> MR-HRC tanh -> float. Domain |z| <= 0.5 (clamped)."""
    if clamp:
        z = jnp.clip(z, -0.5, 0.5)
    zq = fp.quantize(z, cfg.fmt)
    tq = tanh_mr_q(zq, sched, cfg)
    return fp.dequantize(tq, cfg.fmt).astype(z.dtype)


# --------------------------------------------------------------------------
# Introspection helpers (tests & benchmarks)
# --------------------------------------------------------------------------
def r2_residual_f(z, sched: MRSchedule = PAPER_SCHEDULE):
    """|residual angle| after the radix-2 stage only (float)."""
    x = jnp.full_like(z, sched.x0)
    y = jnp.zeros_like(z)
    _, _, zr = r2_hrc_f(x, y, z, sched.r2_js)
    return jnp.abs(zr)


def shift_add_op_count(sched: MRSchedule = PAPER_SCHEDULE) -> dict:
    """Static resource model: adds/shifts/compares per evaluation (Table-1 analog).

    Counts follow the architecture figures: R2-HRC stages use 3 adders
    (x, y, z) and 2 barrel-less fixed shifts; R4-HRC adds the 5-way digit
    mux (2 compares); LVC uses 2 adders + 1 shift; the output stage is one
    add + two shifts; input stage one shift.
    """
    n_r2, n_r4, n_lvc = len(sched.r2_js), len(sched.r4_js), len(sched.lvc_js)
    adds = 3 * n_r2 + 3 * n_r4 + 2 * n_lvc + 1
    shifts = 2 * n_r2 + 2 * n_r4 + 1 * n_lvc + 3
    compares = 1 * n_r2 + 4 * n_r4 + 1 * n_lvc
    rom_bits = (n_r2 + 2 * n_r4) * 16
    return dict(adds=adds, shifts=shifts, compares=compares,
                rom_bits=rom_bits, iterations=sched.num_iterations(),
                multipliers=0, dividers=0, dsp=0)
