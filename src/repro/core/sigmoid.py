"""Sigmoid/tanh evaluators: the paper's MR-HRC pipeline plus the baseline
families it compares against in Table 2 (piecewise-linear, piecewise-poly2,
LUT, Taylor, conventional radix-2 CORDIC).

All baselines are implemented at the same 16-bit fixed-point budget so the
accuracy comparison (benchmarks/accuracy.py) is apples-to-apples, mirroring
the paper's methodology.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fixed_point as fp
from repro.core.cordic import (
    FixedConfig,
    MRSchedule,
    PAPER_FIXED,
    PAPER_SCHEDULE,
    R2_BASELINE_SCHEDULE,
    sigmoid_fixed,
    sigmoid_mr_f,
    tanh_fixed,
    tanh_mr_f,
)

# --------------------------------------------------------------------------
# Reference + paper implementations
# --------------------------------------------------------------------------

def sigmoid_exact(x):
    return jax.nn.sigmoid(x)


def tanh_exact(x):
    return jnp.tanh(x)


def sigmoid_cordic_float(x, sched: MRSchedule = PAPER_SCHEDULE, clamp: bool = True):
    """MR-HRC sigmoid in float arithmetic (algorithmic error only)."""
    if clamp:
        x = jnp.clip(x, -1.0, 1.0)
    return sigmoid_mr_f(x, sched)


def sigmoid_cordic_fixed(x, sched: MRSchedule = PAPER_SCHEDULE,
                         cfg: FixedConfig = PAPER_FIXED, clamp: bool = True):
    """The paper's implementation: 16-bit Q2.14 MR-HRC + R2-LVC."""
    return sigmoid_fixed(x, sched, cfg, clamp=clamp)


def tanh_cordic_float(z, sched: MRSchedule = PAPER_SCHEDULE, clamp: bool = True):
    if clamp:
        z = jnp.clip(z, -0.5, 0.5)
    return tanh_mr_f(z, sched)


def tanh_cordic_fixed(z, sched: MRSchedule = PAPER_SCHEDULE,
                      cfg: FixedConfig = PAPER_FIXED, clamp: bool = True):
    return tanh_fixed(z, sched, cfg, clamp=clamp)


def sigmoid_r2_cordic_fixed(x, cfg: FixedConfig = PAPER_FIXED, clamp: bool = True):
    """Conventional pure radix-2 hyperbolic CORDIC baseline ([9]-family):
    j=2..14 with the textbook repeated iterations, same 16-bit datapath."""
    return sigmoid_fixed(x, R2_BASELINE_SCHEDULE, cfg, clamp=clamp)


# --------------------------------------------------------------------------
# Range extension beyond the paper's |x| <= 1 contract
# --------------------------------------------------------------------------
def sigmoid_cordic_wide(x, sched: MRSchedule = PAPER_SCHEDULE,
                        cfg: FixedConfig = PAPER_FIXED, max_doublings: int = 3):
    """Beyond-paper range extension to |x| <= 2^max_doublings.

    Uses the dyadic identity  sigma(2a) = s^2 / (s^2 + (1-s)^2)  with
    s = sigma(a) — evaluated here in float on top of the fixed-point core —
    applied k times where k = ceil(log2(|x|)). For |x| <= 1 this is exactly
    the paper pipeline (k = 0). Keeps worst-case error bounded while covering
    the pre-activation ranges seen inside LM blocks.
    """
    ax = jnp.abs(x)
    k = jnp.clip(jnp.ceil(jnp.log2(jnp.maximum(ax, 1e-30))), 0, max_doublings)
    scale = jnp.exp2(-k)
    s = sigmoid_cordic_fixed(x * scale, sched, cfg, clamp=True)
    for i in range(max_doublings):
        apply = k > i
        s2 = jnp.square(s)
        doubled = s2 / jnp.maximum(s2 + jnp.square(1.0 - s), 1e-12)
        s = jnp.where(apply, doubled, s)
    return s


# --------------------------------------------------------------------------
# Baseline families (paper Table 1/2 comparison points)
# --------------------------------------------------------------------------
def _quant_out(y, fmt=fp.Q2_14):
    """Quantize a baseline's output to the same 16-bit output format."""
    return fp.dequantize(fp.quantize(y, fmt), fmt)


def _np_quant(a: np.ndarray, fmt=fp.Q2_14) -> np.ndarray:
    """Pure-numpy table quantization (trace-safe constant prep)."""
    q = np.clip(np.round(a * fmt.scale), fmt.min_int, fmt.max_int)
    return (q / fmt.scale).astype(np.float32)


def sigmoid_pwl_fixed(x, segments: int = 16, lo: float = -1.0, hi: float = 1.0):
    """Piecewise-linear approximation ([7]/[11]-family): uniform segments,
    16-bit quantized slope/intercept tables and output."""
    fmt = fp.Q2_14
    edges = np.linspace(lo, hi, segments + 1)
    xs = (edges[:-1] + edges[1:]) / 2.0
    x0, x1 = edges[:-1], edges[1:]
    y0 = 1.0 / (1.0 + np.exp(-x0))
    y1 = 1.0 / (1.0 + np.exp(-x1))
    slope = (y1 - y0) / (x1 - x0)
    icept = y0 - slope * x0
    slope_q = _np_quant(slope, fmt)
    icept_q = _np_quant(icept, fmt)
    xc = jnp.clip(x, lo, hi)
    idx = jnp.clip(((xc - lo) / (hi - lo) * segments).astype(jnp.int32), 0, segments - 1)
    y = jnp.take(jnp.asarray(slope_q), idx) * xc + jnp.take(jnp.asarray(icept_q), idx)
    return _quant_out(y)


def sigmoid_poly2_fixed(x, segments: int = 8, lo: float = -1.0, hi: float = 1.0):
    """Piecewise 2nd-degree polynomial ([2]/[8]-family), least-squares fit
    per segment, 16-bit coefficient/output quantization."""
    fmt = fp.Q2_14
    edges = np.linspace(lo, hi, segments + 1)
    coefs = []
    for a, b in zip(edges[:-1], edges[1:]):
        xs = np.linspace(a, b, 64)
        ys = 1.0 / (1.0 + np.exp(-xs))
        c = np.polyfit(xs, ys, 2)
        coefs.append(c)
    coefs = np.asarray(coefs)  # (segments, 3) highest-first
    coefs_q = _np_quant(coefs, fmt)
    xc = jnp.clip(x, lo, hi)
    idx = jnp.clip(((xc - lo) / (hi - lo) * segments).astype(jnp.int32), 0, segments - 1)
    c2 = jnp.take(jnp.asarray(coefs_q[:, 0]), idx)
    c1 = jnp.take(jnp.asarray(coefs_q[:, 1]), idx)
    c0 = jnp.take(jnp.asarray(coefs_q[:, 2]), idx)
    y = (c2 * xc + c1) * xc + c0
    return _quant_out(y)


def sigmoid_lut_fixed(x, entries: int = 256, lo: float = -1.0, hi: float = 1.0):
    """Direct lookup table ([10]-family): nearest-entry LUT, 16-bit outputs."""
    fmt = fp.Q2_14
    grid = np.linspace(lo, hi, entries)
    tab = 1.0 / (1.0 + np.exp(-grid))
    tab_q = _np_quant(tab, fmt)
    xc = jnp.clip(x, lo, hi)
    idx = jnp.clip(jnp.round((xc - lo) / (hi - lo) * (entries - 1)).astype(jnp.int32),
                   0, entries - 1)
    return jnp.take(jnp.asarray(tab_q), idx)


def sigmoid_taylor_fixed(x, order: int = 5):
    """Maclaurin expansion of sigmoid ([2]-family Taylor variant):
    sigma(x) ~= 1/2 + x/4 - x^3/48 + x^5/480, 16-bit quantized."""
    c = {1: 0.25, 3: -1.0 / 48.0, 5: 1.0 / 480.0, 7: -17.0 / 80640.0}
    xc = jnp.clip(x, -1.0, 1.0)
    y = jnp.full_like(xc, 0.5)
    p = xc
    for k in (1, 3, 5, 7):
        if k > order:
            break
        y = y + c[k] * p
        p = p * xc * xc
    return _quant_out(y)


#: Registry used by the accuracy benchmark (paper Table 2 reproduction).
TABLE2_METHODS = {
    "proposed_mr_hrc_q2.14": lambda x: sigmoid_cordic_fixed(x),
    "r2_cordic_q2.14 [9]": lambda x: sigmoid_r2_cordic_fixed(x),
    "pwl_16seg [7]/[11]": lambda x: sigmoid_pwl_fixed(x, 16),
    "pwl_8seg [11]": lambda x: sigmoid_pwl_fixed(x, 8),
    "poly2_8seg [2]/[8]": lambda x: sigmoid_poly2_fixed(x, 8),
    "lut_256 [10]": lambda x: sigmoid_lut_fixed(x, 256),
    "lut_64 [10]": lambda x: sigmoid_lut_fixed(x, 64),
    "taylor_o5 [2]": lambda x: sigmoid_taylor_fixed(x, 5),
    "mr_hrc_float (algorithmic)": lambda x: sigmoid_cordic_float(x),
}
