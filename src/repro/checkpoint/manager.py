"""Checkpointing: async save, atomic manifest commit, elastic restore.

Layout per checkpoint:
    <dir>/step_<N>/
        manifest.json      — tree structure, dtypes/shapes, mesh snapshot,
                             data-iterator state, committed last (atomic).
        arrays.npz         — flattened leaves keyed by tree path.

Fault-tolerance properties:
* a checkpoint is valid iff its manifest exists ("commit record"); writers
  stage under `.tmp-<N>` and rename, so a crash mid-save never corrupts the
  latest valid checkpoint;
* `latest_step` ignores uncommitted/partial directories;
* restore works onto a *different* mesh ("elastic"): arrays are loaded
  replicated and re-sharded by `jax.device_put` with the new shardings —
  on a real multi-host cluster the same manifest drives per-host shard
  reads, here the single-process path exercises the logic end to end;
* `AsyncCheckpointer` overlaps serialization with the next train steps and
  `wait()`s before the process exits or before saving again (bounded queue
  of 1 — same discipline as Orbax async).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def tree_paths(tree):
    return list(_flatten_with_paths(tree).keys())


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         extra: Optional[dict] = None) -> str:
    """Synchronous checkpoint write with atomic commit."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-{step:08d}-{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Dict[str, Any],
            shardings=None) -> (Dict[str, Any], dict):
    """Restore into the structure of `like`; optionally re-shard (elastic).

    `shardings`: optional pytree (same structure) of NamedShardings for the
    *current* mesh — arrays are device_put with them, so a checkpoint taken
    on one mesh restores onto another.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    ref = _flatten_with_paths(like)
    missing = set(ref) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    shard_flat = _flatten_with_paths(shardings) if shardings is not None else None
    out = {}
    for k, leaf in ref.items():
        arr = jnp.asarray(data[k], dtype=leaf.dtype)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {leaf.shape}")
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[k])
        out[k] = arr

    # unflatten back into the reference structure
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            for path_, _ in leaves_ref]
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), [out[k] for k in keys])
    return restored, manifest["extra"]


class AsyncCheckpointer:
    """Background-thread checkpoint writer (queue depth 1)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state, extra=None) -> None:
        self.wait()
        # snapshot to host memory before handing to the thread
        host_state = jax.tree.map(lambda a: np.asarray(a), state)

        def run():
            try:
                save(self.ckpt_dir, step, host_state, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
