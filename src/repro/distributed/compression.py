"""Int8 error-feedback gradient compression.

Distributed-optimization trick for bandwidth-bound data-parallel training:
gradients are quantized to int8 with a per-tensor scale before the
data-parallel reduction; the quantization residual is carried in an error-
feedback buffer and added back the next step (EF-SGD / 1-bit-Adam lineage),
which keeps convergence unbiased to first order.

Under pjit the DP all-reduce is implicit in the backward pass, so the
compression is exposed two ways:

* `compress_grads` — quantize->dequantize with error feedback applied to the
  gradient pytree right before the optimizer (models the end-to-end numerics
  of a compressed reduction; what the trainer flag uses);
* `psum_compressed` — an explicit shard_map collective (int8 payload, int32
  accumulation) for runtimes that own their reductions; validated in tests
  against the uncompressed psum.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_grads(grads, err_state):
    """Error-feedback int8 round trip. Returns (grads', new_err_state)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, err_state)
    g2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2


def psum_compressed(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce with int8 payload / int32 accumulation (inside shard_map).

    The per-shard scale is max-reduced first so all shards share one scale
    (one tiny f32 all-reduce + one int32 all-reduce of the payload).
    """
    n = jax.lax.psum(1, axis_name)
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale / n
