"""Logical-axis -> mesh-axis sharding rules.

Model code annotates every parameter with logical axis names (models/common.P);
this module turns those into NamedShardings for a concrete mesh:

    RULES (ordered; first applicable wins, one mesh axis used at most once
    per tensor, divisibility checked — e.g. kv_heads=4 on a 16-way model
    axis falls back to replicated rather than failing):

        vocab    -> model        (embedding/logits vocab-parallel)
        mlp      -> model        (FFN tensor-parallel)
        heads    -> model        (attention head-parallel)
        kv_heads -> model        (when divisible)
        experts  -> model        (expert parallelism)
        embed    -> None         (d_model replicated across model axis)
        layers   -> None         (scan dim)

Batch/activation sharding: batch -> ("pod","data") when divisible; for
batch=1 long-context decode the *sequence* dim of activations/caches is
sharded over the data axis instead (sequence parallelism).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

#: logical axis -> candidate mesh axis (None = replicate)
DEFAULT_RULES: Tuple[Tuple[str, Optional[str]], ...] = (
    ("vocab", "model"),
    ("mlp", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("experts", "model"),
    ("embed", None),
    ("layers", None),
)


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  mesh: Mesh, rules=DEFAULT_RULES) -> PS:
    """Build a PartitionSpec for one tensor, enforcing divisibility and
    one-use-per-mesh-axis."""
    rule_map = dict(rules)
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        target = rule_map.get(ax) if ax is not None else None
        if (target is not None and target in mesh.shape and target not in used
                and dim % _mesh_axis_size(mesh, target) == 0):
            parts.append(target)
            used.add(target)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return PS(*parts)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=DEFAULT_RULES):
    """Map spec/axes trees -> NamedSharding tree (same structure)."""
    def one(axes, like):
        return NamedSharding(mesh, spec_for_axes(tuple(axes), tuple(like.shape),
                                                 mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(mesh: Mesh, global_batch: int, seq_len: int,
               extra_dims: int = 0) -> PS:
    """Sharding for (B, S, ...) activations/inputs.

    Prefers batch over ("pod","data"); falls back to sequence sharding
    (SP) for small batches (long-context decode with batch=1).
    """
    dp_axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if global_batch % dp == 0 and global_batch >= dp:
        return PS(tuple(dp_axes), *([None] * (1 + extra_dims)))
    if seq_len % dp == 0:
        return PS(None, tuple(dp_axes), *([None] * extra_dims))
    return PS()


def cache_spec(mesh: Mesh, batch: int, seq_len: int, kv_heads: int) -> dict:
    """Shardings for KV-cache-like (B,S,KH,D) buffers: batch->data when
    divisible, else sequence->data (SP); kv heads->model when divisible."""
    dp_axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    model = mesh.shape.get("model", 1)
    kh = "model" if (kv_heads % model == 0) else None
    if batch % dp == 0 and batch >= dp:
        return {"batch_axis": tuple(dp_axes), "seq_axis": None, "kv_axis": kh}
    return {"batch_axis": None, "seq_axis": tuple(dp_axes), "kv_axis": kh}
