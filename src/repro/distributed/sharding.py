"""Logical-axis -> mesh-axis sharding rules.

Model code annotates every parameter with logical axis names (models/common.P);
this module turns those into NamedShardings for a concrete mesh:

    RULES (ordered; first applicable wins, one mesh axis used at most once
    per tensor, divisibility checked — e.g. kv_heads=4 on a 16-way model
    axis falls back to replicated rather than failing):

        vocab    -> model        (embedding/logits vocab-parallel)
        mlp      -> model        (FFN tensor-parallel)
        heads    -> model        (attention head-parallel)
        kv_heads -> model        (when divisible)
        experts  -> model        (expert parallelism)
        embed    -> None         (d_model replicated across model axis)
        layers   -> None         (scan dim)

Batch/activation sharding: batch -> ("pod","data") when divisible; for
batch=1 long-context decode the *sequence* dim of activations/caches is
sharded over the data axis instead (sequence parallelism).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

#: logical axis -> candidate mesh axis (None = replicate)
DEFAULT_RULES: Tuple[Tuple[str, Optional[str]], ...] = (
    ("vocab", "model"),
    ("mlp", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("experts", "model"),
    ("embed", None),
    ("layers", None),
)


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  mesh: Mesh, rules=DEFAULT_RULES) -> PS:
    """Build a PartitionSpec for one tensor, enforcing divisibility and
    one-use-per-mesh-axis."""
    rule_map = dict(rules)
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        target = rule_map.get(ax) if ax is not None else None
        if (target is not None and target in mesh.shape and target not in used
                and dim % _mesh_axis_size(mesh, target) == 0):
            parts.append(target)
            used.add(target)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return PS(*parts)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=DEFAULT_RULES):
    """Map spec/axes trees -> NamedSharding tree (same structure)."""
    def one(axes, like):
        return NamedSharding(mesh, spec_for_axes(tuple(axes), tuple(like.shape),
                                                 mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(mesh: Mesh, global_batch: int, seq_len: int,
               extra_dims: int = 0) -> PS:
    """Sharding for (B, S, ...) activations/inputs.

    Prefers batch over ("pod","data"); falls back to sequence sharding
    (SP) for small batches (long-context decode with batch=1).
    """
    dp_axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if global_batch % dp == 0 and global_batch >= dp:
        return PS(tuple(dp_axes), *([None] * (1 + extra_dims)))
    if seq_len % dp == 0:
        return PS(None, tuple(dp_axes), *([None] * extra_dims))
    return PS()


def cache_spec(mesh: Mesh, batch: int, seq_len: int, kv_heads: int) -> dict:
    """Shardings for KV-cache-like (B,S,KH,D) buffers: batch->data when
    divisible, else sequence->data (SP); kv heads->model when divisible."""
    dp_axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    model = mesh.shape.get("model", 1)
    kh = "model" if (kv_heads % model == 0) else None
    if batch % dp == 0 and batch >= dp:
        return {"batch_axis": tuple(dp_axes), "seq_axis": None, "kv_axis": kh}
    return {"batch_axis": None, "seq_axis": tuple(dp_axes), "kv_axis": kh}


# ---------------------------------------------------------------------------
# Ambient serving mesh
#
# Model code (models/attention.py, models/transformer.py) is traced from
# inside ServeEngine's jits and must not take a mesh argument — the cfg
# dataclass is hashed into jit cache keys and a Mesh is not a config. The
# engine instead *enters* `serving_mesh(mesh)` around every trace/dispatch,
# and the model reads `active_serving_mesh()` at trace time to decide
# whether to emit sharding constraints / shard_map attention. Thread-local
# so concurrent engines on different meshes can't cross-talk.
# ---------------------------------------------------------------------------

_SERVING_MESH = threading.local()


def active_serving_mesh() -> Optional[Mesh]:
    """The mesh entered by the innermost `serving_mesh(...)`, or None."""
    return getattr(_SERVING_MESH, "mesh", None)


@contextlib.contextmanager
def serving_mesh(mesh: Optional[Mesh]):
    """Make `mesh` visible to model code traced inside this block."""
    prev = getattr(_SERVING_MESH, "mesh", None)
    _SERVING_MESH.mesh = mesh
    try:
        yield mesh
    finally:
        _SERVING_MESH.mesh = prev


def serve_param_shardings(cfg, params, mesh: Mesh):
    """NamedSharding tree for serving params on a ("data","model") mesh.

    The Megatron-style cut via DEFAULT_RULES (wq/wk/wv head-parallel,
    FFN column/row-parallel, untied lm_head vocab-parallel — the vocab
    cut is what makes the single logits all-gather the *only* gather in
    a decode step), with one serving-specific override: the embedding
    table is forced replicated. `jnp.take(table, tokens)` on a
    vocab-sharded table would lower to a collective inside the datapath;
    a replicated table keeps the embed lookup shard-local and costs only
    vocab*d_model bytes per device. Tied-embeddings models therefore
    replicate the head too (documented carve-out: zero all-gathers —
    logits are computed replicated from replicated weights).
    """
    from repro.models import common as cm
    from repro.models import transformer as _tf

    axes = cm.param_axes(_tf.model_spec(cfg))
    sh = tree_shardings(axes, params, mesh)
    repl = NamedSharding(mesh, PS())
    if "embed" in sh:
        sh["embed"] = jax.tree.map(lambda _: repl, sh["embed"])
    return sh


#: cache-tree leaf names whose dim -2 is the kv-head axis (dense stacked
#: (slots,1,S,KH,hd), per-slot (1,S,KH,hd), paged pools (N,L,KH,hd), and
#: the quantized pools' per-block scale tensors (N,1,KH,1) — with or
#: without a leading stacked-layer axis, -2 is always KH, so the scale
#: shards travel with the head slice whose codes they dequantize).
_KV_HEAD_LEAVES = ("k", "v", "k_pool", "v_pool",
                   "k_scale_pool", "v_scale_pool")


def kv_cache_shardings(cache, mesh: Mesh, rules=DEFAULT_RULES):
    """NamedSharding tree for a serve cache (dense or paged), same structure.

    k/v buffers and paged k/v pools shard their head axis (dim -2) over
    the model axis via the "kv_heads" rule — divisibility fallback to
    replicated comes for free from spec_for_axes. Everything else (block
    tables, lens, cur_idx, MLA latent `c_kv_pool`/`k_rope_pool`,
    recurrent state) is replicated: tables/lens are scalar-prefetched
    host metadata, and the MLA latent is per-slot-small + needed whole
    by every head shard.
    """
    repl = NamedSharding(mesh, PS())

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _KV_HEAD_LEAVES and getattr(leaf, "ndim", 0) >= 2:
            axes = [None] * leaf.ndim
            axes[-2] = "kv_heads"
            return NamedSharding(
                mesh, spec_for_axes(tuple(axes), tuple(leaf.shape), mesh,
                                    rules))
        return repl

    return jax.tree_util.tree_map_with_path(one, cache)
