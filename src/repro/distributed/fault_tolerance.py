"""Fault-tolerance runtime pieces: straggler detection, heartbeats, and a
failure-injection harness used by tests and the training loop.

On a real cluster these hooks drive actuation (reassigning a slice,
re-sharding around a dead host, triggering elastic restart); in this
container the detection logic, the restart-from-checkpoint path, and the
elastic re-shard are all exercised for real, while actuation is logged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor with z-score flagging.

    A step is a straggler candidate if it exceeds mean + threshold*std of
    the exponentially-weighted history (warmup-protected).
    """

    alpha: float = 0.1
    threshold: float = 3.0
    warmup: int = 10
    min_rel_excess: float = 0.5   # must also exceed mean by 50% (guards std~0)
    _mean: float = 0.0
    _m2: float = 0.0              # Welford M2 during warmup
    _var: float = 0.0             # EWMA variance after warmup
    _n: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            # prime the statistics (Welford)
            d = dt - self._mean
            self._mean += d / self._n
            self._m2 += d * (dt - self._mean)
            if self._n == self.warmup:
                self._var = self._m2 / max(self.warmup - 1, 1)
            return False
        std = max(self._var ** 0.5, 1e-9)
        is_straggler = (dt > self._mean + self.threshold * std
                        and dt > self._mean * (1 + self.min_rel_excess))
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "mean": self._mean,
                                "std": std, "time": time.time()})
        else:
            # EWMA update (straggler samples excluded so they don't poison it)
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
            self._var = (1 - self.alpha) * self._var \
                + self.alpha * (dt - self._mean) ** 2
        return is_straggler


class HeartbeatMonitor:
    """Tracks per-host heartbeats; hosts silent for > timeout are dead.

    The trainer calls `beat(host)` every step (in a multi-process runtime
    each host beats for itself via the coordination service); `dead()`
    feeds the recovery policy (restore-from-checkpoint on a shrunk mesh).
    """

    def __init__(self, timeout_s: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[str, float] = {}

    def beat(self, host: str) -> None:
        self.last[host] = self.clock()

    def dead(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]


class FailureInjector:
    """Deterministic fault injection for tests/examples: raises
    `InjectedFailure` when the trainer reaches a scheduled step."""

    class InjectedFailure(RuntimeError):
        pass

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.InjectedFailure(f"injected failure at step {step}")
