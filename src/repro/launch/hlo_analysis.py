"""HLO-level analysis for the dry-run: collective-byte accounting and
roofline terms.

The compiled module is SPMD (per-device shapes), so every parsed byte count
is *per chip*. Roofline terms (TPU v5e targets):

    compute   = flops_per_chip / 197e12        [bf16 MXU peak]
    memory    = bytes_per_chip / 819e9         [HBM bandwidth]
    collective= sum(factor_op * bytes_op) / 50e9   [per-link ICI]

factor: all-reduce moves 2x its buffer through each chip (reduce+broadcast
phases of a ring), all-gather / reduce-scatter / all-to-all move ~1x
((n-1)/n ~ 1), collective-permute exactly 1x.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

# e.g.:  %all-gather.12 = bf16[4,1024,128]{2,1,0} all-gather(...)
#        ROOT %t = (f32[8,16]{...}, f32[8]{...}) tuple(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
    r"|ragged-all-to-all)(?:-start|-done)?\(",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip collective traffic by op kind, plus the weighted total.

    `-done` ops are skipped (the `-start` carries the shape) to avoid double
    counting async pairs; sync ops appear once anyway.
    """
    per_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims)
        per_kind[kind] += b
        counts[kind] += 1
    weighted = sum(_COLL_FACTOR[k] * v for k, v in per_kind.items())
    return {"per_kind_bytes": dict(per_kind), "op_counts": dict(counts),
            "weighted_bytes": weighted}


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   coll_weighted_bytes: float) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = hbm_bytes_per_chip / HBM_BW
    collective = coll_weighted_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["bound_s"] = bound
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {}
    for k in ("flops", "bytes accessed", "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # per-memory-space byte entries if present
    for k, v in ca.items():
        if isinstance(k, str) and k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out
