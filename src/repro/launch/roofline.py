"""Roofline report generator: reads dry-run JSONs and emits the
EXPERIMENTS.md section Roofline table (single-pod mesh, per spec).

    PYTHONPATH=src python -m repro.launch.roofline --glob 'results/dryrun_*.json'

Per (arch x shape): the three terms in seconds, the dominant bottleneck,
MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPS, and a one-line lever on the
dominant term.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


LEVERS = {
    ("memory_s", "train"): "less remat recompute / bf16 master-weight IO / "
                           "fused attention kernel keeps scores in VMEM",
    ("memory_s", "prefill"): "KV-cache layout + flash-style fusion (scores "
                             "never round-trip HBM)",
    ("memory_s", "decode"): "batch up decode (cache reads amortize) / "
                            "quantize KV cache to int8",
    ("compute_s", "train"): "already MXU-bound: raise per-chip batch or shrink "
                            "remat to trade memory for fewer recompute FLOPs",
    ("compute_s", "prefill"): "MXU-bound: good; tune attention chunking",
    ("compute_s", "decode"): "decode should not be compute-bound: check MLA "
                             "absorbed-path einsum order",
    ("collective_s", "train"): "shard logits/embedding differently; overlap "
                               "grad all-reduce with backward (microbatch)",
    ("collective_s", "prefill"): "re-shard activations: keep TP collectives "
                                 "per-layer not per-token",
    ("collective_s", "decode"): "replicate small KV (skip gather) / move "
                                "vocab-parallel logits all-gather off-path",
}


def load(paths):
    recs = []
    for p in paths:
        with open(p) as f:
            recs.extend(json.load(f))
    return recs


def fmt_row(r):
    t = r["roofline"]
    mf = r["model_flops_per_chip"]
    ratio = r.get("useful_flops_ratio")
    lever = LEVERS.get((t["dominant"], r["kind"]), "")
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} "
            f"| {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| {t['dominant'].replace('_s', '')} "
            f"| {mf:.3e} | {ratio:.3f} | {lever} |" if ratio is not None else "")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--glob", default="results/dryrun_*.json")
    ap.add_argument("--multi-pod", action="store_true",
                    help="table for the 2x16x16 mesh instead (default 16x16)")
    ap.add_argument("--md-out", default=None)
    args = ap.parse_args(argv)

    recs = load(sorted(glob.glob(args.glob)))
    rows = [r for r in recs if r.get("status") == "ok"
            and r.get("multi_pod") == args.multi_pod]
    skips = [r for r in recs if r.get("status") == "skipped"
             and r.get("multi_pod") == args.multi_pod]
    errs = [r for r in recs if r.get("status") == "error"]

    lines = []
    mesh = "2x16x16 (512 chips)" if args.multi_pod else "16x16 (256 chips)"
    lines.append(f"Mesh: {mesh}. Terms are seconds/step per chip "
                 f"(197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI link).")
    lines.append("")
    lines.append("| arch | shape | compute (s) | memory (s) | collective (s) "
                 "| dominant | MODEL_FLOPS/chip | useful ratio | lever on dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order[r["shape"]])):
        lines.append(fmt_row(r))
    lines.append("")
    for r in sorted(skips, key=lambda r: (r["arch"], order[r["shape"]])):
        lines.append(f"* skipped: {r['arch']} x {r['shape']} — {r['reason']}")
    for r in errs:
        lines.append(f"* ERROR: {r['arch']} x {r['shape']} "
                     f"(multi_pod={r['multi_pod']}) — {r['error']}")
    out = "\n".join(lines)
    print(out)
    if args.md_out:
        with open(args.md_out, "w") as f:
            f.write(out + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
