"""§Perf hillclimb harness: re-lower a cell under named knob variants and
report the roofline-term deltas vs the paper-faithful baseline.

    PYTHONPATH=src python -m repro.launch.perf --cell yi-9b:decode_32k \
        --variant baseline --variant bf16_scores --out results/perf_yi.json

Each variant is a named dict of lower_cell kwargs; EXPERIMENTS.md §Perf
narrates the hypothesis → change → before/after for each.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

VARIANTS = {
    # paper-faithful framework defaults
    "baseline": {},
    # H1: kill the f32 cache copy; accumulate scores in f32 on the MXU
    "bf16_scores": {"score_dtype": "bf16_mxu"},
    # H2: flash-decode SP — shard KV cache seq dim over the model axis
    "kv_seq_shard": {"score_dtype": "bf16_mxu", "kv_shard": "seq_model"},
    "kv_seq_shard_f32": {"kv_shard": "seq_model"},
    # train-side knobs
    "no_remat": {"remat": "none"},
    "remat_dots": {"remat": "dots"},
    "accum4": {"accum": 4},
    "accum4_dots": {"accum": 4, "remat": "dots"},
    "zero1": {"zero1": True},
    # H3: MaxText-style head padding -> awkward head counts become 16-way
    # TP-shardable (kills replicated attention projections / cache reads)
    "pad_heads": {"pad_heads_to": 16},
    "pad_heads_bf16": {"pad_heads_to": 16, "score_dtype": "bf16_mxu"},
    "pad_heads_full": {"pad_heads_to": 16, "score_dtype": "bf16_mxu",
                       "kv_shard": "seq_model"},
    "zero1_dots": {"zero1": True, "remat": "dots"},
    "chunk512": {"attn_chunk": 512},
    "chunk8k": {"attn_chunk": 8192},
    # activation implementation comparison (paper technique vs exact)
    "act_exact": {"act_impl": "exact"},
    "act_pallas": {"act_impl": "cordic_pallas"},
    "act_float": {"act_impl": "cordic_float"},
    # H4: replicate the sLSTM recurrent state across TP (xLSTM-specific):
    # trade tiny redundant compute for zero per-timestep collectives
    "slstm_rep": {"slstm_state": "replicated"},
    "mlstm_chunk128": {"mixer_chunk": 128},
    "mlstm_chunk64": {"mixer_chunk": 64},
    "xlstm_best": {"slstm_state": "replicated", "mixer_chunk": 128},
    "accum4_fixed": {"accum": 4},
    "slstm_rep_dots": {"slstm_state": "replicated", "remat": "dots"},
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", required=True,
                    choices=list(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.launch.dryrun import lower_cell  # sets XLA_FLAGS on import

    arch, shape = args.cell.split(":")
    results = []
    base_terms = None
    for name in args.variant:
        kw = VARIANTS[name]
        try:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod, **kw)
            rec["variant"] = name
            rec["knobs"] = kw
            t = rec["roofline"]
            line = (f"[perf] {arch}:{shape} {name:18s} "
                    f"compute {t['compute_s']:.3e}  memory {t['memory_s']:.3e}  "
                    f"coll {t['collective_s']:.3e}  dom={t['dominant']}")
            if base_terms is None and name == "baseline":
                base_terms = t
            elif base_terms is not None:
                d = base_terms[base_terms["dominant"]]
                n = t[base_terms["dominant"]]
                line += f"  [dominant-term delta vs baseline: {100 * (1 - n / d):+.1f}%]"
            print(line)
        except Exception as e:
            rec = {"variant": name, "arch": arch, "shape": shape,
                   "status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-1500:]}
            print(f"[perf] {arch}:{shape} {name}: FAILED {e!r}")
        results.append(rec)
        sys.stdout.flush()

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
