"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Runs the fault-tolerant training loop (train/loop.py) for any registered
architecture. `--smoke` selects the reduced config (CPU-runnable); the full
configs are for real accelerator meshes — their distribution plan is
validated by `repro.launch.dryrun`.

On a multi-host cluster this same entry point is started once per host
(jax.distributed.initialize picks up the coordinator from the environment);
the data pipeline shards by process index and the checkpoint manager writes
per-host shards.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro import configs
from repro.optim import adamw
from repro.train import loop as loop_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--act-impl", default="cordic_fixed")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch, act_impl=args.act_impl) if args.smoke
           else configs.get_config(args.arch, act_impl=args.act_impl))
    if cfg.input_mode != "tokens":
        cfg = dataclasses.replace(cfg, input_mode="tokens")
    print(f"[train] arch={cfg.name} params={cfg.param_counts()['total'] / 1e6:.1f}M "
          f"act={cfg.act_impl} compress={args.compress}")

    lc = loop_lib.LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                             ckpt_dir=args.ckpt_dir, accum=args.accum,
                             compress=args.compress)
    out = loop_lib.run(cfg, lc, opt_cfg=adamw.AdamWConfig(lr=args.lr))
    print(f"[train] final loss {out['final_loss']:.4f} after "
          f"{len(out['history'])} steps; restarts={out['restarts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
