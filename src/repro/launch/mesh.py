"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init;
smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the two-pod (512-chip) dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
