"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init;
smoke tests and benches see the real single device.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the two-pod (512-chip) dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """("data","model") mesh over whatever devices exist (CPU tests /
    examples; force more with XLA_FLAGS=--xla_force_host_platform_
    device_count=N). The model axis is ``model_parallel`` wide, the data
    axis soaks up the rest."""
    n = jax.device_count()
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"device count {n} is not divisible by model_parallel="
            f"{model_parallel}; pick a tensor-parallel degree that divides "
            "the devices visible to jax (force more CPU devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def mesh_or_none(model_parallel: int = 1) -> Optional[jax.sharding.Mesh]:
    """``make_host_mesh`` for multi-shard runs, ``None`` for TP=1.

    Single-device paths must never construct a trivial mesh: a 1-wide
    mesh still commits every array to an explicit sharding, changing jit
    cache keys and forcing device_put traffic for nothing. Callers treat
    ``None`` as "stay on the legacy single-device datapath"."""
    if model_parallel in (None, 0, 1):
        return None
    return make_host_mesh(model_parallel)
