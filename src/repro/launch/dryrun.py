import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape) cell this lowers + compiles the real
step function (train_step for train shapes, serve prefill/decode for the
others) against ShapeDtypeStruct inputs on:

    * the single-pod production mesh  (16, 16)   ("data", "model")
    * the two-pod mesh               (2, 16, 16) ("pod", "data", "model")

and records memory_analysis / cost_analysis / per-chip collective bytes
(parsed from the compiled SPMD HLO) to JSON for EXPERIMENTS.md and the
roofline report.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.launch import hlo_analysis as hlo
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.serve import engine as serve_engine
from repro.train import step as step_lib

from jax.sharding import NamedSharding, PartitionSpec as PS


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               remat: str = "full", act_impl: str = "cordic_fixed",
               attn_chunk: int = 2048, score_dtype: str = "f32",
               kv_shard: str = "auto", accum: int = 1, zero1: bool = False,
               pad_heads_to: int = 0, slstm_state: str = "auto",
               mixer_chunk: int = 0, keep_hlo: bool = False) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg = configs.get_config(arch, act_impl=act_impl)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    cfg = dataclasses.replace(cfg, remat=remat, attn_chunk=attn_chunk,
                              score_dtype=score_dtype, kv_shard=kv_shard,
                              pad_heads_to=pad_heads_to,
                              slstm_state=slstm_state)
    if mixer_chunk:
        if cfg.xlstm is not None:
            cfg = dataclasses.replace(
                cfg, xlstm=dataclasses.replace(cfg.xlstm, chunk=mixer_chunk))
        if cfg.ssm is not None:
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk=mixer_chunk))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state_shapes = sp.abstract_train_state(cfg)
            state_sh = sp.state_shardings(cfg, mesh, state_shapes, zero1=zero1)
            batch_specs = sp.train_input_specs(cfg, shape)
            batch_sh = sp.batch_shardings(cfg, mesh, shape, batch_specs)
            fn = step_lib.make_train_step(cfg, adamw.AdamWConfig(), accum=accum)
            scalar = NamedSharding(mesh, PS())  # prefix: replicated metrics
            jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, scalar),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_specs)
        elif shape.kind == "prefill":
            params_shapes = sp.abstract_params(cfg, dtype=jnp.bfloat16)
            params_sh = sp.params_shardings(cfg, mesh, params_shapes)
            cache_shapes = sp.abstract_cache(cfg, shape.global_batch,
                                             shape.seq_len)
            cache_sh = sp.cache_shardings(cfg, mesh, cache_shapes, shape)
            batch_specs = sp.prefill_input_specs(cfg, shape)
            batch_sh = sp.batch_shardings(cfg, mesh, shape, batch_specs)
            fn = serve_engine.make_prefill_step(cfg)
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dpn = 1
            for a in dp:
                dpn *= mesh.shape[a]
            b_ax = dp if shape.global_batch % dpn == 0 else None
            v_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
            logits_sh = NamedSharding(mesh, PS(b_ax, v_ax))
            jitted = jax.jit(fn, in_shardings=(params_sh, cache_sh, batch_sh),
                             out_shardings=((logits_sh, cache_sh)),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes, batch_specs)
        else:  # decode
            params_shapes = sp.abstract_params(cfg, dtype=jnp.bfloat16)
            params_sh = sp.params_shardings(cfg, mesh, params_shapes)
            cache_shapes = sp.abstract_cache(cfg, shape.global_batch,
                                             shape.seq_len)
            cache_sh = sp.cache_shardings(cfg, mesh, cache_shapes, shape)
            tok_specs = sp.decode_input_specs(cfg, shape)
            tok_sh = sp.batch_shardings(cfg, mesh, shape,
                                        {"t": tok_specs})["t"]
            fn = serve_engine.make_decode_step(cfg)
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dpn = 1
            for a in dp:
                dpn *= mesh.shape[a]
            out_tok_sh = NamedSharding(
                mesh, PS(dp) if shape.global_batch % dpn == 0
                and shape.global_batch >= dpn else PS())
            jitted = jax.jit(fn, in_shardings=(params_sh, cache_sh, tok_sh),
                             out_shardings=(out_tok_sh, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes, tok_specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo_text = compiled.as_text()
    coll = hlo.collective_bytes(hlo_text)          # raw (scan-body-once)
    cost = hlo.cost_analysis_dict(compiled)        # raw XLA cost analysis
    mem = _mem_analysis_dict(compiled)

    # scan-corrected accounting (hlo_cost): while-loop trip multipliers —
    # raw cost_analysis counts a lax.scan body once (tests/test_hlo_cost.py)
    from repro.launch import hlo_cost

    corrected = hlo_cost.analyze(hlo_text)
    flops = corrected.get("flops", 0.0)
    hbm_bytes = corrected.get("hbm_bytes", 0.0)
    coll_bytes = corrected.get("collective_weighted_bytes", 0.0)
    terms = hlo.roofline_terms(flops, hbm_bytes, coll_bytes)

    pc = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = 6 * pc["active"] * tokens
    if shape.kind == "train":
        model_flops *= 1  # 6ND already includes fwd+bwd for train
    else:
        model_flops = 2 * pc["active"] * tokens  # fwd only

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": list(mesh.devices.shape), "devices": int(n_dev),
        "status": "ok", "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_chip": flops, "hbm_bytes_per_chip": hbm_bytes,
        "collective": {
            "per_kind_bytes": corrected.get("collective_bytes_by_kind", {}),
            "op_counts": corrected.get("collective_op_counts", {}),
            "weighted_bytes": coll_bytes,
        },
        "raw_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes_accessed", 0.0),
            "collective_weighted_bytes": coll["weighted_bytes"],
            "note": "XLA counts while bodies once; see hlo_cost.py",
        },
        "memory_analysis": mem,
        "roofline": terms,
        "params_total": pc["total"], "params_active": pc["active"],
        "tokens_per_step": tokens,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / n_dev,
        "useful_flops_ratio": (model_flops / n_dev) / flops if flops else None,
    }
    if keep_hlo:
        rec["hlo_lines"] = len(hlo_text.splitlines())
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--act-impl", default="cordic_fixed")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = lower_cell(arch, shape, multi_pod=mp, remat=args.remat,
                                 act_impl=args.act_impl)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[dryrun] OK   {tag}: compile {rec['compile_s']}s "
                          f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
                          f"coll {r['collective_s']:.3e}s dom={r['dominant']}")
                    if rec["memory_analysis"]:
                        print(f"         mem: {rec['memory_analysis']}")
                else:
                    print(f"[dryrun] SKIP {tag}: {rec['reason']}")
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[dryrun] FAIL {tag}: {e!r}")
            results.append(rec)
            sys.stdout.flush()

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
