"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --requests 8 --slots 4

Boots the slot-based continuous-batching engine for a registered arch
(reduced config on CPU; the full-config decode distribution is what
repro.launch.dryrun lowers for the decode shapes).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro import obs as repro_obs
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--act-impl", default="cordic_fixed")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (CORDIC datapath); 0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--kv-impl", default="dense", choices=["dense", "paged"],
                    help="decode KV layout: dense per-slot buffers or the "
                         "global block pool (serve/kv_pager.py)")
    ap.add_argument("--block-len", type=int, default=16,
                    help="positions per KV block / prefill bucket granularity")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size incl. scratch (0 = worst-case "
                         "slots*max_len/block_len + 1)")
    ap.add_argument("--paged-attend-impl", default="gather",
                    choices=["gather", "pallas"],
                    help="paged decode attend: full-table gather (dense-"
                         "shaped transient) or the block-walking Pallas "
                         "kernel (O(block_len) transient; same tokens). "
                         "Requires --kv-impl paged")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8", "q2_14"],
                    help="paged-pool storage format (core/kv_quant.py): "
                         "K/V quantized at pool-write time against per-"
                         "block-per-head scales and dequantized at every "
                         "read via the CORDIC linear-rotation multiply — "
                         "int8 cuts resident pool bytes ~4x, q2_14 ~2x. "
                         "Requires --kv-impl paged")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: prompts longer than this stream "
                         "in as block-aligned chunks interleaved with "
                         "decode steps (serve/scheduler.py; same tokens). "
                         "0 = off (single-shot bucketed prefill)")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="max scheduled prefill rows packed into one "
                         "multi-row paged dispatch (0 = auto: slots when "
                         "chunking a paged engine, else 1)")
    ap.add_argument("--max-prefill-tokens", type=int, default=0,
                    help="per-iteration prefill token budget across "
                         "scheduled rows (0 = unlimited)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prompt-prefix cache over the paged "
                         "pool (serve/prefix_cache.py): admissions whose "
                         "prompt shares full KV blocks with an earlier "
                         "prompt bind those blocks instead of recomputing "
                         "them (same tokens). Requires --kv-impl paged. "
                         "The demo prepends a shared system prompt so "
                         "hits actually occur")
    ap.add_argument("--prefix-eviction", default="lru",
                    choices=["lru", "fifo"],
                    help="prefix-cache eviction order over idle cached "
                         "blocks when the pool runs dry")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree: shard params + KV over "
                         "the mesh 'model' axis (must divide the visible "
                         "device count; force CPU devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N). "
                         "Tokens are bit-identical to --tp 1. 0/1 = "
                         "unsharded single-device engine")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine's metrics-registry snapshot "
                         "(TTFT/TPOT/e2e histograms, queue depth, pool "
                         "occupancy, compile + saturation counters) to "
                         "this path")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace (Perfetto-loadable) JSON "
                         "of request lifecycles + engine phase spans to "
                         "this path")
    args = ap.parse_args(argv)

    obs = (repro_obs.Observability(trace=args.trace_out is not None)
           if (args.metrics_json or args.trace_out) else None)
    cfg = (configs.get_smoke(args.arch, act_impl=args.act_impl) if args.smoke
           else configs.get_config(args.arch, act_impl=args.act_impl))
    if cfg.input_mode != "tokens":
        import dataclasses

        cfg = dataclasses.replace(cfg, input_mode="tokens")
    print(f"[serve] arch={cfg.name} slots={args.slots} kv={args.kv_impl} "
          f"kv_quant={args.kv_quant} tp={args.tp or 1}")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    # temperature <= 0 resolves to greedy inside SamplingParams
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      sampling=sampling, kv_impl=args.kv_impl,
                      block_len=args.block_len,
                      num_blocks=args.num_blocks or None,
                      paged_attend_impl=args.paged_attend_impl,
                      kv_quant=args.kv_quant,
                      prefill_chunk=args.prefill_chunk or None,
                      prefill_batch=args.prefill_batch or None,
                      max_prefill_tokens=args.max_prefill_tokens or None,
                      prefix_cache=args.prefix_cache,
                      prefix_eviction=args.prefix_eviction,
                      tp=args.tp or None,
                      obs=obs)
    if eng.mesh is not None:
        print(f"[serve] mesh: {dict(eng.mesh.shape)} over "
              f"{eng.mesh.size} devices")

    rng = np.random.default_rng(0)
    # with the prefix cache on, share a system prompt across requests so
    # later admissions hit the radix index instead of recomputing it
    sys_prompt = (rng.integers(0, cfg.vocab_size,
                               2 * args.block_len).astype(np.int32)
                  if args.prefix_cache else np.zeros(0, np.int32))
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 12))).astype(np.int32)
        eng.submit(Request(
            rid=i,
            prompt=np.concatenate([sys_prompt, tail]),
            max_new_tokens=args.max_new))
    t0 = time.time()
    if obs is not None:
        # count eager fixed-point boundary clips into the same registry
        with repro_obs.observe_saturation(obs.metrics):
            done = eng.run()
    else:
        done = eng.run()
    total = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total} tokens, "
          f"{time.time() - t0:.1f}s")
    if eng.pager is not None:
        st = eng.pager.stats()
        print(f"[serve] pool: peak {st.peak_in_use}/{st.num_blocks - 1} "
              f"blocks x {eng.block_len} positions, "
              f"{st.allocs} allocs, {st.alloc_failures} backpressure waits")
    if eng.prefix is not None:
        print(f"[serve] prefix cache ({eng.prefix.policy}): "
              f"{eng.prefix.hits} hits / {eng.prefix.hit_blocks} blocks "
              f"reused, {eng.prefix.evicted_blocks} evicted")
    if obs is not None:
        ttft = obs.metrics.get("engine.ttft_ms")
        tpot = obs.metrics.get("engine.tpot_ms")
        print(f"[serve] ttft p50/p99 {ttft.quantile(0.5):.1f}/"
              f"{ttft.quantile(0.99):.1f} ms, tpot p50 "
              f"{tpot.quantile(0.5):.2f} ms "
              f"({int(obs.metrics.get('engine.tokens.emitted').value)} tok)")
        if args.metrics_json:
            obs.metrics.to_json(args.metrics_json)
            print(f"[serve] wrote metrics -> {args.metrics_json}")
        if args.trace_out:
            obs.trace.export(args.trace_out)
            print(f"[serve] wrote Chrome trace -> {args.trace_out} "
                  f"(load at ui.perfetto.dev)")
    assert len(done) == args.requests
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
