"""Scan-corrected cost accounting from compiled HLO text.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) counts a
``while`` body ONCE, regardless of trip count — so any model whose layers
run under ``lax.scan`` (every full config here: that is how 64-layer models
compile to O(1) HLO) under-reports flops/bytes/collective traffic by the
layer count. Verified empirically in tests/test_hlo_cost.py (scan vs
unrolled tiny model).

This module re-derives the three roofline inputs from the compiled module
*text* with loop-trip multipliers:

    1. parse HLO computations, building a per-computation symbol table
       (operand types are not inlined in modern dumps);
    2. extract each while loop's trip count from the constant bound in its
       condition computation;
    3. propagate execution-count multipliers through the call graph
       (while bodies/conds, fusions, reducers, conditionals) from ENTRY;
    4. count per call site:
         flops       — dot ops: 2 * prod(output) * prod(contracted dims)
                       (+1 flop/element for elementwise arithmetic ops),
         hbm bytes   — operand + output bytes of ops at *unfused* level
                       (fusion-internal ops do not touch HBM),
         collectives — all-reduce/all-gather/reduce-scatter/all-to-all/
                       collective-permute payload bytes (weighted: AR x2).

Caveats (see EXPERIMENTS.md §Roofline): byte counts model fusion-boundary
HBM traffic of the CPU-backend module, an upper bound on a TPU module's;
trip counts use the max s32 constant in the loop condition (exact for
lax.scan / fori_loop lowerings, which is all this codebase emits).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_ATTR = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_COLL_FACTOR = {"all-reduce": 2.0}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "logistic", "log", "rsqrt", "sqrt", "negate",
    "compare", "select", "and", "or", "xor", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "floor", "abs",
    "round-nearest-afz", "clamp", "exponential-minus-one",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            total += _numel(dims) * _DTYPE_BYTES[dt]
    return total


class _Op:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name, type_str, op, line):
        self.name, self.type_str, self.op, self.line = name, type_str, op, line


def _parse(hlo: str):
    """-> (comps: name -> [Op], entry_name)."""
    comps: Dict[str, List[_Op]] = {}
    cur: List[_Op] = []
    cur_name = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur_name = hdr.group(2)
            cur = comps.setdefault(cur_name, [])
            if hdr.group(1):
                entry = cur_name
            continue
        if line == "}":
            cur_name = None
            continue
        if cur_name is None:
            continue
        d = _DEF_RE.match(line)
        if d:
            cur.append(_Op(d.group(1), d.group(2), d.group(3), line))
        else:
            # parameter lines: "%p = f32[2,3]{1,0} parameter(0)" match above;
            # anything else (attrs continuation) appended to last op's line
            if cur:
                cur[-1].line += " " + line
    return comps, entry


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = _parse(hlo)
    if entry is None:
        return {}

    symtab = {name: {op.name: op.type_str for op in ops}
              for name, ops in comps.items()}

    def trips_of(cond: str) -> int:
        best = 1
        for op in comps.get(cond, ()):
            for c in _CONST_RE.findall(op.line):
                best = max(best, int(c))
        return best

    # multiplier propagation through the call graph
    mult: Dict[str, float] = defaultdict(float)
    fused: Dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    order = [entry]
    qi = 0
    while qi < len(order):
        name = order[qi]
        qi += 1
        m = mult[name]
        for op in comps.get(name, ()):
            if op.op == "while":
                w = _WHILE_ATTR.search(op.line)
                if w:
                    t = trips_of(w.group(1))
                    for callee in (w.group(2), w.group(1)):
                        if callee in comps:
                            mult[callee] += m * t
                            if callee not in order:
                                order.append(callee)
                continue
            callees = _CALLS_RE.findall(op.line)
            b = _BRANCHES_RE.search(op.line)
            if b:
                callees += [c.strip().lstrip("%") for c in b.group(1).split(",")]
            for callee in callees:
                if callee in comps:
                    mult[callee] += m
                    fused[callee] = True  # fusion/reducer: flops yes, bytes no
                    if callee not in order:
                        order.append(callee)

    def op_flops(op: _Op, comp: str) -> float:
        if op.op == "dot":
            out_n = _numel(_SHAPE_RE.search(op.type_str).group(2)) \
                if _SHAPE_RE.search(op.type_str) else 0
            cm = _CONTRACT_RE.search(op.line)
            args = op.line.split("dot(", 1)[1] if "dot(" in op.line else ""
            names = _OPERANDS_RE.findall(args.split(")", 1)[0])
            if not (cm and names):
                return 0.0
            lhs_t = symtab[comp].get(names[0], "")
            sh = _SHAPE_RE.search(lhs_t)
            if not sh:
                return 0.0
            lhs_dims = [int(d) for d in sh.group(2).split(",") if d]
            k = 1
            for i in (int(i) for i in cm.group(1).split(",") if i):
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
            return 2.0 * out_n * k
        if op.op in _ELEMENTWISE:
            sh = _SHAPE_RE.search(op.type_str)
            return float(_numel(sh.group(2))) if sh else 0.0
        return 0.0

    def op_bytes(op: _Op, comp: str) -> float:
        """TPU-fusion-realistic HBM byte model.

        XLA:TPU fuses elementwise/broadcast/reduce chains into their
        producers, so those intermediates never hit HBM; what does:

          * outputs of MXU/layout/memory ops (dot, reduce(-window), dynamic
            slice/update, gather/scatter, transpose/reshape/copy, concat,
            pad, slice, rng, collectives, custom-call) — written once;
          * operands of dot and collective ops — read from HBM (dots do not
            fuse their operands; a softmax-ed score matrix is re-read by
            the AV matmul even though the softmax itself fused away).

        The CPU-backend module fuses less than TPU would, so applying this
        model to its op graph approximates the TPU traffic; EXPERIMENTS.md
        documents it as an estimate used consistently across variants.
        """
        if op.op in _SKIP_BYTES_OPS or op.op in _ELEMENTWISE \
                or op.op in ("broadcast", "reverse", "map"):
            return 0.0
        total = float(_type_bytes(op.type_str))
        if op.op == "dot" or op.op.replace("-start", "") in _COLL_KINDS:
            args = op.line.split("(", 1)[1] if "(" in op.line else ""
            args = args.split(")", 1)[0]
            for nm in _OPERANDS_RE.findall(args):
                total += _type_bytes(symtab[comp].get(nm, ""))
        return total

    flops = 0.0
    hbm_bytes = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)
    for name in order:
        m = mult[name]
        if m <= 0:
            continue
        in_fused = fused[name]
        for op in comps.get(name, ()):
            flops += m * op_flops(op, name)
            if not in_fused:
                hbm_bytes += m * op_bytes(op, name)
            base = op.op.replace("-start", "")
            if base in _COLL_KINDS and not op.op.endswith("-done"):
                coll_bytes[base] += m * _type_bytes(op.type_str)
                coll_counts[base] += m

    weighted = sum(_COLL_FACTOR.get(k, 1.0) * v for k, v in coll_bytes.items())
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes_by_kind": dict(coll_bytes),
        "collective_op_counts": dict(coll_counts),
        "collective_weighted_bytes": weighted,
        "num_computations": len(comps),
    }
