"""Abstract (ShapeDtypeStruct) inputs + NamedSharding assembly for the
dry-run: the same pattern production launchers use — weak-type-correct,
shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import common as cm
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train.step import TrainState


# ---------------------------------------------------------------------------
# Abstract state / inputs
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    return jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0), dtype))


def abstract_train_state(cfg: ModelConfig, dtype=jnp.float32) -> TrainState:
    params = abstract_params(cfg, dtype)
    opt = jax.eval_shape(lambda p: adamw.init(p), params)
    return TrainState(params=params, opt=opt, err=None)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len, dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:
        batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
    batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    if cfg.input_mode == "tokens":
        return jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------
def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)])) or 1


def params_shardings(cfg: ModelConfig, mesh: Mesh, params_shapes=None):
    params_shapes = params_shapes or abstract_params(cfg)
    axes = cm.param_axes(tf.model_spec(cfg))
    return shd.tree_shardings(axes, params_shapes, mesh)


def _zero1_extend(sharding: NamedSharding, shape, mesh: Mesh) -> NamedSharding:
    """ZeRO-1: additionally shard an optimizer-state leaf over the data axes
    on the first divisible, not-yet-sharded dim (falls back unchanged)."""
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)
    if not dp or dpn == 1:
        return sharding
    spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    for i, (dim, cur) in enumerate(zip(shape, spec)):
        if cur is None and dim % dpn == 0 and dim >= dpn:
            spec[i] = dp if len(dp) > 1 else dp[0]
            return NamedSharding(mesh, PS(*spec))
    return sharding


def state_shardings(cfg: ModelConfig, mesh: Mesh,
                    state_shapes: Optional[TrainState] = None,
                    zero1: bool = False) -> TrainState:
    state_shapes = state_shapes or abstract_train_state(cfg)
    p_sh = params_shardings(cfg, mesh, state_shapes.params)
    scalar = NamedSharding(mesh, PS())
    if zero1:
        # optimizer moments sharded over the data axes on top of TP — the
        # ZeRO-1 memory trick; GSPMD inserts the gather/scatter around the
        # optimizer update (overlappable with the next step's forward).
        mu_sh = jax.tree.map(
            lambda sh, like: _zero1_extend(sh, like.shape, mesh),
            p_sh, state_shapes.params)
    else:
        mu_sh = p_sh
    opt_sh = adamw.AdamWState(step=scalar, mu=mu_sh, nu=mu_sh)
    err_sh = None if state_shapes.err is None else p_sh
    return TrainState(params=p_sh, opt=opt_sh, err=err_sh)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                    batch_specs: Dict[str, Any]):
    B = shape.global_batch
    out = {}
    for k, v in batch_specs.items():
        seq = v.shape[1] if len(v.shape) >= 2 else 1
        extra = max(len(v.shape) - 2, 0)
        out[k] = NamedSharding(mesh, shd.batch_spec(mesh, B, seq, extra))
    return out


def _cache_leaf_spec(key: str, shape: Tuple[int, ...], cfg: ModelConfig,
                     mesh: Mesh, batch: int) -> PS:
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)
    model = mesh.shape.get("model", 1)
    b_ax = dp if (batch % dpn == 0 and batch >= dpn) else None
    seq_ok = lambda s: s % dpn == 0
    mdl = lambda d: "model" if d % model == 0 else None

    if key in ("k", "v") and len(shape) == 4:
        B, S, KH, D = shape
        if cfg.kv_shard == "seq_model" and S % model == 0:
            return PS(b_ax, "model", None, None)
        if b_ax is not None:
            return PS(b_ax, None, mdl(KH), None)
        return PS(None, dp if seq_ok(S) else None, mdl(KH), None)
    if key in ("c_kv", "k_rope") and len(shape) == 3:
        B, S, L = shape
        if cfg.kv_shard == "seq_model" and S % model == 0:
            return PS(b_ax, "model", None)
        if b_ax is not None:
            return PS(b_ax, None, None)
        return PS(None, dp if seq_ok(S) else None, None)
    if key == "ssm" and len(shape) == 4:       # (B,H,P,N)
        return PS(b_ax, mdl(shape[1]), None, None)
    if key == "conv" and len(shape) == 3:      # (B,W,C)
        return PS(b_ax, None, mdl(shape[2]))
    if key == "C" and len(shape) == 4:         # mLSTM (B,H,dk,dv)
        return PS(b_ax, None, mdl(shape[2]), None)
    if key == "n" and len(shape) == 3:         # mLSTM (B,H,dk)
        return PS(b_ax, None, mdl(shape[2]))
    if key == "m" and len(shape) == 2:         # mLSTM (B,H)
        return PS(b_ax, None)
    if key in ("c", "n", "m", "h") and len(shape) == 2:   # sLSTM (B,d)
        return PS(b_ax, mdl(shape[1]))
    if key == "idx":
        return PS()
    # stacked variants carry a leading layers dim -> shift everything right
    return PS()


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shapes,
                    shape: ShapeConfig):
    """Sharding tree for the decode cache; handles the stacked (layers,...)
    leading dim added by scan segments / shared apps."""
    batch = shape.global_batch

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        key = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        # detect stacked leading layers dim: cache built per segment gets
        # (count, B, ...) — the raw key shapes above are (B, ...)
        base_nd = {"k": 4, "v": 4, "c_kv": 3, "k_rope": 3, "ssm": 4,
                   "conv": 3, "C": 4, "n": 3, "m": 2, "c": 2, "h": 2,
                   "idx": 0}.get(key)
        # ambiguity note: mLSTM n/m vs sLSTM n/m differ in rank, and sLSTM
        # layers never form scan runs in the assigned patterns, so the
        # rank-based stacking test below disambiguates every real case.
        stacked = base_nd is not None and nd == base_nd + 1
        inner_shape = leaf.shape[1:] if stacked else leaf.shape
        # sLSTM "n"/"m"/"c"/"h" are (B,d); mLSTM "n" is (B,H,dk), "m" (B,H)
        spec = _cache_leaf_spec(key, tuple(inner_shape), cfg, mesh, batch)
        if stacked:
            spec = PS(None, *spec)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
