"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio; returns a scale in (0,1]."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup_steps, warm, cos)


def constant(step, *, value: float = 1.0):
    return jnp.full((), value, jnp.float32)
