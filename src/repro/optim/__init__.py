from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init,
)
from repro.optim import schedule  # noqa: F401
