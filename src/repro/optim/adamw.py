"""AdamW from scratch (no optax), pytree-native, with optional ZeRO-1
sharding hooks: the optimizer state tree mirrors the param tree, so the
distributed layer can assign it the same NamedShardings (or shard it further
along the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(params, state: AdamWState, grads, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, m, v, g):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mhat = m2 / b1t
        vhat = v2 / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, state.mu, state.nu, grads)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gn}
