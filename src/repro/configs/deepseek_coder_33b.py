"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch. [arXiv:2401.14196]"""
from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-coder-33b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=19200, vocab_size=32256, qkv_bias=False,
        rope_theta=1e5, act_impl=act_impl,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=160, vocab_size=512, qkv_bias=False,
        rope_theta=1e4, act_impl=act_impl, dtype="float32",
    )
