"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2 backbone; the InternViT patch frontend
is a STUB (input_specs provides precomputed patch+text embeddings).
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

ARCH_ID = "internvl2-1b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151655,
        rope_theta=1e6, act_impl=act_impl, input_mode="embeds",
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        num_layers=2, d_model=56, num_heads=7, num_kv_heads=1,
        d_ff=112, vocab_size=512,
        rope_theta=1e4, act_impl=act_impl, input_mode="embeds", dtype="float32",
    )
