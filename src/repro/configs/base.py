"""Model/shape configuration system.

`ModelConfig` covers every assigned architecture family (dense GQA, MLA+MoE,
GQA+MoE, Mamba2 hybrid, xLSTM, audio/VLM backbones with stub frontends).
`block_pattern` drives the generic decoder in models/transformer.py: a tuple
with one entry per layer naming the block builder; runs of equal entries are
stacked and executed with lax.scan (O(1) HLO size for 64-layer configs).

`ShapeConfig` encodes the assigned input shapes (train_4k / prefill_32k /
decode_32k / long_500k) and which step function they lower (train vs serve).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_score: str = "softmax"       # "softmax" | "sigmoid" (V3-style)
    normalize_gates: bool = True
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0            # mLSTM up-projection factor
    ffn_factor: float = 4.0 / 3.0 * 2   # sLSTM post-FFN factor
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense|ssm|hybrid|moe|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = ()  # len == num_layers (+ shared apps)
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_kind: str = "swiglu"             # swiglu | gelu
    d_ff_dense: int = 0                  # dense-FFN width in MoE archs (0 -> d_ff)
    act_impl: str = "cordic_fixed"       # exact|cordic_float|cordic_fixed|cordic_pallas
    softmax_impl: str = "exact"          # exact | cordic_fixed | cordic_pallas:
                                         # attention-row softmax via the fused
                                         # CORDIC-exp + LVC-normalize kernel
    loss_impl: str = "exact"             # exact | cordic | cordic_pallas:
                                         # cross-entropy log-softmax via the
                                         # CORDIC exp + hyperbolic-vectoring
                                         # log legs (train/losses.py); the
                                         # backward pass is always the
                                         # analytic softmax - onehot form
    attn_chunk: int = 1024
    kv_impl: str = "dense"               # dense | paged: decode KV layout —
                                         # one max_len buffer per slot vs a
                                         # global block pool + per-slot block
                                         # tables (serve/kv_pager.py); decode
                                         # output is bit-identical either way
    kv_block_len: int = 16               # positions per KV block (paged) and
                                         # the prefill-bucket granularity
    paged_attend_impl: str = "gather"    # gather | pallas: how a paged decode
                                         # attends — full-table gather (dense-
                                         # shaped transient, provably bit-
                                         # identical) vs the block-walking
                                         # Pallas kernel (O(block_len) VMEM
                                         # transient per step, token-identical;
                                         # kernels/paged_attention.py)
    kv_quant: str = "none"               # none | int8 | q2_14: paged-pool
                                         # storage format (core/kv_quant.py) —
                                         # K/V quantized at pool-write time
                                         # against per-block-per-head amax
                                         # scales, dequantized at every read
                                         # (gather attend and inside the
                                         # Pallas kernel's per-chunk VMEM
                                         # step) via the CORDIC linear-
                                         # rotation multiply. Requires
                                         # kv_impl="paged"; GQA only
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    input_mode: str = "tokens"           # tokens | embeds (stub frontends)
    remat: str = "none"                  # none | full | dots (per-layer ckpt)
    score_dtype: str = "f32"             # f32 (cast) | bf16_mxu (f32 accum)
    kv_shard: str = "auto"               # auto | seq_model (flash-decode SP)
    pad_heads_to: int = 0                # pad H/KH up to a multiple (0=off);
                                         # makes awkward head counts TP-shardable
                                         # exactly (padded wo rows are zero)
    slstm_state: str = "auto"            # auto | replicated: pin the sLSTM
                                         # recurrent state off the model axis
                                         # (kills per-timestep TP collectives)
    sub_quadratic: bool = False          # eligible for long_500k
    dtype: str = "bfloat16"
    # zamba2-style shared block: applied after layers i with i% period == offset
    shared_block: Optional[str] = None   # e.g. "dense" (attn+mlp, shared weights)
    shared_period: int = 6

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.d_ff_dense == 0:
            object.__setattr__(self, "d_ff_dense", self.d_ff)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("dense",) * self.num_layers)
        assert len(self.block_pattern) == self.num_layers

    # ---- parameter counting (roofline MODEL_FLOPS) -------------------------
    def param_counts(self) -> dict:
        """Returns dict(total=..., active=...) parameter counts (no embeds
        double count; active = per-token touched params for MoE)."""
        d, hd = self.d_model, self.head_dim
        H, KH = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb
        for blk in self.block_pattern:
            t, a = self._block_params(blk)
            total += t
            active += a
        if self.shared_block is not None:
            t, a = self._block_params(self.shared_block)
            total += t
            n_apps = sum(1 for i in range(self.num_layers)
                         if (i + 1) % self.shared_period == 0)
            active += a * max(n_apps - 1, 0)  # reused weights, extra compute
        return dict(total=total, active=active)

    def _block_params(self, blk: str):
        d, hd = self.d_model, self.head_dim
        H, KH = self.num_heads, self.num_kv_heads
        attn = d * hd * (H + 2 * KH) + H * hd * d
        mlp = 3 * d * self.d_ff if self.mlp_kind == "swiglu" else 2 * d * self.d_ff
        if blk == "dense":
            return attn + mlp, attn + mlp
        if blk == "mla_dense" or blk == "mla_moe":
            m = self.mla
            a = (d * H * (m.qk_nope_dim + m.qk_rope_dim)
                 + d * (m.kv_lora_rank + m.qk_rope_dim)
                 + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_dim)
                 + H * m.v_dim * d)
            if blk == "mla_dense":
                md = 3 * d * self.d_ff_dense
                return a + md, a + md
            e = self.moe
            routed = 3 * d * e.d_ff_expert
            shared = 3 * d * e.d_ff_expert * e.num_shared_experts
            tot = a + routed * e.num_experts + shared + d * e.num_experts
            act = a + routed * e.top_k + shared + d * e.num_experts
            return tot, act
        if blk == "gqa_moe":
            e = self.moe
            routed = 3 * d * e.d_ff_expert
            tot = attn + routed * e.num_experts + d * e.num_experts
            act = attn + routed * e.top_k + d * e.num_experts
            return tot, act
        if blk == "mamba2":
            s = self.ssm
            di = s.expand * d
            nh = di // s.head_dim
            conv_dim = di + 2 * s.n_groups * s.d_state
            p = (d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                 + s.d_conv * conv_dim + conv_dim + 3 * nh + di + di * d)
            return p, p
        if blk == "mlstm":
            x = self.xlstm
            di = int(d * x.proj_factor)
            p = (d * 2 * di + x.d_conv * di + di + 3 * di * di
                 + di * 2 * H + 2 * H + di + di * d)
            return p, p
        if blk == "slstm":
            x = self.xlstm
            dff = int(d * x.ffn_factor)
            dh = d // H
            p = d * 4 * d + 4 * d + 4 * H * dh * dh + d + 3 * d * dff
            return p, p
        raise ValueError(blk)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Per-spec skip rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is a full-attention arch (skip per spec)")
    return True, ""
