"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

ARCH_ID = "yi-9b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, qkv_bias=False,
        rope_theta=1e4, act_impl=act_impl,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, qkv_bias=False,
        rope_theta=1e4, act_impl=act_impl, dtype="float32",
    )
