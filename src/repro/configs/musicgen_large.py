"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048
— decoder-only over EnCodec tokens, GELU MLP. The EnCodec frontend is a STUB:
input_specs() provides precomputed frame embeddings (B,S,d); the LM head
predicts a flattened single-codebook stream (vocab 2048 — DESIGN.md dev. 6).
[arXiv:2306.05284]"""
from repro.configs.base import ModelConfig

ARCH_ID = "musicgen-large"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, mlp_kind="gelu",
        rope_theta=1e4, act_impl=act_impl, input_mode="embeds",
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, mlp_kind="gelu",
        rope_theta=1e4, act_impl=act_impl, input_mode="embeds", dtype="float32",
    )
