"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + ONE shared attention+MLP block (shared
weights) applied every 6 layers, each application with its own KV cache.
Sub-quadratic (Mamba2 state + O(L)-per-token attention decode) -> runs
long_500k. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        block_pattern=("mamba2",) * 38,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        shared_block="dense", shared_period=6,
        rope_theta=1e4, act_impl=act_impl, sub_quadratic=True,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        block_pattern=("mamba2",) * 4,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        shared_block="dense", shared_period=2,
        rope_theta=1e4, act_impl=act_impl, sub_quadratic=True, dtype="float32",
    )
