"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks at the xLSTM[7:1] ratio (every 8th layer is sLSTM). Sub-quadratic:
O(1) recurrent state -> runs long_500k. [arXiv:2405.04517]

This arch is the paper technique's richest habitat: all mLSTM forget gates,
sLSTM input/forget/output gates, and the block output gates are sigmoids
evaluated by the MR-HRC CORDIC pipeline when act_impl=cordic_*.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

ARCH_ID = "xlstm-1.3b"


def _pattern(n_layers: int, period: int = 8):
    return tuple("slstm" if (i + 1) % period == 0 else "mlstm"
                 for i in range(n_layers))


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=_pattern(48),
        xlstm=XLSTMConfig(proj_factor=2.0, d_conv=4, chunk=256),
        act_impl=act_impl, sub_quadratic=True,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=512,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        xlstm=XLSTMConfig(proj_factor=2.0, d_conv=4, chunk=16),
        act_impl=act_impl, sub_quadratic=True, dtype="float32",
    )
