"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias. [hf:Qwen/Qwen2.5-*; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-32b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=27648, vocab_size=152064, qkv_bias=True,
        rope_theta=1e6, act_impl=act_impl,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, qkv_bias=True,
        rope_theta=1e4, act_impl=act_impl, dtype="float32",
    )
