"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias. [hf:CohereForAI/c4ai-command-r*]"""
from repro.configs.base import ModelConfig

ARCH_ID = "command-r-plus-104b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=33792, vocab_size=256000, qkv_bias=False,
        rope_theta=1e6, act_impl=act_impl,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=192, vocab_size=512, qkv_bias=False,
        rope_theta=1e4, act_impl=act_impl, dtype="float32",
    )
