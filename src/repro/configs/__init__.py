"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

All 10 assigned architectures plus the paper's own activation config.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs import (
    command_r_plus_104b,
    deepseek_coder_33b,
    deepseek_v2_lite_16b,
    internvl2_1b,
    musicgen_large,
    phi3_5_moe_42b,
    qwen2_5_32b,
    xlstm_1_3b,
    yi_9b,
    zamba2_1_2b,
)
from repro.configs.base import (  # noqa: F401
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    shape_applicable,
)

_MODULES = {
    "qwen2.5-32b": qwen2_5_32b,
    "command-r-plus-104b": command_r_plus_104b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "yi-9b": yi_9b,
    "xlstm-1.3b": xlstm_1_3b,
    "musicgen-large": musicgen_large,
    "zamba2-1.2b": zamba2_1_2b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "internvl2-1b": internvl2_1b,
}

ARCH_IDS = tuple(_MODULES.keys())


def get_config(arch_id: str, **kw) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _MODULES[arch_id].full(**kw)


def get_smoke(arch_id: str, **kw) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _MODULES[arch_id].smoke(**kw)
