"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (kv=16) vocab=102400 —
MLA (kv_lora=512, qk_nope=128, qk_rope=64), layer 0 dense FFN (10944), layers
1..26 MoE with 2 shared + 64 routed experts (d_ff_expert=1408), top-6.

NOTE: the assignment line says both "MoE 64e top-6" and "2 shared+160
routed"; 160 routed is full DeepSeek-V2 (236B) — the *lite* model has 64
routed (DESIGN.md deviation 5). [arXiv:2405.04434]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def full(act_impl: str = "cordic_fixed", router_score: str = "softmax") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, d_ff_dense=10944, vocab_size=102400,
        block_pattern=("mla_dense",) + ("mla_moe",) * 26,
        mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared_experts=2, router_score=router_score),
        rope_theta=1e4, act_impl=act_impl, head_dim=128,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, d_ff_dense=96, vocab_size=512,
        block_pattern=("mla_dense", "mla_moe", "mla_moe"),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, num_shared_experts=1),
        rope_theta=1e4, act_impl=act_impl, head_dim=16, dtype="float32",
    )
