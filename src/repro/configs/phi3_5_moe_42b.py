"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) vocab=32064,
16 experts top-2, d_ff_expert=6400. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def full(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=6400, vocab_size=32064,
        block_pattern=("gqa_moe",) * 32,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
        rope_theta=1e4, act_impl=act_impl,
    )


def smoke(act_impl: str = "cordic_fixed") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512,
        block_pattern=("gqa_moe",) * 2,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
        rope_theta=1e4, act_impl=act_impl, dtype="float32",
    )
