"""The paper's own configuration: the MR-HRC sigmoid unit itself.

This is not an LM architecture — it is the canonical configuration of the
activation datapath (schedules, fixed-point format) that all `cordic_*`
act_impls share, exposed here so experiments can reference one source of
truth.
"""
from repro.core.cordic import FixedConfig, MRSchedule, PAPER_FIXED, PAPER_SCHEDULE

ARCH_ID = "paper-sigmoid-mrhrc"

#: Radix-2 j=2..9, radix-4 j=4..7, LVC j=1..14 (paper Sec. 3.1-3.3).
SCHEDULE: MRSchedule = PAPER_SCHEDULE
#: 16-bit Q2.14, truncating datapath shifts, nearest final rounding.
FIXED: FixedConfig = PAPER_FIXED

#: Input contracts.
SIGMOID_DOMAIN = (-1.0, 1.0)
TANH_DOMAIN = (-0.5, 0.5)

#: Paper-reported references (asserted in tests/test_paper_claims.py).
PAPER_MAE = 4.23e-4
PAPER_SLICES = 835
PAPER_DSP = 0
