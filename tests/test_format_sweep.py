"""Wider-format accuracy sweep: Q2.14 -> Q2.20 -> Q2.29 schedules must give
strictly monotone MAE improvement for exp/log/tanh (closing the ROADMAP's
"accuracy study pending" item).

The sweep itself lives in benchmarks/accuracy.py::format_sweep — the same
code that records the numbers into BENCH_accuracy.json and feeds the CI
regression gate — so the test and the recorded study cannot drift apart.
"""
import pathlib
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.cordic_engine import functions as F

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "benchmarks"))
import accuracy  # noqa: E402  (benchmarks/accuracy.py)

LADDER = ("q2_14", "q2_20", "q2_29")


@pytest.fixture(scope="module")
def sweep():
    return accuracy.format_sweep()


@pytest.mark.parametrize("fn_name", ["exp", "log", "tanh"])
def test_monotone_mae_improvement(fn_name, sweep):
    maes = [sweep[f"fmt_sweep/{fn_name}_mae_{n}"] for n in LADDER]
    for narrow, wide in zip(maes, maes[1:]):
        assert wide < narrow, (fn_name, dict(zip(LADDER, maes)))
    # widening 14 -> 20 fraction bits must buy at least ~one decade
    assert maes[1] < maes[0] / 10.0, (fn_name, maes)


def test_sweep_metrics_all_gated(sweep):
    """Every recorded sweep metric has a regression threshold (and passes).

    check_thresholds also reports THRESHOLDS keys missing from the input
    (metric-rename protection); this subset run only asserts on sweep keys.
    """
    for k in sweep:
        assert k in accuracy.THRESHOLDS, k
    bad = [b for b in accuracy.check_thresholds(sweep) if b[0] in sweep]
    assert not bad, bad


def test_format_profiles_resolution_scaling():
    """Schedule depth tracks the format: smallest elementary angle of each
    profile's vectoring stage is within 2x of the format resolution."""
    for name in LADDER:
        p = F.FORMAT_PROFILES[name]
        assert p.vectoring.resolution <= 2.0 * p.cfg.fmt.resolution, name
        assert p.division.resolution == 2.0 ** -p.cfg.fmt.frac_bits, name


def test_divide_improves_with_width():
    rng = np.random.default_rng(5)
    y = jnp.asarray(rng.uniform(-10, 10, 2048), jnp.float32)
    x = jnp.asarray(rng.uniform(0.1, 10, 2048), jnp.float32)
    want = np.asarray(y, np.float64) / np.asarray(x, np.float64)
    maes = []
    for name in ("q2_14", "q2_20"):
        p = F.FORMAT_PROFILES[name]
        got = F.divide_fixed(y, x, sched=p.division, cfg=p.cfg)
        maes.append(float(np.abs(np.asarray(got, np.float64) - want).mean()))
    assert maes[1] < maes[0] / 10.0, maes


def test_kernel_ops_honor_wider_formats():
    """The Pallas exp/log kernels must stay bit-identical to the jnp fixed
    path under the Q2.20 profile too (quantizer width + vectoring depth are
    format-sized, not hardcoded to 16 bits)."""
    from repro.kernels import ops as kops

    p = F.FORMAT_PROFILES["q2_20"]
    x = jnp.linspace(-4.0, 4.0, 801, dtype=jnp.float32)
    got = np.asarray(kops.exp(x, p.pipeline, p.cfg))
    want = np.asarray(F.exp_fixed(x, sched=p.rotation, cfg=p.cfg))
    np.testing.assert_array_equal(got, want)

    xl = jnp.asarray(np.geomspace(0.1, 10.0, 801), jnp.float32)
    got = np.asarray(kops.log(xl, p.pipeline, p.cfg))
    want = np.asarray(F.log_fixed(xl, sched=p.vectoring, cfg=p.cfg))
    np.testing.assert_array_equal(got, want)
