"""Model-component unit tests: attention vs naive reference, chunked
causal equivalence, MoE dispatch semantics, Mamba2/mLSTM chunk invariance,
RoPE properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moem
from repro.models import ssm as ssmm
from repro.models import xlstm as xlm
from repro.models import transformer as tf


def _naive_causal(q, k, v):
    """Reference O(S^2) attention. q: (B,S,KH,G,D), k/v: (B,S,KH,D)."""
    B, S, KH, G, D = q.shape
    out = np.zeros_like(np.asarray(q, np.float32))
    qn = np.asarray(q, np.float32)
    kn = np.asarray(k, np.float32)
    vn = np.asarray(v, np.float32)
    for b in range(B):
        for h in range(KH):
            for g in range(G):
                s = qn[b, :, h, g] @ kn[b, :, h].T / np.sqrt(D)
                mask = np.tril(np.ones((S, S), bool))
                s = np.where(mask, s, -1e30)
                p = np.exp(s - s.max(-1, keepdims=True))
                p /= p.sum(-1, keepdims=True)
                out[b, :, h, g] = p @ vn[b, :, h]
    return out


def test_chunked_attention_matches_naive():
    rng = np.random.default_rng(0)
    B, S, KH, G, D = 2, 64, 2, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, KH, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KH, D)), jnp.float32)
    got_full = attn.causal_attention(q, k, v, chunk=128)   # single block
    got_chunk = attn.causal_attention(q, k, v, chunk=16)   # scanned chunks
    want = _naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(got_full), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_chunk), want, atol=1e-4)


def test_rope_rotation_preserves_norm_and_relativity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = cm.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on (m - n)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)
    def dot(m, n):
        qm = cm.apply_rope(q, jnp.asarray([[m]]))
        kn = cm.apply_rope(k, jnp.asarray([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)
    assert dot(3, 1) == pytest.approx(dot(10, 8), rel=1e-4)


def test_moe_capacity_drops_and_combines():
    cfg = configs.get_smoke("phi3.5-moe-42b-a6.6b", act_impl="exact")
    m = cfg.moe
    params = cm.init_params(moem.moe_spec(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8, cfg.d_model)),
                    jnp.float32)
    y, aux = moem.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 0
    # capacity semantics: with capacity_factor -> 0 almost all tokens drop
    import dataclasses as dc

    cfg0 = dc.replace(cfg, moe=dc.replace(m, capacity_factor=1e-6))
    y0, _ = moem.moe_apply(params, x, cfg0)
    assert float(jnp.abs(y0).mean()) < float(jnp.abs(y).mean())


def test_moe_sigmoid_router():
    import dataclasses as dc

    cfg = configs.get_smoke("deepseek-v2-lite-16b", act_impl="cordic_fixed")
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, router_score="sigmoid"))
    params = cm.init_params(moem.moe_spec(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 4, cfg.d_model)),
                    jnp.float32)
    y, aux = moem.moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_mamba2_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    import dataclasses as dc

    cfg = configs.get_smoke("zamba2-1.2b", act_impl="exact")
    params = cm.init_params(ssmm.mamba2_spec(cfg), jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(2).normal(0, 0.5, (2, 32, cfg.d_model)),
                    jnp.float32)
    y1, _ = ssmm.mamba2_apply(params, x, dc.replace(
        cfg, ssm=dc.replace(cfg.ssm, chunk=8)))
    y2, _ = ssmm.mamba2_apply(params, x, dc.replace(
        cfg, ssm=dc.replace(cfg.ssm, chunk=32)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


def test_mamba2_decode_matches_prefill():
    cfg = configs.get_smoke("zamba2-1.2b", act_impl="exact")
    params = cm.init_params(ssmm.mamba2_spec(cfg), jax.random.PRNGKey(3))
    x = jnp.asarray(np.random.default_rng(3).normal(0, 0.5, (1, 9, cfg.d_model)),
                    jnp.float32)
    y_full, _ = ssmm.mamba2_apply(params, x, cfg)
    cache = ssmm.mamba2_init_cache(cfg, 1)
    y_pre, cache = ssmm.mamba2_apply(params, x[:, :8], cfg, cache=cache)
    y_dec, _ = ssmm.mamba2_apply(params, x[:, 8:9], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 8]),
                               atol=2e-4)


def test_mlstm_chunk_invariance_and_decode():
    cfg = configs.get_smoke("xlstm-1.3b", act_impl="exact")
    params = cm.init_params(xlm.mlstm_spec(cfg), jax.random.PRNGKey(4))
    x = jnp.asarray(np.random.default_rng(4).normal(0, 0.5, (2, 32, cfg.d_model)),
                    jnp.float32)
    import dataclasses as dc

    y1, _ = xlm.mlstm_apply(params, x, dc.replace(
        cfg, xlstm=dc.replace(cfg.xlstm, chunk=8)))
    y2, _ = xlm.mlstm_apply(params, x, dc.replace(
        cfg, xlstm=dc.replace(cfg.xlstm, chunk=32)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)

    cache = xlm.mlstm_init_cache(cfg, 2)
    y_pre, cache = xlm.mlstm_apply(params, x[:, :31], cfg, cache=cache)
    y_dec, _ = xlm.mlstm_apply(params, x[:, 31:32], cfg, cache=cache)
    y_full, _ = xlm.mlstm_apply(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 31]),
                               atol=2e-3)


def test_slstm_cache_continuation():
    cfg = configs.get_smoke("xlstm-1.3b", act_impl="exact")
    params = cm.init_params(xlm.slstm_spec(cfg), jax.random.PRNGKey(5))
    x = jnp.asarray(np.random.default_rng(5).normal(0, 0.5, (1, 12, cfg.d_model)),
                    jnp.float32)
    y_full, _ = xlm.slstm_apply(params, x, cfg)
    cache = xlm.slstm_init_cache(cfg, 1)
    _, cache = xlm.slstm_apply(params, x[:, :11], cfg, cache=cache)
    y_dec, _ = xlm.slstm_apply(params, x[:, 11:12], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 11]),
                               atol=2e-4)


def test_mla_absorbed_decode_matches_decompressed():
    """MLA decode (absorbed form) == prefill-style decompressed attention."""
    cfg = configs.get_smoke("deepseek-v2-lite-16b", act_impl="exact")
    params = cm.init_params(attn.mla_spec(cfg), jax.random.PRNGKey(6))
    x = jnp.asarray(np.random.default_rng(6).normal(0, 0.5, (2, 9, cfg.d_model)),
                    jnp.float32)
    y_full, _ = attn.mla_apply(params, x, cfg)          # decompressed path
    cache = attn.mla_init_cache(cfg, 2, 16, jnp.float32)
    _, cache = attn.mla_apply(params, x[:, :8], cfg, cache=cache)
    y_dec, _ = attn.mla_apply(params, x[:, 8:9], cfg, cache=cache)  # absorbed
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 8]),
                               atol=2e-4)


def test_scan_segments_match_python_loop():
    """The lax.scan execution of stacked layers == sequential python apply."""
    cfg = configs.get_smoke("yi-9b", act_impl="exact")
    params = tf.init(cfg, jax.random.PRNGKey(7))
    toks = jnp.asarray(np.random.default_rng(7).integers(0, cfg.vocab_size,
                                                         (2, 16)), jnp.int32)
    logits, _, _ = tf.apply(params, {"tokens": toks}, cfg)

    # manual: unstack seg0 and loop
    x = cm.embed(params["embed"], toks).astype(jnp.float32)
    seg = params["seg0"]
    for i in range(cfg.num_layers):
        layer = jax.tree.map(lambda a: a[i], seg)
        x, _, _ = tf.BLOCKS["dense"][1](layer, x, cfg, None, None)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    manual = cm.unembed(params["lm_head"], x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(manual),
                               atol=2e-3, rtol=2e-3)


def test_mla_chunked_prefill_matches_single_block():
    """Regression: chunked causal path must handle D_qk != D_v (MLA)."""
    cfg = configs.get_smoke("deepseek-v2-lite-16b", act_impl="exact")
    cfg_chunked = dataclasses.replace(cfg, attn_chunk=8)
    params = cm.init_params(attn.mla_spec(cfg), jax.random.PRNGKey(8))
    x = jnp.asarray(np.random.default_rng(8).normal(0, 0.5, (2, 32, cfg.d_model)),
                    jnp.float32)
    y_single, _ = attn.mla_apply(params, x, cfg)
    y_chunked, _ = attn.mla_apply(params, x, cfg_chunked)
    np.testing.assert_allclose(np.asarray(y_single), np.asarray(y_chunked),
                               atol=1e-5)


def test_gqa_chunked_prefill_matches_single_block():
    cfg = configs.get_smoke("qwen2.5-32b", act_impl="exact")
    cfg_chunked = dataclasses.replace(cfg, attn_chunk=8)
    params = cm.init_params(attn.gqa_spec(cfg), jax.random.PRNGKey(9))
    x = jnp.asarray(np.random.default_rng(9).normal(0, 0.5, (2, 32, cfg.d_model)),
                    jnp.float32)
    y_single, _ = attn.gqa_apply(params, x, cfg)
    y_chunked, _ = attn.gqa_apply(params, x, cfg_chunked)
    np.testing.assert_allclose(np.asarray(y_single), np.asarray(y_chunked),
                               atol=1e-5)


def test_pad_heads_forward_exact():
    """pad_heads_to=16: padded layout output == unpadded output exactly
    (padded k/v are zero -> padded heads contribute nothing through wo)."""
    cfg = configs.get_smoke("qwen2.5-32b", act_impl="exact")   # H=4, KH=2
    cfg_pad = dataclasses.replace(cfg, pad_heads_to=3)          # KH'=3, H'=6
    params = cm.init_params(attn.gqa_spec(cfg), jax.random.PRNGKey(10))
    params_pad = cm.init_params(attn.gqa_spec(cfg_pad), jax.random.PRNGKey(11))
    # copy the real weights into the padded layout, zero the padded k/v rows
    G = cfg.num_heads // cfg.num_kv_heads
    Hp = 3 * G
    import numpy as onp

    def pad3(w, n_real, n_pad):   # (d, heads, hd)
        out = onp.asarray(params_pad[w]) * 0.0
        out[:, :n_real] = onp.asarray(params[w])
        return jnp.asarray(out)

    params_pad = dict(params_pad)
    params_pad["wq"] = pad3("wq", cfg.num_heads, Hp)
    params_pad["wk"] = pad3("wk", cfg.num_kv_heads, 3)
    params_pad["wv"] = pad3("wv", cfg.num_kv_heads, 3)
    wo = onp.zeros((Hp, cfg.head_dim, cfg.d_model), onp.float32)
    wo[: cfg.num_heads] = onp.asarray(params["wo"])
    params_pad["wo"] = jnp.asarray(wo)
    for b, n in (("bq", cfg.num_heads), ("bk", cfg.num_kv_heads),
                 ("bv", cfg.num_kv_heads)):
        arr = onp.zeros_like(onp.asarray(params_pad[b]))
        arr[:n] = onp.asarray(params[b])
        params_pad[b] = jnp.asarray(arr)

    x = jnp.asarray(np.random.default_rng(12).normal(0, 0.5, (2, 16, cfg.d_model)),
                    jnp.float32)
    y_ref, _ = attn.gqa_apply(params, x, cfg)
    y_pad, _ = attn.gqa_apply(params_pad, x, cfg_pad)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref), atol=2e-5)


def test_pad_heads_decode_cache_shapes():
    cfg = dataclasses.replace(configs.get_smoke("qwen2.5-32b", act_impl="exact"),
                              pad_heads_to=3)
    cache = attn.gqa_init_cache(cfg, 2, 8, jnp.float32)
    assert cache["k"].shape == (2, 8, 3, cfg.head_dim)
    params = cm.init_params(attn.gqa_spec(cfg), jax.random.PRNGKey(13))
    x = jnp.asarray(np.random.default_rng(13).normal(0, 0.5, (2, 1, cfg.d_model)),
                    jnp.float32)
    y, c2 = attn.gqa_apply(params, x, cfg, cache=cache)
    assert y.shape == (2, 1, cfg.d_model)
    assert int(c2["idx"]) == 1
