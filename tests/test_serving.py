"""Serving engine tests: prefill/decode steps, continuous batching slots,
the batched stacked-cache decode path, and the serving-loop regressions
(run() result collection, admission eos/max_new_tokens off-by-one)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine, make_decode_step, make_prefill_step
from repro.serve.sampling import SamplingParams


def _cfg():
    return configs.get_smoke("yi-9b", act_impl="exact")


def test_decode_step_shapes():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg)
    nxt, cache = decode(params, cache, jnp.zeros((2, 1), jnp.int32))
    assert nxt.shape == (2,)
    assert int(jax.tree.leaves({"i": cache["seg0"]["idx"]})[0][0]) == 1


def test_engine_serves_batch():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+argmax loop for the same prompt."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7], np.int32)

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    while eng.step():
        pass

    cache = tf.init_cache(cfg, 1, 32, jnp.float32)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, cache, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        nxt, cache = decode(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(nxt[0]))
    assert req.out == toks


def test_sampling_decode_threads_rng():
    """Non-greedy decode consumes a per-step key: same key -> same sample,
    fresh keys -> the draw actually varies (the seed bug reused PRNGKey(0)
    every step, freezing temperature sampling)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg, greedy=False, temperature=3.0)
    toks = jnp.zeros((2, 1), jnp.int32)

    a1, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    a2, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    draws = {tuple(np.asarray(decode(params, cache, toks,
                                     jax.random.PRNGKey(s))[0]))
             for s in range(8)}
    assert len(draws) > 1, "identical samples across 8 distinct keys"

    import pytest
    with pytest.raises(ValueError, match="rng"):
        decode(params, cache, toks)


def test_engine_sampling_varies_across_steps():
    """ServeEngine(greedy=False) emits a non-degenerate token stream and is
    reproducible for a fixed seed."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))

    def run(seed):
        eng = ServeEngine(cfg, params, slots=1, max_len=64, greedy=False,
                          temperature=3.0, seed=seed)
        req = Request(rid=0, prompt=np.asarray([2, 4, 6], np.int32),
                      max_new_tokens=12)
        eng.submit(req)
        while eng.step():
            pass
        return req.out

    out_a, out_a2, out_b = run(0), run(0), run(123)
    assert out_a == out_a2                       # seed-deterministic
    assert len(set(out_a)) > 1                   # not frozen on one token
    assert out_a != out_b                        # seed actually matters
    assert all(0 <= t < cfg.vocab_size for t in out_a)


# ---------------------------------------------------------------------------
# Serving-loop regressions
# ---------------------------------------------------------------------------
def _mk_requests(cfg, n, *, max_new=6, plen=5, seed=0, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
                    max_new_tokens=max_new, sampling=sampling)
            for i in range(n)]


def test_run_returns_finished_requests():
    """run() used to return an always-empty list; it must hand back every
    submitted request, finished."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = _mk_requests(cfg, 5)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.rid for r in done) == [r.rid for r in reqs]
    assert all(r.done and len(r.out) == 6 for r in done)
    assert eng.run() == []                       # drained; no double-return


def test_max_new_tokens_1_stops_at_prefill():
    """The admission off-by-one: a max_new_tokens=1 request must finish on
    the prefill-emitted token, not overshoot by a full decode step."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = _mk_requests(cfg, 3, max_new=1)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 1 for r in done), [r.out for r in done]


def test_eos_on_first_token_stops_at_prefill():
    """A request whose prefill-emitted token IS eos must finish at admission
    with exactly one output token."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7], np.int32)

    probe = ServeEngine(cfg, params, slots=1, max_len=32)
    probe.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    first = probe.run()[0].out[0]

    eng = ServeEngine(cfg, params, slots=1, max_len=32, eos_token=first)
    req = Request(rid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    done = eng.run()
    assert [r.rid for r in done] == [0]
    assert req.out == [first]


def test_step_issues_single_decode_call():
    """One engine step == exactly one jitted decode dispatch, whatever the
    slot count / occupancy."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=4, max_len=64)
    calls = []
    inner = eng._decode
    eng._decode = lambda *a, **k: (calls.append(1), inner(*a, **k))[1]
    for r in _mk_requests(cfg, 6, max_new=4):
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 100
    assert len(calls) == steps


def _serve(cfg, params, reqs, *, slots, seed=0, **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=64, seed=seed, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


@pytest.mark.parametrize("sampling", [
    SamplingParams(greedy=True),
    SamplingParams(temperature=2.5),
    SamplingParams(temperature=1.5, top_k=8),
])
def test_batched_decode_matches_sequential(sampling):
    """Bit-for-bit equivalence: the same requests served at slots=4 and
    slots=1 (sequential) emit identical token streams, greedy AND seeded
    sampling — per-request key streams make the draw independent of batch
    composition, and the vmapped decode is bit-identical per slot."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))

    def reqs():
        return _mk_requests(cfg, 6, max_new=5, sampling=sampling, seed=7)

    batched = _serve(cfg, params, reqs(), slots=4)
    sequential = _serve(cfg, params, reqs(), slots=1)
    assert batched == sequential


def test_mixed_sampling_params_in_one_batch():
    """Slots may mix greedy and different temperatures; each request keeps
    the stream it would have gotten alone in the engine."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    kinds = [SamplingParams(greedy=True), SamplingParams(temperature=3.0),
             SamplingParams(temperature=0.5, top_k=4), None]
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in kinds]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=5, sampling=k)
                for i, (p, k) in enumerate(zip(prompts, kinds))]

    mixed = _serve(cfg, params, reqs(), slots=4)
    alone = [_serve(cfg, params, [r], slots=1)[0] for r in reqs()]
    assert mixed == alone


def test_stack_insert_take_slot_roundtrip():
    cfg = _cfg()
    caches = [tf.init_cache(cfg, 1, 16, jnp.float32) for _ in range(3)]
    caches[1] = jax.tree.map(lambda a: a + 1.0 if a.dtype == jnp.float32
                             else a + 1, caches[1])
    stacked = tf.stack_caches(caches)
    for i, c in enumerate(caches):
        got = tf.take_slot(stacked, i)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(c)))
    stacked2 = tf.insert_slot(stacked, caches[1], 2)
    got = tf.take_slot(stacked2, 2)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(got),
                               jax.tree.leaves(caches[1])))
