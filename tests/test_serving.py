"""Serving engine tests: prefill/decode steps, continuous batching slots,
the batched stacked-cache decode path, the paged KV plane (block pool +
bucketed prefill, bit-identical to dense), and the serving-loop
regressions (run() result collection, admission eos/max_new_tokens
off-by-one).

CI also runs this file once per datapath backend via REPRO_TEST_BACKEND in
{"jnp", "pallas_interpret"}: the attention-softmax impl follows the
backend (cordic_fixed / cordic_pallas), so a drift in one backend's decode
path is attributed there instead of surfacing as tier-1 flakiness. Unset
(the default tier-1 run), the exact softmax is used.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serve import kv_pager as kvp
from repro.serve.engine import Request, ServeEngine, make_decode_step, make_prefill_step
from repro.serve.sampling import SamplingParams

_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")
assert _BACKEND in _SOFTMAX_BY_BACKEND, \
    f"REPRO_TEST_BACKEND={_BACKEND!r} not in {sorted(filter(None, _SOFTMAX_BY_BACKEND))}"


def _cfg(arch: str = "yi-9b"):
    return dataclasses.replace(configs.get_smoke(arch, act_impl="exact"),
                               softmax_impl=_SOFTMAX_BY_BACKEND[_BACKEND])


def test_decode_step_shapes():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg)
    nxt, cache = decode(params, cache, jnp.zeros((2, 1), jnp.int32))
    assert nxt.shape == (2,)
    assert int(jax.tree.leaves({"i": cache["seg0"]["idx"]})[0][0]) == 1


def test_engine_serves_batch():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+argmax loop for the same prompt."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7], np.int32)

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    while eng.step():
        pass

    cache = tf.init_cache(cfg, 1, 32, jnp.float32)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, cache, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        nxt, cache = decode(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(nxt[0]))
    assert req.out == toks


def test_sampling_decode_threads_rng():
    """Non-greedy decode consumes a per-step key: same key -> same sample,
    fresh keys -> the draw actually varies (the seed bug reused PRNGKey(0)
    every step, freezing temperature sampling)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg, greedy=False, temperature=3.0)
    toks = jnp.zeros((2, 1), jnp.int32)

    a1, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    a2, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    draws = {tuple(np.asarray(decode(params, cache, toks,
                                     jax.random.PRNGKey(s))[0]))
             for s in range(8)}
    assert len(draws) > 1, "identical samples across 8 distinct keys"

    import pytest
    with pytest.raises(ValueError, match="rng"):
        decode(params, cache, toks)


def test_engine_sampling_varies_across_steps():
    """ServeEngine(greedy=False) emits a non-degenerate token stream and is
    reproducible for a fixed seed."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))

    def run(seed):
        eng = ServeEngine(cfg, params, slots=1, max_len=64, greedy=False,
                          temperature=3.0, seed=seed)
        req = Request(rid=0, prompt=np.asarray([2, 4, 6], np.int32),
                      max_new_tokens=12)
        eng.submit(req)
        while eng.step():
            pass
        return req.out

    out_a, out_a2, out_b = run(0), run(0), run(123)
    assert out_a == out_a2                       # seed-deterministic
    assert len(set(out_a)) > 1                   # not frozen on one token
    assert out_a != out_b                        # seed actually matters
    assert all(0 <= t < cfg.vocab_size for t in out_a)


# ---------------------------------------------------------------------------
# Serving-loop regressions
# ---------------------------------------------------------------------------
def _mk_requests(cfg, n, *, max_new=6, plen=5, seed=0, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
                    max_new_tokens=max_new, sampling=sampling)
            for i in range(n)]


def test_run_returns_finished_requests():
    """run() used to return an always-empty list; it must hand back every
    submitted request, finished."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = _mk_requests(cfg, 5)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.rid for r in done) == [r.rid for r in reqs]
    assert all(r.done and len(r.out) == 6 for r in done)
    assert eng.run() == []                       # drained; no double-return


def test_max_new_tokens_1_stops_at_prefill():
    """The admission off-by-one: a max_new_tokens=1 request must finish on
    the prefill-emitted token, not overshoot by a full decode step."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = _mk_requests(cfg, 3, max_new=1)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 1 for r in done), [r.out for r in done]


def test_eos_on_first_token_stops_at_prefill():
    """A request whose prefill-emitted token IS eos must finish at admission
    with exactly one output token."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7], np.int32)

    probe = ServeEngine(cfg, params, slots=1, max_len=32)
    probe.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    first = probe.run()[0].out[0]

    eng = ServeEngine(cfg, params, slots=1, max_len=32, eos_token=first)
    req = Request(rid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    done = eng.run()
    assert [r.rid for r in done] == [0]
    assert req.out == [first]


def test_step_issues_single_decode_call():
    """One engine step == exactly one jitted decode dispatch, whatever the
    slot count / occupancy."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=4, max_len=64)
    calls = []
    inner = eng._decode
    eng._decode = lambda *a, **k: (calls.append(1), inner(*a, **k))[1]
    for r in _mk_requests(cfg, 6, max_new=4):
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 100
    assert len(calls) == steps


def _serve(cfg, params, reqs, *, slots, seed=0, **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=64, seed=seed, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


@pytest.mark.parametrize("sampling", [
    SamplingParams(greedy=True),
    SamplingParams(temperature=2.5),
    SamplingParams(temperature=1.5, top_k=8),
])
def test_batched_decode_matches_sequential(sampling):
    """Bit-for-bit equivalence: the same requests served at slots=4 and
    slots=1 (sequential) emit identical token streams, greedy AND seeded
    sampling — per-request key streams make the draw independent of batch
    composition, and the vmapped decode is bit-identical per slot."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))

    def reqs():
        return _mk_requests(cfg, 6, max_new=5, sampling=sampling, seed=7)

    batched = _serve(cfg, params, reqs(), slots=4)
    sequential = _serve(cfg, params, reqs(), slots=1)
    assert batched == sequential


def test_mixed_sampling_params_in_one_batch():
    """Slots may mix greedy and different temperatures; each request keeps
    the stream it would have gotten alone in the engine."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    kinds = [SamplingParams(greedy=True), SamplingParams(temperature=3.0),
             SamplingParams(temperature=0.5, top_k=4), None]
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in kinds]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=5, sampling=k)
                for i, (p, k) in enumerate(zip(prompts, kinds))]

    mixed = _serve(cfg, params, reqs(), slots=4)
    alone = [_serve(cfg, params, [r], slots=1)[0] for r in reqs()]
    assert mixed == alone


# ---------------------------------------------------------------------------
# Paged KV plane: bit-identity with dense, bucketed-prefill compile bounds,
# and the block lifecycle (alloc/free, reuse, backpressure)
# ---------------------------------------------------------------------------
def _mk_varied(cfg, n, *, max_new=5, seed=7, sampling=None):
    """Requests with pairwise-distinct prompt lengths (3, 5, 7, ...)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + 2 * i),
                    max_new_tokens=max_new, sampling=sampling)
            for i in range(n)]


def _serve_kv(cfg, params, reqs, *, kv_impl, slots=4, **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=64,
                      kv_impl=kv_impl, **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    return eng, done, [r.out for r in reqs]


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("sampling", [
    SamplingParams(greedy=True),
    SamplingParams(temperature=2.5, top_k=8),
])
def test_paged_decode_bit_identical_to_dense(arch, sampling):
    """The acceptance bar for the paged memory plane: identical token
    streams to the dense engine for the same requests — greedy AND seeded
    sampling, GQA and MLA, across slot reuse and distinct prompt lengths
    (so block allocation, table gathers, stale-block masking, and the
    bucketed prefill are all on the hot path)."""
    cfg = _cfg(arch)
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, _, dense = _serve_kv(cfg, params, _mk_varied(cfg, 6, sampling=sampling),
                            kv_impl="dense")
    _, _, paged = _serve_kv(cfg, params, _mk_varied(cfg, 6, sampling=sampling),
                            kv_impl="paged")
    assert dense == paged


def test_paged_batched_matches_sequential():
    """Slot placement independence holds on the paged plane too: slots=4
    and slots=1 emit identical streams (per-request key streams + per-row
    table gathers)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    s = SamplingParams(temperature=1.5, top_k=8)
    _, _, batched = _serve_kv(cfg, params, _mk_varied(cfg, 6, sampling=s),
                              kv_impl="paged", slots=4)
    _, _, seq = _serve_kv(cfg, params, _mk_varied(cfg, 6, sampling=s),
                          kv_impl="paged", slots=1)
    assert batched == seq


@pytest.mark.parametrize("kv_impl,attend_impl", [
    ("dense", "gather"), ("paged", "gather"), ("paged", "pallas")])
def test_prefill_compile_count_bounded_by_buckets(kv_impl, attend_impl):
    """The bucketed-prefill guarantee, enforced: serving 7 requests with 7
    distinct prompt lengths (spanning 2 of the 3 buckets at max_len=64)
    compiles at most len(buckets) prefills — here exactly 2 — and exactly
    2 decode variants (argmax-only + sampling), not one per length. Holds
    for the block-walking kernel decode too (the kernel's shapes depend on
    the pool geometry, never on a request's length)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl=kv_impl,
                      paged_attend_impl=attend_impl)
    assert eng.buckets == (16, 32, 64)
    rng = np.random.default_rng(0)
    for i, plen in enumerate([3, 5, 9, 13, 16, 19, 25]):   # buckets 16 + 32
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
                           max_new_tokens=3,
                           sampling=(SamplingParams(temperature=2.0)
                                     if i % 2 else SamplingParams(greedy=True))))
    done = eng.run()
    assert len(done) == 7
    counts = eng.compile_counts()
    assert counts["prefill"] == 2, counts
    assert counts["prefill"] <= len(eng.buckets)
    assert counts["decode"] == 2, counts


def test_paged_blocks_alloc_and_free_on_finish():
    """Every finished request returns its blocks: after run() the pool is
    empty, and serving more requests than slots proves slot/block reuse."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng, done, _ = _serve_kv(cfg, params, _mk_varied(cfg, 6, max_new=4),
                             kv_impl="paged", slots=2)
    assert len(done) == 6
    st = eng.pager.stats()
    assert st.blocks_in_use == 0
    assert st.allocs == 6                        # one per admitted request
    assert 0 < st.peak_in_use <= 2 * eng.max_blocks   # never above 2 slots
    assert st.blocks_free == st.num_blocks - 1


def test_paged_pool_exhaustion_backpressure():
    """A queue head that does not fit the pool WAITS (no crash, no drop):
    with 2 allocatable blocks and 2-block requests, exactly one request is
    in flight at a time, every request still completes, and the pager
    records the backpressure events."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    # plen 3 + max_new 20 -> 23 positions -> 2 blocks of 16
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3),
                    max_new_tokens=20) for i in range(3)]
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      num_blocks=3)             # 2 allocatable + scratch
    for r in reqs:
        eng.submit(r)
    peak_active = 0
    steps = 0
    while eng._queue or any(a is not None for a in eng._active):
        peak_active = max(peak_active, eng.step())
        steps += 1
        assert steps < 300
    assert all(r.done and len(r.out) == 20 for r in reqs)
    assert peak_active == 1                      # pool-serialized, not slots
    assert eng.pager.stats().alloc_failures > 0
    assert eng.pager.stats().blocks_in_use == 0


def test_paged_impossible_request_rejected_at_submit():
    """A request larger than the whole pool is rejected the moment it is
    submitted (req.error set, done, no tokens) instead of head-of-line-
    blocking the queue forever — and the engine keeps serving admissible
    requests submitted around it."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=1, max_len=64, kv_impl="paged",
                      num_blocks=2)              # 1 allocatable block
    rng = np.random.default_rng(0)
    ok_before = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 4),
                        max_new_tokens=4)
    too_big = Request(rid=1,
                      prompt=np.arange(40, dtype=np.int32) % cfg.vocab_size,
                      max_new_tokens=8)
    ok_after = Request(rid=2, prompt=rng.integers(0, cfg.vocab_size, 4),
                       max_new_tokens=4)
    eng.submit(ok_before)
    eng.submit(too_big)
    eng.submit(ok_after)
    assert too_big.done and too_big.error is not None
    assert "KV blocks" in too_big.error and too_big.out == []
    done = eng.run()                             # engine keeps serving
    assert sorted(r.rid for r in done) == [0, 1, 2]
    for r in (ok_before, ok_after):
        assert r.done and r.error is None and len(r.out) == 4


def test_completion_order_stable_under_mixed_max_new():
    """run() completion order under mixed max_new_tokens is deterministic
    and identical across KV impls: short-budget requests sharing the batch
    finish first, and two runs agree exactly."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    budgets = [9, 2, 6, 2, 12, 4]

    def run_once(kv_impl):
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4),
                        max_new_tokens=b) for i, b in enumerate(budgets)]
        eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl=kv_impl)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert [len(r.out) for r in sorted(done, key=lambda r: r.rid)] == budgets
        return [r.rid for r in done]

    dense = run_once("dense")
    paged = run_once("paged")
    assert dense == run_once("dense")            # deterministic
    assert dense == paged                        # impl-independent ordering
    assert dense.index(1) < dense.index(0)       # 2-token beats 9-token


def test_recurrent_arch_prefill_not_padded():
    """Bucket padding must NOT leak into recurrent state: mamba/xlstm scans
    fold every prefill token into their state (there is no causal mask to
    hide a pad tail), so recurrent-family archs prefill at exact prompt
    length and the engine still matches a manual prefill+argmax loop for a
    prompt whose length is no bucket width."""
    cfg = _cfg("xlstm-1.3b")
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7, 11, 2], np.int32)     # 5: not a bucket

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run()

    cache = tf.init_cache(cfg, 1, 32, jnp.float32)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, cache, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        nxt, cache = decode(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(nxt[0]))
    assert req.out == toks


def test_paged_rejects_recurrent_archs():
    """Paged KV is an attention-cache feature: recurrent state is O(1) and
    block-aligned padded prefill would contaminate it, so the engine
    refuses instead of silently serving wrong tokens."""
    cfg = _cfg("xlstm-1.3b")
    params = tf.init(cfg, jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="attention"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="paged")


def test_budget_past_max_len_truncated_not_corrupted():
    """A budget that would decode past max_len is truncated to fit
    (max_len - prompt + 1 tokens) instead of writing beyond the cache:
    unclamped, dense clamps its update into the last position while paged
    overwrites a live block through the clipped table index — garbage, and
    *different* garbage, so this also guards the bit-identity contract."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 40)

    def serve(kv_impl):
        req = Request(rid=0, prompt=prompt, max_new_tokens=30)
        eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl=kv_impl)
        eng.submit(req)
        eng.run()
        return req.out

    dense, paged = serve("dense"), serve("paged")
    assert len(dense) == 64 - 40 + 1             # truncated, not overrun
    assert dense == paged


def test_paged_memory_footprint_below_dense():
    """The point of paging: a pool sized well below slots x max_len serves
    the same workload with identical outputs. Dense pins 4 slots x 64
    positions = 16 blocks; this pool holds 8."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    reqs = lambda: _mk_varied(cfg, 6, max_new=4)          # noqa: E731
    _, _, dense = _serve_kv(cfg, params, reqs(), kv_impl="dense", slots=4)
    eng, _, paged = _serve_kv(cfg, params, reqs(), kv_impl="paged", slots=4,
                              num_blocks=9)               # 8 allocatable
    assert paged == dense
    st = eng.pager.stats()
    assert st.peak_in_use <= 8 < eng.slots * eng.max_blocks


def test_stack_insert_take_slot_roundtrip():
    cfg = _cfg()
    caches = [tf.init_cache(cfg, 1, 16, jnp.float32) for _ in range(3)]
    caches[1] = jax.tree.map(lambda a: a + 1.0 if a.dtype == jnp.float32
                             else a + 1, caches[1])
    stacked = tf.stack_caches(caches)
    for i, c in enumerate(caches):
        got = tf.take_slot(stacked, i)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(c)))
    stacked2 = tf.insert_slot(stacked, caches[1], 2)
    got = tf.take_slot(stacked2, 2)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(got),
                               jax.tree.leaves(caches[1])))
