"""Serving engine tests: prefill/decode steps, continuous batching slots."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine, make_decode_step, make_prefill_step


def _cfg():
    return configs.get_smoke("yi-9b", act_impl="exact")


def test_decode_step_shapes():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg)
    nxt, cache = decode(params, cache, jnp.zeros((2, 1), jnp.int32))
    assert nxt.shape == (2,)
    assert int(jax.tree.leaves({"i": cache["seg0"]["idx"]})[0][0]) == 1


def test_engine_serves_batch():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+argmax loop for the same prompt."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    prompt = np.asarray([3, 5, 7], np.int32)

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    while eng.step():
        pass

    cache = tf.init_cache(cfg, 1, 32, jnp.float32)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, cache, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        nxt, cache = decode(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(nxt[0]))
    assert req.out == toks


def test_sampling_decode_threads_rng():
    """Non-greedy decode consumes a per-step key: same key -> same sample,
    fresh keys -> the draw actually varies (the seed bug reused PRNGKey(0)
    every step, freezing temperature sampling)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 2, 32, jnp.float32)
    decode = make_decode_step(cfg, greedy=False, temperature=3.0)
    toks = jnp.zeros((2, 1), jnp.int32)

    a1, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    a2, _ = decode(params, cache, toks, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    draws = {tuple(np.asarray(decode(params, cache, toks,
                                     jax.random.PRNGKey(s))[0]))
             for s in range(8)}
    assert len(draws) > 1, "identical samples across 8 distinct keys"

    import pytest
    with pytest.raises(ValueError, match="rng"):
        decode(params, cache, toks)


def test_engine_sampling_varies_across_steps():
    """ServeEngine(greedy=False) emits a non-degenerate token stream and is
    reproducible for a fixed seed."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))

    def run(seed):
        eng = ServeEngine(cfg, params, slots=1, max_len=64, greedy=False,
                          temperature=3.0, seed=seed)
        req = Request(rid=0, prompt=np.asarray([2, 4, 6], np.int32),
                      max_new_tokens=12)
        eng.submit(req)
        while eng.step():
            pass
        return req.out

    out_a, out_a2, out_b = run(0), run(0), run(123)
    assert out_a == out_a2                       # seed-deterministic
    assert len(set(out_a)) > 1                   # not frozen on one token
    assert out_a != out_b                        # seed actually matters
    assert all(0 <= t < cfg.vocab_size for t in out_a)
