"""Fused CORDIC softmax kernel: accuracy vs jax.nn.softmax, masking
semantics, differentiability, and the attention/serve wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

SHAPES = [(8, 128), (5, 130), (3, 257), (64, 1000), (1, 7), (2, 4, 96),
          (16, 2048)]


def _logits(shape, seed=0, scale=4.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


@pytest.mark.parametrize("shape", SHAPES)
def test_softmax_matches_exact(shape):
    x = _logits(shape)
    got = np.asarray(ops.softmax(x))
    want = np.asarray(jax.nn.softmax(x))
    assert np.abs(got - want).max() < 1e-2       # acceptance bound
    assert np.abs(got - want).max() < 2e-3       # measured headroom
    assert np.abs(got.sum(-1) - 1.0).max() < 5e-3


def test_softmax_axis_argument():
    x = _logits((6, 33, 5))
    got = np.asarray(ops.softmax(x, 1))
    want = np.asarray(jax.nn.softmax(x, axis=1))
    assert np.abs(got - want).max() < 2e-3


def test_softmax_masked_lanes_flush_to_zero():
    """-1e30 masked positions (attention padding) produce exactly 0."""
    x = _logits((4, 96), seed=2)
    x = x.at[:, 50:].set(-1e30)
    got = np.asarray(ops.softmax(x))
    want = np.asarray(jax.nn.softmax(x))
    assert (got[:, 50:] == 0.0).all()
    assert np.abs(got - want).max() < 2e-3


def test_softmax_fully_masked_row_uniform():
    x = jnp.full((2, 64), -1e30, jnp.float32)
    got = np.asarray(ops.softmax(x))
    assert np.abs(got - 1.0 / 64).max() < 1e-3


def test_softmax_extreme_logits():
    """Large spread: peaked rows stay normalized, small probs underflow to 0."""
    x = jnp.asarray([[0.0, -50.0, -10.0, 30.0] + [-1e30] * 4], jnp.float32)
    got = np.asarray(ops.softmax(x))
    want = np.asarray(jax.nn.softmax(x))
    assert np.abs(got - want).max() < 2e-3
    assert abs(got.sum() - 1.0) < 5e-3


def test_softmax_bf16_dtype_preserved():
    x = _logits((8, 256)).astype(jnp.bfloat16)
    got = ops.softmax(x)
    assert got.dtype == jnp.bfloat16
    want = jax.nn.softmax(x.astype(jnp.float32))
    assert np.abs(np.asarray(got, np.float32) - np.asarray(want)).max() < 8e-3


def test_softmax_grad_matches_exact_softmax_grad():
    x = _logits((4, 64), seed=5, scale=2.0)
    w = jax.random.normal(jax.random.PRNGKey(9), (4, 64))
    g = jax.grad(lambda v: jnp.sum(ops.softmax(v) * w))(x)
    g_ref = jax.grad(lambda v: jnp.sum(jax.nn.softmax(v) * w))(x)
    assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() < 2e-2


def test_softmax_fixed_jnp_path_grad():
    """The cordic_fixed attention path must use the custom_jvp wrapper —
    raw differentiation through quantize/frexp boundary ops is garbage."""
    from repro.cordic_engine import functions as F

    x = _logits((4, 16), seed=6, scale=2.0)
    w = jax.random.normal(jax.random.PRNGKey(11), (4, 16))
    g = jax.grad(lambda v: jnp.sum(F.softmax(v) * w))(x)
    g_ref = jax.grad(lambda v: jnp.sum(jax.nn.softmax(v) * w))(x)
    assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() < 2e-2


def test_softmax_jit_compose():
    x = _logits((8, 128))
    a = np.asarray(jax.jit(lambda v: ops.softmax(v))(x))
    b = np.asarray(ops.softmax(x))
    np.testing.assert_allclose(a, b, atol=1e-7)


# ---------------------------------------------------------------------------
# Attention / serve wiring
# ---------------------------------------------------------------------------
def test_causal_attention_with_cordic_softmax():
    from repro.models.attention import causal_attention

    B, S, KH, G, D = 1, 16, 2, 2, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, KH, G, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, KH, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, KH, D), jnp.float32)
    o_exact = causal_attention(q, k, v)
    o_cordic = causal_attention(q, k, v, softmax_impl="cordic_pallas")
    assert np.abs(np.asarray(o_cordic) - np.asarray(o_exact)).max() < 2e-2


def test_model_forward_with_cordic_softmax():
    from repro import configs
    from repro.models import transformer as tf

    cfg = configs.get_smoke("yi-9b", act_impl="exact")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = {"tokens": jnp.ones((1, 8), jnp.int32)}
    ref, _, _ = tf.apply(params, toks, cfg)
    for impl in ("cordic_pallas", "cordic_fixed"):
        cfg_i = dataclasses.replace(cfg, softmax_impl=impl)
        out, _, _ = tf.apply(params, toks, cfg_i)
        assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 5e-2, impl


def test_serve_engine_softmax_override():
    from repro import configs
    from repro.models import transformer as tf
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke("yi-9b", act_impl="exact")
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=1, max_len=32,
                      softmax_impl="cordic_pallas")
    assert eng.cfg.softmax_impl == "cordic_pallas"
    req = Request(rid=0, prompt=np.asarray([3, 1, 4], np.int32), max_new_tokens=4)
    eng.submit(req)
    while eng.step():
        pass
    assert len(req.out) == 4
    assert all(0 <= t < cfg.vocab_size for t in req.out)
