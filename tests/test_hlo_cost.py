"""Validation of the scan-corrected HLO cost analyzer: a scanned model must
yield the same corrected flops as its unrolled twin (which XLA counts
fully), while raw cost_analysis undercounts the scan by the trip count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost

L, M, K = 8, 128, 256


def _layer(p, x):
    return jnp.tanh(x @ p)


def _scan_model(ps, x):
    def body(c, p):
        return _layer(p, c), None
    y, _ = jax.lax.scan(body, x, ps)
    return y.sum()


def _loop_model(ps, x):
    for i in range(L):
        x = _layer(ps[i], x)
    return x.sum()


@pytest.fixture(scope="module")
def compiled():
    ps = jax.ShapeDtypeStruct((L, K, K), jnp.float32)
    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    return {name: jax.jit(fn).lower(ps, x).compile()
            for name, fn in (("scan", _scan_model), ("loop", _loop_model))}


def _raw_flops(c):
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def test_raw_cost_analysis_undercounts_scan(compiled):
    """The bug this module exists for: raw flops(scan) ~ flops(loop)/L."""
    raw_scan = _raw_flops(compiled["scan"])
    raw_loop = _raw_flops(compiled["loop"])
    assert raw_scan < raw_loop / (L / 2)


def test_corrected_flops_match_unrolled(compiled):
    analytic = L * 2 * M * K * K
    got_scan = hlo_cost.analyze(compiled["scan"].as_text())["flops"]
    got_loop = hlo_cost.analyze(compiled["loop"].as_text())["flops"]
    assert got_scan == pytest.approx(analytic, rel=0.1)
    assert got_loop == pytest.approx(analytic, rel=0.1)
    assert got_scan == pytest.approx(got_loop, rel=0.1)


def test_corrected_bytes_scale_with_trip_count(compiled):
    b_scan = hlo_cost.analyze(compiled["scan"].as_text())["hbm_bytes"]
    b_loop = hlo_cost.analyze(compiled["loop"].as_text())["hbm_bytes"]
    # same order of magnitude (fusion decisions differ scan vs unrolled)
    assert b_loop / 3 <= b_scan <= b_loop * 3
    # dominated by the L weight reads + activations, not the once-counted body
    analytic_weights = L * K * K * 4
    assert b_scan > analytic_weights


def test_collectives_multiplied_by_trips():
    """An all-reduce inside a scan body must count trip-count times."""
    mesh = jax.make_mesh((1,), ("d",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    def inner(x):
        def body(c, _):
            return jax.lax.psum(c, "d") * 0.5, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    f = shard_map(inner, mesh=mesh, in_specs=PS(), out_specs=PS())
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
    out = hlo_cost.analyze(c.as_text())
    ar = out["collective_bytes_by_kind"].get("all-reduce", 0)
    assert ar == pytest.approx(5 * 64 * 4, rel=0.01), out
