"""Reproduction of the paper's own quantitative claims (Sections 3-4).

Every assertion here maps to a number printed in the paper; deviations are
documented in DESIGN.md section 9.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cordic as C
from repro.core import sigmoid as S
from repro.core.errors import error_stats


SCHED = C.PAPER_SCHEDULE


class TestConvergenceArithmetic:
    def test_r2_convergence_range_covers_half(self):
        """Paper: R2-HRC j=2..9 covers the required |z| <= 0.5 (eq. 5).

        Paper prints 0.5688; exact evaluation gives 0.50421 — still >= 0.5.
        """
        assert SCHED.r2_range == pytest.approx(0.504210, abs=1e-6)
        assert SCHED.r2_range >= 0.5

    def test_r4_start_range_matches_paper(self):
        """Paper: radix-4 admissible start range at j=4 is ~0.0104 (eq. 6)."""
        assert SCHED.r4_range == pytest.approx(0.0104, abs=2e-4)

    def test_r2_residual_matches_paper(self):
        """Paper: residual after R2 j=2..9 is ~0.0061 (the no-repeat gaps).

        Measured worst case on a dense grid is ~0.0066; the radix-4 stage's
        0.0104 admissible range covers it, so the handoff is error-free.
        """
        z = jnp.linspace(-0.5, 0.5, 100001, dtype=jnp.float32)
        res = float(jnp.max(C.r2_residual_f(z, SCHED)))
        assert res == pytest.approx(0.0061, abs=1.5e-3)
        assert res <= SCHED.r4_range

    def test_r4_scale_factor_is_unity_at_16bit(self):
        """Paper: starting R4 at j=4 makes the gain ~1 (scale-free).

        The worst-case cumulative radix-4 gain deviation must be below the
        16-bit ULP (2^-14), so no compensation hardware is needed.
        """
        lo, hi = SCHED.r4_gain_bounds
        assert hi == 1.0
        assert 1.0 - lo < 2.0 ** -14

    def test_lvc_domain(self):
        """Paper: |y/x| = |tanh(0.5)| ~ 0.52 << 2, inside the LVC domain."""
        assert math.tanh(0.5) < 2.0

    def test_kh_constant(self):
        assert SCHED.r2_gain == pytest.approx(0.958150, abs=1e-6)
        assert SCHED.x0 == pytest.approx(1.043678, abs=1e-6)


class TestAccuracyClaims:
    def test_mae_meets_paper_table2(self):
        """Paper Table 2: proposed achieves MAE 4.23e-4 at 16 bits.

        Our full pipeline (LVC j=1..14) achieves ~6.4e-5, comfortably inside
        the paper's claim; asserted against the paper's number as the bound.
        """
        st = error_stats(lambda x: S.sigmoid_cordic_fixed(x), S.sigmoid_exact, -1, 1)
        assert st["mae"] <= 4.23e-4
        assert st["max"] <= 1e-3

    def test_paper_mae_reproducible_with_9_lvc_iterations(self):
        """With LVC truncated at j=9 the MAE lands at ~4.9e-4 ~ the paper's
        4.23e-4 — the likely provenance of the published figure."""
        sched = C.MRSchedule(lvc_js=tuple(range(1, 10)))
        st = error_stats(lambda x: S.sigmoid_cordic_fixed(x, sched), S.sigmoid_exact, -1, 1)
        assert 2e-4 <= st["mae"] <= 8e-4

    def test_float_algorithm_error_floor(self):
        """Algorithmic (unquantized) error of MR-HRC is < 5e-5: quantization,
        not the mixed-radix math, dominates the fixed-point error."""
        st = error_stats(lambda x: S.sigmoid_cordic_float(x), S.sigmoid_exact, -1, 1)
        assert st["max"] <= 5e-5

    def test_beats_prior_art_families(self):
        """Table 2 ordering at the same bit budget & domain: the proposed
        pipeline beats the PWL-8, LUT-256/64 families it is compared to."""
        prop = error_stats(lambda x: S.sigmoid_cordic_fixed(x), S.sigmoid_exact, -1, 1)
        for name in ("pwl_8seg [11]", "lut_256 [10]", "lut_64 [10]"):
            other = error_stats(S.TABLE2_METHODS[name], S.sigmoid_exact, -1, 1)
            assert prop["mae"] < other["mae"], name

    def test_mixed_radix_fewer_iterations_than_radix2(self):
        """The point of mixed radix: fewer iterations at equal-or-better MAE
        than the conventional radix-2 schedule (with textbook repeats)."""
        mr = SCHED.num_iterations()
        r2 = C.R2_BASELINE_SCHEDULE.num_iterations()
        assert mr < r2
        st_mr = error_stats(lambda x: S.sigmoid_cordic_fixed(x), S.sigmoid_exact, -1, 1)
        st_r2 = error_stats(S.TABLE2_METHODS["r2_cordic_q2.14 [9]"], S.sigmoid_exact, -1, 1)
        assert st_mr["mae"] <= st_r2["mae"] * 1.05

    def test_dsp_free_resource_model(self):
        """Table 1 analog: zero multipliers/dividers in the datapath."""
        r = C.shift_add_op_count(SCHED)
        assert r["multipliers"] == 0 and r["dividers"] == 0 and r["dsp"] == 0
        assert r["iterations"] == 26


class TestRangeExtension:
    def test_wide_range_sigmoid(self):
        """Beyond-paper: dyadic range extension holds error < 2e-3 on [-8,8]."""
        st = error_stats(lambda x: S.sigmoid_cordic_wide(x), S.sigmoid_exact, -8, 8)
        assert st["mae"] <= 2e-3

    def test_wide_equals_paper_inside_unit_domain(self):
        x = jnp.linspace(-1, 1, 4001, dtype=jnp.float32)
        a = S.sigmoid_cordic_wide(x)
        b = S.sigmoid_cordic_fixed(x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
