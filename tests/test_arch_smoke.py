"""Per-architecture smoke tests: instantiate the REDUCED config of each
family, run one forward + one train(grad) step + one decode step on CPU;
assert output shapes and finiteness. The FULL configs are exercised only by
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.models import frontends

B, S = 2, 32


def _batch(cfg, key=0):
    rng = np.random.default_rng(key)
    if cfg.input_mode == "tokens":
        toks = rng.integers(0, cfg.vocab_size, (B, S))
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
    emb = frontends.audio_frame_embeddings(B, S, cfg.d_model)
    labels = rng.integers(0, cfg.vocab_size, (B, S))
    return {"embeds": emb, "labels": jnp.asarray(labels, jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_shapes(arch):
    cfg = configs.get_smoke(arch)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, _ = tf.apply(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = configs.get_smoke(arch)
    params = tf.init(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, key=1)
    (loss, metrics), grads = jax.value_and_grad(tf.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    # at least 99% of grad leaves should be non-zero somewhere (wired up)
    nonzero = sum(int(np.abs(np.asarray(g)).sum() > 0) for g in flat)
    assert nonzero >= int(0.8 * len(flat)), f"{nonzero}/{len(flat)} live grads"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = tf.init(cfg, jax.random.PRNGKey(2))
    cache = tf.init_cache(cfg, batch=B, max_len=64, dtype=jnp.float32)
    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    else:
        batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    logits, _, new_cache = tf.apply(params, batch, cfg, cache=cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert new_cache is not None


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "deepseek-v2-lite-16b",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_prefill_then_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced forward on same tokens.

    MoE capacity dropping depends on the token count, so for this exactness
    check the capacity factor is raised until nothing drops (the drop
    behaviour itself is exercised in test_models.py)."""
    import dataclasses

    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = tf.init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    T = 12
    if cfg.input_mode == "tokens":
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        full_logits, _, _ = tf.apply(params, {"tokens": toks}, cfg)
        cache = tf.init_cache(cfg, batch=B, max_len=32, dtype=jnp.float32)
        pre_logits, _, cache = tf.apply(params, {"tokens": toks[:, :T - 1]},
                                        cfg, cache=cache)
        dec_logits, _, _ = tf.apply(params, {"tokens": toks[:, T - 1:T]},
                                    cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                                   np.asarray(full_logits[:, -1]),
                                   atol=2e-2, rtol=2e-2)


def test_param_counts_sane():
    """Full-config param counts land near the advertised sizes."""
    expect = {
        "qwen2.5-32b": (31e9, 36e9),
        "command-r-plus-104b": (98e9, 118e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "yi-9b": (8e9, 10e9),
        # assignment fixes 48L x 2048; with the mLSTM proj_factor 2.0 that is
        # ~3.7B params (the "1.3b" name matches the original 24-block config;
        # DESIGN.md deviation 8)
        "xlstm-1.3b": (1.0e9, 3.8e9),
        # decoder backbone only (T5 text encoder + EnCodec are stubbed per
        # the assignment spec); published 3.3B includes the frontends
        "musicgen-large": (2.2e9, 3.6e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "internvl2-1b": (0.5e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = configs.get_config("phi3.5-moe-42b-a6.6b")
    pc = cfg.param_counts()
    # a6.6b: active ~6.6B (plus embeddings)
    assert 5e9 <= pc["active"] <= 8e9
    assert pc["active"] < pc["total"]
