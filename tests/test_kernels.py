"""Pallas kernel validation vs the pure-jnp oracle (ref.py).

Sweeps shapes (incl. ragged tails), dtypes, and ops; the integer path must
be bit-exact, float paths exact-to-f32 (same math, same order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed_point as fp
from repro.kernels import ops, ref

SHAPES = [(8,), (100,), (128,), (257,), (8, 128), (16, 1000), (4, 3, 65),
          (2, 5, 7, 33), (1,), (2048,), (3, 4096)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, lo=-1.0, hi=1.0, seed=0):
    rng = np.random.default_rng(seed + int(np.prod(shape)))
    return jnp.asarray(rng.uniform(lo, hi, size=shape), dtype=dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sigmoid_matches_oracle(shape, dtype):
    x = _rand(shape, dtype)
    got = ops.sigmoid(x)
    want = ref.sigmoid_ref(x.astype(jnp.float32)).astype(dtype)
    assert got.shape == shape and got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=(1e-6 if dtype == jnp.float32 else 4e-3))


@pytest.mark.parametrize("shape", [(8, 128), (100,), (16, 1000)])
def test_sigmoid_bit_exact_f32(shape):
    """f32 in-domain: kernel and oracle produce identical Q2.14 codes."""
    x = _rand(shape, jnp.float32)
    got = np.asarray(ops.sigmoid(x))
    want = np.asarray(ref.sigmoid_ref(x))
    code_g = np.round(got * fp.Q2_14.scale)
    code_w = np.round(want * fp.Q2_14.scale)
    np.testing.assert_array_equal(code_g, code_w)


@pytest.mark.parametrize("dtype", [jnp.int16, jnp.int32])
@pytest.mark.parametrize("shape", [(128,), (8, 128), (300,)])
def test_sigmoid_q_bit_exact(dtype, shape):
    """Integer path is bit-exact vs the Q2.14 oracle."""
    rng = np.random.default_rng(7)
    xq = jnp.asarray(rng.integers(-(1 << 14), (1 << 14) + 1, size=shape), dtype)
    got = np.asarray(ops.sigmoid_q(xq), np.int32)
    want = np.asarray(ref.sigmoid_q_ref(xq.astype(jnp.int32)), np.int32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [(64,), (8, 256)])
def test_tanh_matches_oracle(shape):
    x = _rand(shape, jnp.float32, -0.5, 0.5)
    got = np.asarray(ops.tanh(x))
    want = np.asarray(ref.tanh_ref(x))
    # direct angle feed: bit-identical Q2.14 codes
    np.testing.assert_array_equal(np.round(got * fp.Q2_14.scale),
                                  np.round(want * fp.Q2_14.scale))
    exact = np.tanh(np.asarray(x, np.float64))
    assert np.abs(got - exact).max() < 1e-3


@pytest.mark.parametrize("shape", [(512,), (8, 300)])
def test_silu_and_wide(shape):
    x = _rand(shape, jnp.float32, -6.0, 6.0, seed=3)
    got_s = np.asarray(ops.sigmoid_wide(x))
    exact_s = 1.0 / (1.0 + np.exp(-np.asarray(x, np.float64)))
    assert np.abs(got_s - exact_s).max() < 6e-3
    got = np.asarray(ops.silu(x))
    want = np.asarray(ref.silu_ref(x))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_silu_mul_fused_matches_unfused():
    g = _rand((16, 512), jnp.float32, -4, 4, seed=11)
    u = _rand((16, 512), jnp.float32, -2, 2, seed=12)
    got = np.asarray(ops.silu_mul(g, u))
    want = np.asarray(u) * np.asarray(ops.silu(g))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_gradients_flow():
    x = _rand((64,), jnp.float32, -3, 3, seed=5)
    for f in (ops.sigmoid_wide, ops.silu, ops.tanh):
        g = jax.grad(lambda v: jnp.sum(f(v)))(x)
        assert np.isfinite(np.asarray(g)).all()
    gg = jax.grad(lambda v: jnp.sum(ops.silu_mul(v, x)))(x)
    assert np.isfinite(np.asarray(gg)).all()


def test_jit_and_vmap_compose():
    x = _rand((4, 64), jnp.float32)
    a = jax.jit(ops.sigmoid)(x)
    b = jax.vmap(ops.sigmoid)(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


# ---------------------------------------------------------------------------
# 2D kernel entry points: integer path + fused SwiGLU on non-aligned shapes
# (exercises the _grid_and_specs sublane/lane padding directly)
# ---------------------------------------------------------------------------
from repro.kernels import cordic_act as KA  # noqa: E402
from repro.kernels.ops import _use_interpret  # noqa: E402

UNALIGNED_2D = [(8, 128), (5, 130), (3, 257), (100, 1000), (1, 1), (7, 100),
                (300, 129)]


@pytest.mark.parametrize("dtype", [jnp.int16, jnp.int32])
@pytest.mark.parametrize("shape", UNALIGNED_2D)
def test_act_q_2d_bit_exact(dtype, shape):
    """act_q_2d (Q2.14 codes end-to-end) is bit-exact vs the jnp oracle on
    aligned and ragged tiles alike."""
    rng = np.random.default_rng(13 + shape[0])
    xq = jnp.asarray(rng.integers(-(1 << 14), (1 << 14) + 1, size=shape), dtype)
    got = KA.act_q_2d(xq, interpret=_use_interpret())
    assert got.shape == shape and got.dtype == dtype
    want = ref.sigmoid_q_ref(xq.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.int32),
                                  np.asarray(want, np.int32))


def test_act_q_2d_int16_roundtrip_is_lossless():
    """Sigmoid codes lie in [0, 2^14] — int16 storage loses nothing."""
    xq32 = jnp.asarray(
        np.random.default_rng(3).integers(-(1 << 14), (1 << 14) + 1,
                                          size=(16, 256)), jnp.int32)
    got16 = KA.act_q_2d(xq32.astype(jnp.int16), interpret=_use_interpret())
    got32 = KA.act_q_2d(xq32, interpret=_use_interpret())
    np.testing.assert_array_equal(np.asarray(got16, np.int32),
                                  np.asarray(got32, np.int32))


@pytest.mark.parametrize("shape", UNALIGNED_2D)
def test_silu_mul_2d_matches_oracle_unaligned(shape):
    g = _rand(shape, jnp.float32, -4, 4, seed=21)
    u = _rand(shape, jnp.float32, -2, 2, seed=22)
    got = KA.silu_mul_2d(g, u, interpret=_use_interpret())
    assert got.shape == shape
    want = np.asarray(u) * np.asarray(ref.silu_ref(g))
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=2e-5)


def test_silu_mul_2d_padding_region_not_leaked():
    """Ragged tiles: the lane/sublane padding must not corrupt real outputs
    (compare the ragged result against an aligned superset computation)."""
    g = _rand((130, 257), jnp.float32, -4, 4, seed=31)
    u = _rand((130, 257), jnp.float32, -2, 2, seed=32)
    ragged = np.asarray(KA.silu_mul_2d(g, u, interpret=_use_interpret()))
    gp = jnp.zeros((256, 384), jnp.float32).at[:130, :257].set(g)
    up = jnp.zeros((256, 384), jnp.float32).at[:130, :257].set(u)
    aligned = np.asarray(KA.silu_mul_2d(gp, up,
                                        interpret=_use_interpret()))[:130, :257]
    np.testing.assert_array_equal(ragged, aligned)
