"""Paged-attention kernel conformance: the block-walking Pallas decode
kernels (kernels/paged_attention.py) against the full-table *gather*
reference (kernels/ref.py, the PR-4 path in models/attention.py), plus the
serving-level contract — ``cfg.paged_attend_impl="pallas"`` must emit
token streams bit-identical to the gather path AND to the dense engine
(greedy and seeded sampling, GQA and MLA).

Kernel-level: attention outputs agree with the gather oracle to f32
round-off (the online/block-sequential accumulation reorders float
reductions) and the per-row argmax never moves.  Edge geometry is
exercised explicitly: lengths exactly on / one off block boundaries, a
slot with a single block, vacant slots (all-zero tables scribbling into
scratch block 0), and mixed-length batches.

CI runs this file once per datapath backend via REPRO_TEST_BACKEND in
{"jnp", "pallas_interpret"} (the kernel-conformance step of the
conformance matrix): the attention softmax follows the backend
(cordic_fixed / cordic_pallas), so a drift in one backend's block-walking
normalization is attributed there.  Unset (tier-1), the exact softmax runs.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ops as kops
from repro.kernels import paged_attention as PA
from repro.kernels import ref as kref
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")
assert _BACKEND in _SOFTMAX_BY_BACKEND, \
    f"REPRO_TEST_BACKEND={_BACKEND!r} not in {sorted(filter(None, _SOFTMAX_BY_BACKEND))}"
SOFTMAX_IMPL = _SOFTMAX_BY_BACKEND[_BACKEND]

#: f32 contraction-order tolerance — probabilities are lane-exact vs the
#: reference (see kernels/paged_attention.py), only reduction order differs.
ATOL = 2e-5


def _cfg(arch: str = "yi-9b"):
    return dataclasses.replace(configs.get_smoke(arch, act_impl="exact"),
                               softmax_impl=SOFTMAX_IMPL)


# ---------------------------------------------------------------------------
# Kernel vs gather oracle (GQA)
# ---------------------------------------------------------------------------
def _gqa_case(klen_list, *, L=4, KH=2, G=2, hd=8, seed=0):
    """Pools/tables/lens for a batch of rows with the given live lengths.

    Rows with klen 0 are 'vacant': all-zero table (scratch block 0) and
    k_len pinned to 1, exactly how the engine drives inactive slots."""
    rng = np.random.default_rng(seed)
    B = len(klen_list)
    M = max(-(-k // L) for k in klen_list if k) if any(klen_list) else 1
    N = 1 + B * M
    q = jnp.asarray(rng.normal(size=(B, KH, G, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, L, KH, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, L, KH, hd)), jnp.float32)
    tables = np.zeros((B, M), np.int32)
    nxt = 1
    for b, klen in enumerate(klen_list):
        for c in range(-(-klen // L)):
            tables[b, c] = nxt
            nxt += 1
    k_len = jnp.asarray([max(k, 1) for k in klen_list], jnp.int32)
    return q, kp, vp, jnp.asarray(tables), k_len


def _assert_kernel_matches_ref(q, kp, vp, tables, k_len, scale=0.3):
    got = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=scale,
                                   softmax_impl=SOFTMAX_IMPL, interpret=True))
    want = np.asarray(kref.paged_attend_gqa_ref(q, kp, vp, tables, k_len,
                                                scale=scale,
                                                softmax_impl=SOFTMAX_IMPL))
    assert np.abs(got - want).max() < ATOL, np.abs(got - want).max()
    # token-decision identity at kernel granularity: the per-(kh,g) argmax
    # over the output features must never move
    np.testing.assert_array_equal(got.reshape(got.shape[0], -1).argmax(-1),
                                  want.reshape(want.shape[0], -1).argmax(-1))
    assert np.isfinite(got).all()


@pytest.mark.parametrize("klen", [1, 3, 4, 5, 7, 8, 9, 16])
def test_gqa_kernel_block_boundary_lengths(klen):
    """Lengths exactly on (4, 8, 16) and one off (3, 5, 7, 9) the L=4
    block boundaries, plus the single-element and single-block cases."""
    _assert_kernel_matches_ref(*_gqa_case([klen], seed=klen))


def test_gqa_kernel_single_block_slot():
    _assert_kernel_matches_ref(*_gqa_case([2], L=16))


def test_gqa_kernel_mixed_length_batch():
    """Rows at different lengths (spanning 1..4 live blocks) in one call."""
    _assert_kernel_matches_ref(*_gqa_case([1, 4, 5, 13, 16, 3], seed=3))


def test_gqa_kernel_vacant_slot_reads_scratch():
    """A vacant row (all-zero table, len 0 -> k_len 1) rides along like an
    inactive engine slot: its output is finite garbage from scratch block
    0, and the live rows are bit-unaffected by its presence."""
    q, kp, vp, tables, k_len = _gqa_case([5, 0, 9], seed=4)
    assert int(tables[1].max()) == 0            # vacant -> scratch only
    _assert_kernel_matches_ref(q, kp, vp, tables, k_len)
    # live rows identical with the vacant row removed from the batch
    keep = np.asarray([0, 2])
    full = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                                    softmax_impl=SOFTMAX_IMPL, interpret=True))
    sub = np.asarray(PA.gqa_decode(q[keep], kp, vp,
                                   jnp.asarray(np.asarray(tables)[keep]),
                                   jnp.asarray(np.asarray(k_len)[keep]),
                                   scale=0.3, softmax_impl=SOFTMAX_IMPL,
                                   interpret=True))
    np.testing.assert_array_equal(full[keep], sub)


def test_gqa_kernel_kv_dtype_rounding_matches_gather():
    """The gather path attends K/V cast to x.dtype (bf16 for bf16 models);
    the kernel must apply the same per-block rounding."""
    q, kp, vp, tables, k_len = _gqa_case([7, 12], seed=5)
    got = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                                   softmax_impl=SOFTMAX_IMPL,
                                   kv_dtype=jnp.bfloat16, interpret=True))
    want = np.asarray(kref.paged_attend_gqa_ref(q, kp, vp, tables, k_len,
                                                scale=0.3,
                                                softmax_impl=SOFTMAX_IMPL,
                                                kv_dtype=jnp.bfloat16))
    assert np.abs(got - want).max() < ATOL
    # and it differs from the unrounded attend (the cast is load-bearing)
    raw = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                                   softmax_impl=SOFTMAX_IMPL, interpret=True))
    assert np.abs(got - raw).max() > 0


# ---------------------------------------------------------------------------
# Kernel vs gather oracle (MLA)
# ---------------------------------------------------------------------------
def _mla_case(klen_list, *, L=4, H=4, R=16, P=8, seed=0):
    rng = np.random.default_rng(seed)
    B = len(klen_list)
    M = max(-(-k // L) for k in klen_list if k) if any(klen_list) else 1
    N = 1 + B * M
    qe = jnp.asarray(rng.normal(size=(B, H, R)), jnp.float32)
    qr = jnp.asarray(rng.normal(size=(B, H, P)), jnp.float32)
    cp = jnp.asarray(rng.normal(size=(N, L, R)), jnp.float32)
    rp = jnp.asarray(rng.normal(size=(N, L, P)), jnp.float32)
    tables = np.zeros((B, M), np.int32)
    nxt = 1
    for b, klen in enumerate(klen_list):
        for c in range(-(-klen // L)):
            tables[b, c] = nxt
            nxt += 1
    k_len = jnp.asarray([max(k, 1) for k in klen_list], jnp.int32)
    return qe, qr, cp, rp, jnp.asarray(tables), k_len


@pytest.mark.parametrize("klens", [[1], [4], [5], [8], [9],
                                   [3, 8, 1, 13, 16]])
def test_mla_kernel_matches_ref(klens):
    qe, qr, cp, rp, tables, k_len = _mla_case(klens, seed=len(klens))
    got = np.asarray(PA.mla_decode(qe, qr, cp, rp, tables, k_len, scale=0.2,
                                   softmax_impl=SOFTMAX_IMPL, interpret=True))
    want = np.asarray(kref.paged_attend_mla_ref(qe, qr, cp, rp, tables,
                                                k_len, scale=0.2,
                                                softmax_impl=SOFTMAX_IMPL))
    assert np.abs(got - want).max() < ATOL, np.abs(got - want).max()
    np.testing.assert_array_equal(got.reshape(got.shape[0], -1).argmax(-1),
                                  want.reshape(want.shape[0], -1).argmax(-1))


def test_mla_kernel_vacant_slot():
    qe, qr, cp, rp, tables, k_len = _mla_case([6, 0], seed=9)
    out = np.asarray(PA.mla_decode(qe, qr, cp, rp, tables, k_len, scale=0.2,
                                   softmax_impl=SOFTMAX_IMPL, interpret=True))
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# Serving-level token identity (the acceptance bar)
# ---------------------------------------------------------------------------
def _mk_varied(cfg, n, *, max_new=5, seed=7, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + 2 * i),
                    max_new_tokens=max_new, sampling=sampling)
            for i in range(n)]


def _serve(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, slots=4, max_len=64, seed=0, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("sampling", [
    SamplingParams(greedy=True),
    SamplingParams(temperature=2.5, top_k=8),
])
def test_pallas_decode_tokens_bit_identical(arch, sampling):
    """cfg.paged_attend_impl='pallas' emits token streams bit-identical to
    the gather path AND to the dense engine — greedy and seeded sampling,
    GQA and MLA, across slot reuse and distinct prompt lengths (block
    boundaries, stale blocks, and vacant slots all land on the hot path)."""
    cfg = _cfg(arch)
    params = tf.init(cfg, jax.random.PRNGKey(3))
    dense = _serve(cfg, params, _mk_varied(cfg, 6, sampling=sampling),
                   kv_impl="dense")
    gather = _serve(cfg, params, _mk_varied(cfg, 6, sampling=sampling),
                    kv_impl="paged", paged_attend_impl="gather")
    pallas = _serve(cfg, params, _mk_varied(cfg, 6, sampling=sampling),
                    kv_impl="paged", paged_attend_impl="pallas")
    assert gather == dense
    assert pallas == gather


def test_pallas_decode_crosses_block_boundaries():
    """Prompt/decode lengths engineered so generation crosses block
    boundaries mid-stream (len 15->21 and 16->22 with L=16): tokens match
    the gather path at and across every boundary."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (15, 16, 17)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]

    gather = _serve(cfg, params, reqs(), kv_impl="paged")
    pallas = _serve(cfg, params, reqs(), kv_impl="paged",
                    paged_attend_impl="pallas")
    assert pallas == gather


def test_pallas_engine_with_vacant_slots():
    """Fewer requests than slots: vacant slots decode against scratch
    block 0 every step; tokens still match the gather path."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(4))
    reqs = lambda: _mk_varied(cfg, 2, max_new=6)               # noqa: E731
    gather = _serve(cfg, params, reqs(), kv_impl="paged")
    pallas = _serve(cfg, params, reqs(), kv_impl="paged",
                    paged_attend_impl="pallas")
    assert pallas == gather


def test_pallas_requires_paged_plane():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="dense",
                    paged_attend_impl="pallas")
    with pytest.raises(ValueError, match="paged_attend_impl"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="paged",
                    paged_attend_impl="nope")


def test_pallas_rejects_bf16_mxu_scoring():
    """The kernels score in f32 only; a bf16_mxu gather attend rounds
    differently, so the combination must fail loudly instead of silently
    breaking the token-identity contract."""
    from repro.models.attention import _paged_attend_impl

    cfg = dataclasses.replace(_cfg(), score_dtype="bf16_mxu",
                              paged_attend_impl="pallas")
    with pytest.raises(ValueError, match="score_dtype"):
        _paged_attend_impl(cfg)
    # and the engine fails fast at construction, not mid-serving
    params = tf.init(_cfg(), jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="score_dtype"):
        ServeEngine(dataclasses.replace(cfg, paged_attend_impl="gather"),
                    params, slots=1, max_len=32, kv_impl="paged",
                    paged_attend_impl="pallas")


# ---------------------------------------------------------------------------
# Transient working set: the metric benchmarks/serving.py gates
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
def test_kernel_transient_independent_of_max_len(arch):
    """The point of the kernel: its per-step transient is a function of
    block_len only, while the gather path's scales linearly with max_len."""
    cfg = _cfg(arch)
    tr = lambda impl, ml: PA.decode_transient_bytes(                # noqa: E731
        cfg, max_len=ml, block_len=16, impl=impl)
    assert tr("pallas", 64) == tr("pallas", 1 << 20)
    assert tr("gather", 128) == 2 * tr("gather", 64)
    assert tr("pallas", 1 << 20) < tr("gather", 1 << 20)
