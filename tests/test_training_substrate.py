"""Training-substrate tests: optimizer, data pipeline, checkpointing,
gradient compression, microbatch accumulation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, DataIterator, SyntheticLMDataset
from repro.distributed import compression as comp
from repro.optim import adamw
from repro.train import step as step_lib


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
                            grad_clip=1e9)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = adamw.init(p)
    p2, st2, _ = adamw.apply_updates(p, st, g, cfg)

    gn = np.asarray(g["w"], np.float64)
    m = 0.1 * gn
    v = 0.001 * gn * gn
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    ref = np.asarray(p["w"], np.float64) - 1e-2 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"], np.float64))
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clipping():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_determinism_and_resume():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=7)
    ds = SyntheticLMDataset(cfg)
    a = ds.batch_at(12)
    b = ds.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])

    it = DataIterator(ds)
    for _ in range(5):
        next(it)
    st = it.state()
    x = next(it)
    it2 = DataIterator(ds)
    it2.restore(st)
    y = next(it2)
    np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=1)
    b = SyntheticLMDataset(cfg).batch_at(0)
    # label[t] is the next token of tokens[t] — consistency of the stream
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_data_local_slice():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=1)
    ds = SyntheticLMDataset(cfg)
    b = ds.batch_at(3)
    parts = [ds.local_slice(b, i, 4) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate([p["tokens"] for p in parts]),
                                  b["tokens"])


def test_data_learnable_structure():
    """The synthetic stream must beat uniform entropy (it's learnable)."""
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=8, seed=3)
    b = SyntheticLMDataset(cfg).batch_at(0)
    # bigram predictability: most mass concentrated on few successors
    from collections import Counter

    cnt = Counter(zip(b["tokens"].ravel()[:-1], b["tokens"].ravel()[1:]))
    uni = Counter(b["tokens"].ravel())
    top = sum(c for _, c in cnt.most_common(64 * 4))
    assert top / sum(cnt.values()) > 0.5  # structured, not uniform


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 5, state, extra={"data_step": 5})
    assert ckpt.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    rest, extra = ckpt.restore(str(tmp_path), 5, like)
    np.testing.assert_array_equal(np.asarray(rest["a"]), np.asarray(state["a"]))
    assert extra["data_step"] == 5


def test_checkpoint_atomic_commit(tmp_path):
    """Partial (uncommitted) checkpoints are invisible to latest_step."""
    state = {"a": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, state)
    # simulate a crashed writer: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    state = {"w": jnp.full((128, 128), 3.0)}
    saver.save(7, state)
    saver.wait()
    rest, _ = ckpt.restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, state))
    assert float(rest["w"][0, 0]) == 3.0


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------
def test_compression_error_feedback_unbiased():
    """Sum of (compressed grads + final error) == sum of raw grads."""
    rng = np.random.default_rng(0)
    g_seq = [{"w": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}
             for _ in range(20)]
    err = comp.init_error_state(g_seq[0])
    total_c = np.zeros(64)
    total_raw = np.zeros(64)
    for g in g_seq:
        gc, err = comp.compress_grads(g, err)
        total_c += np.asarray(gc["w"])
        total_raw += np.asarray(g["w"])
    resid = np.abs(total_c + np.asarray(err["w"]) - total_raw).max()
    assert resid < 1e-3


def test_compression_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(1).normal(0, 2, (1000,)), jnp.float32)
    q, s = comp.quantize_int8(x)
    err = np.abs(np.asarray(comp.dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6


def test_psum_compressed_matches_mean():
    """shard_map int8 psum ~ uncompressed mean within quantization error."""
    from jax.sharding import Mesh, PartitionSpec as PS
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (8,)), jnp.float32)
    f = shard_map(lambda v: comp.psum_compressed(v, "d"), mesh=mesh,
                  in_specs=PS(), out_specs=PS())
    got = np.asarray(f(x))
    assert np.abs(got - np.asarray(x)).max() < float(jnp.max(jnp.abs(x))) / 127 + 1e-6


# ---------------------------------------------------------------------------
# Microbatch accumulation
# ---------------------------------------------------------------------------
def test_accumulation_matches_full_batch():
    cfg = configs.get_smoke("yi-9b", act_impl="exact")
    opt = adamw.AdamWConfig(lr=1e-3)
    state = step_lib.init_state(cfg, jax.random.PRNGKey(0), opt)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}

    s1 = jax.jit(step_lib.make_train_step(cfg, opt, accum=1))
    s2 = jax.jit(step_lib.make_train_step(cfg, opt, accum=2))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree.leaves(st1.params)
    l2 = jax.tree.leaves(st2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
