"""Observability-layer tests (repro.obs + the instrumented ServeEngine):

* metrics registry — counter/gauge semantics, log-bucketed histogram
  quantiles against numpy percentiles, snapshot/JSON export;
* Chrome-trace recorder — schema validity of exported traces;
* request lifecycle — per-request event ordering invariants
  (enqueue <= admit <= prefill <= first_token <= token* <= finish);
* saturation accounting — the eager-quantize observer fires on a
  deliberately overflowing Q2.14 input, never fires inside a jit trace,
  and the FORMAT_PROFILES audit reports per-format clip counts;
* the no-interference contract — an engine run with observability (and
  tracing) enabled emits bit-identical tokens and *identical compile
  counts* to an untraced run, and KVPager feeds pool gauges/counters.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import obs as obs_lib
from repro.core import fixed_point as fp
from repro.models import transformer as tf
from repro.obs.metrics import Histogram, MetricsRegistry, NULL_REGISTRY
from repro.obs.trace import TraceRecorder, validate_chrome_trace
from repro.serve import kv_pager as kvp
from repro.serve.engine import Request, ServeEngine


def _cfg():
    return configs.get_smoke("yi-9b", act_impl="exact")


def _requests(cfg, n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 9))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500
    return [list(r.out) for r in reqs]


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------
def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("c", unit="tok")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    for v in (3.0, 7.0, 2.0):
        g.set(v)
    assert g.last == 2.0 and g.peak == 7.0
    assert g.mean == pytest.approx(4.0)
    # get-or-create returns the same instance; type conflicts raise
    assert reg.counter("c") is c
    with pytest.raises(TypeError):
        reg.gauge("c")


@pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
def test_histogram_quantiles_match_numpy(dist):
    rng = np.random.default_rng(42)
    if dist == "uniform":
        xs = rng.uniform(0.5, 50.0, 5000)
    elif dist == "lognormal":
        xs = rng.lognormal(1.0, 1.5, 5000)
    else:
        # asymmetric split so no tested quantile sits exactly on the mode
        # boundary (where numpy interpolates *between* modes and no
        # histogram estimate can agree)
        xs = np.concatenate([rng.normal(2.0, 0.1, 2000),
                             rng.normal(200.0, 5.0, 3000)])
        xs = np.abs(xs)
    h = Histogram("h", growth=1.07)
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.sum == pytest.approx(xs.sum(), rel=1e-9)
    for q in (0.50, 0.90, 0.99):
        exact = np.percentile(xs, q * 100)
        got = h.quantile(q)
        # log-bucket growth 1.07 bounds the relative error by ~sqrt(1.07)
        # (plus discreteness at the very tail); 8% absorbs both
        assert got == pytest.approx(exact, rel=0.08), (q, got, exact)


def test_histogram_edge_cases():
    h = Histogram("h")
    assert np.isnan(h.quantile(0.5))
    h.observe(0.0)          # <= lo: bucket 0
    h.observe(-1.0)         # negative: clamped into bucket 0
    h.observe(5.0)
    assert h.count == 3
    assert h.quantile(0.0) == h.min == -1.0
    assert h.quantile(1.0) == h.max == 5.0
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_registry_snapshot_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.count", unit="tok").inc(3)
    reg.gauge("b.depth").set(2.0)
    h = reg.histogram("c.lat_ms", unit="ms")
    for v in (1.0, 2.0, 10.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["a.count"]["value"] == 3
    assert snap["b.depth"]["peak"] == 2.0
    assert snap["c.lat_ms"]["count"] == 3
    assert set(snap["c.lat_ms"]) >= {"p50", "p90", "p99", "min", "max"}
    path = tmp_path / "metrics.json"
    reg.to_json(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["metrics"] == json.loads(json.dumps(snap))


def test_null_registry_is_inert():
    c = NULL_REGISTRY.counter("x")
    c.inc(10)
    assert c.value == 0
    NULL_REGISTRY.gauge("y").set(5.0)
    NULL_REGISTRY.histogram("z").observe(1.0)
    assert NULL_REGISTRY.snapshot() == {}
    with pytest.raises(RuntimeError):
        NULL_REGISTRY.to_json("/dev/null")


# --------------------------------------------------------------------------
# chrome trace
# --------------------------------------------------------------------------
def test_trace_schema_valid(tmp_path):
    tr = TraceRecorder()
    tr.instant("enqueue", 10.0, track="req 0", args={"prompt_len": 4})
    tr.complete("prefill", 20.0, 15.0, track="req 0")
    tr.counter("engine.load", 30.0, {"queue_depth": 2})
    doc = tr.to_dict()
    validate_chrome_trace(doc)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    validate_chrome_trace(json.loads(path.read_text()))
    # every logical track got exactly one thread_name metadata record
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"
            and e["name"] == "thread_name"]
    assert {e["args"]["name"] for e in meta} == {"req 0", "engine"}


def test_trace_validator_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X",
                                               "ts": 0.0, "pid": 1}]})
    with pytest.raises(ValueError):        # X without dur
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 0.0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):        # unknown phase
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "Q", "ts": 0.0, "pid": 1, "tid": 0}]})


# --------------------------------------------------------------------------
# saturation accounting
# --------------------------------------------------------------------------
def test_saturation_counter_fires_on_overflowing_q2_14():
    reg = MetricsRegistry()
    with obs_lib.observe_saturation(reg):
        # 3.0 > Q2.14 max (~1.99994): every element must clip
        fp.quantize(jnp.full((8,), 3.0), fp.Q2_14)
        # in-range values must not count as clips
        fp.quantize(jnp.full((4,), 0.5), fp.Q2_14)
    clips = reg.get("fixed_point.saturation.clips{fmt=Q2.14}")
    total = reg.get("fixed_point.saturation.elements{fmt=Q2.14}")
    assert clips.value == 8
    assert total.value == 12
    # observer detached on scope exit
    fp.quantize(jnp.full((8,), 3.0), fp.Q2_14)
    assert clips.value == 8


def test_saturation_observer_never_traces():
    """Inside jit the quantizer sees tracers: the observer must not fire
    (no Python metric state inside a compiled function) and must not
    change what the function compiles to."""
    reg = MetricsRegistry()

    def f(x):
        return fp.dequantize(fp.quantize(x, fp.Q2_14), fp.Q2_14)

    jf = jax.jit(f)
    with obs_lib.observe_saturation(reg):
        out = jf(jnp.full((8,), 3.0))
    assert reg.get("fixed_point.saturation.clips{fmt=Q2.14}") is None
    np.testing.assert_allclose(np.asarray(out), fp.Q2_14.max_int / 2**14)


@pytest.mark.parametrize("fmt,label", [("int8", "Q8.0"), ("q2_14", "Q2.14")])
def test_kv_quant_saturation_counters(fmt, label):
    """The quantized paged-KV write path rides the same eager-quantize
    observer: per-block amax scales map every element inside the code
    range (clips stay ZERO on in-range traces), while a deliberately
    pinned too-small scale pushes the tail out of range and the clip
    counter for the format's Q label moves."""
    from repro.core import kv_quant as kvq

    spec = kvq.spec_for(fmt)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, 2, 8)).astype(np.float32))
    good = kvq.block_scale(x, spec)
    reg = MetricsRegistry()
    with obs_lib.observe_saturation(reg):
        kvq.quantize(x, spec, good)
    clips = reg.get(f"fixed_point.saturation.clips{{fmt={label}}}")
    total = reg.get(f"fixed_point.saturation.elements{{fmt={label}}}")
    assert clips is not None and clips.value == 0
    assert total.value == x.size
    with obs_lib.observe_saturation(reg):
        # an eighth of the proper scale leaves everything past amax/8
        # outside the representable range — the counter must see it
        kvq.quantize(x, spec, good / 8.0)
    assert clips.value > 0
    assert total.value == 2 * x.size


def test_engine_kv_quant_gauges():
    """A quantized engine registers the kv.quant.* gauges (code width,
    derated bytes/token) and the pager's kv.pool.bytes_in_use follows
    alloc/release at the quantized block size."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(6))
    ob = obs_lib.Observability()
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      kv_quant="int8", obs=ob)
    _serve(eng, _requests(cfg, 3, max_new=3))
    m = ob.metrics
    assert m.get("kv.quant.code_bits").last == 8.0
    bpt = m.get("kv.quant.bytes_per_token").last
    assert bpt == eng.pager.block_bytes / eng.block_len > 0
    assert m.get("kv.pool.bytes_in_use").peak > 0
    assert m.get("kv.pool.bytes_in_use").last == 0.0    # all freed
    # the unquantized engine reports the f32 width through the same gauge
    ob32 = obs_lib.Observability()
    eng32 = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                        obs=ob32)
    assert ob32.metrics.get("kv.quant.code_bits").last == 32.0
    assert ob32.metrics.get("kv.quant.bytes_per_token").last > bpt


def test_saturation_audit_per_profile():
    audit = obs_lib.saturation_audit(
        {"inrange": np.linspace(-1.5, 1.5, 64),
         "logits": np.linspace(-20.0, 0.0, 64)})
    for prof in ("q2_14", "q2_20", "q2_29"):
        assert audit[prof]["inrange"]["clipped"] == 0
        assert audit[prof]["logits"]["clipped"] > 0
        assert audit[prof]["logits"]["total"] == 64
        assert 0 < audit[prof]["logits"]["frac"] <= 1


# --------------------------------------------------------------------------
# engine lifecycle + no-interference contract
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kv_impl", ["dense", "paged"])
def test_engine_obs_no_interference(kv_impl):
    """The acceptance gate: identical tokens AND identical compile counts
    with observability (metrics + tracing) on vs off, plus a Perfetto-
    loadable trace out of the observed run."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))

    eng_off = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl=kv_impl)
    toks_off = _serve(eng_off, _requests(cfg, 5))

    ob = obs_lib.Observability(trace=True)
    eng_on = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl=kv_impl,
                         obs=ob)
    toks_on = _serve(eng_on, _requests(cfg, 5))

    assert toks_on == toks_off
    assert eng_on.compile_counts() == eng_off.compile_counts()
    validate_chrome_trace(ob.trace.to_dict())


def test_engine_lifecycle_event_ordering():
    """Per request: enqueue <= admit <= first_token <= token steps
    (monotone ts) <= finish, with per-token steps increasing by 1."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    ob = obs_lib.Observability(trace=True)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      obs=ob)
    reqs = _requests(cfg, 5, max_new=5)
    _serve(eng, reqs)

    for r in reqs:
        evs = ob.trace.track_events(f"req {r.rid}")
        names = [e["name"] for e in evs]
        # prefill is a span starting at admit time; order the rest
        assert names[0] == "enqueue"
        assert names[1] == "admit"
        assert "first_token" in names
        assert names[-1] == "finish"
        ts = [e["ts"] for e in evs if e["ph"] == "i"]
        assert ts == sorted(ts), f"req {r.rid} events out of order"
        tok_steps = [e["args"]["step"] for e in evs if e["name"] == "token"]
        assert tok_steps == list(range(2, len(r.out) + 1))
        # timestamps mirrored onto the Request itself
        assert 0 <= r.t_enqueue <= r.t_admit <= r.t_first <= r.t_finish

    # engine-phase spans exist for every phase of every step
    phase_names = {e["name"] for e in ob.trace.track_events("engine")
                   if e["ph"] == "X"}
    assert {"admit", "dispatch", "host_sync",
            "sample_copy"} <= phase_names


def test_engine_metrics_populated():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    ob = obs_lib.Observability()
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      obs=ob)
    reqs = _requests(cfg, 4, max_new=4)
    _serve(eng, reqs)

    m = ob.metrics
    assert m.get("engine.requests.submitted").value == 4
    assert m.get("engine.requests.finished").value == 4
    assert m.get("engine.tokens.emitted").value == sum(
        len(r.out) for r in reqs)
    assert m.get("engine.ttft_ms").count == 4
    assert m.get("engine.tpot_ms").count == 4     # max_new 4 > 1 token
    assert m.get("engine.e2e_ms").count == 4
    assert m.get("engine.batch_occupancy").peak == 2.0
    # cold engine: exactly the bucketed-prefill + decode compiles, seen
    # from the host via compile_counts() deltas
    assert m.get("engine.compiles.prefill").value >= 1
    assert m.get("engine.compiles.decode").value >= 1
    assert (m.get("engine.compiles.prefill").value
            + m.get("engine.compiles.decode").value
            == sum(eng.compile_counts().values()))
    # pool telemetry flowed through the same registry
    assert m.get("kv.pool.allocs").value == 4
    assert m.get("kv.pool.blocks_freed").value > 0
    assert m.get("kv.pool.blocks_in_use").peak > 0
    assert m.get("kv.pool.blocks_in_use").last == 0.0   # all freed
    # every phase histogram saw every decode step
    steps = m.get("engine.step_ms").count
    for ph in ("admit", "dispatch", "host_sync", "sample_copy"):
        assert m.get(f"engine.phase.{ph}_ms").count >= steps


def test_pager_backpressure_metric():
    ob = obs_lib.Observability()
    pager = kvp.KVPager(4, 16, 2, metrics=ob.metrics)
    assert pager.alloc(0, 3) is not None
    assert pager.alloc(1, 2) is None         # only 0 free: backpressure
    assert ob.metrics.get("kv.pool.alloc_failures").value == 1
    pager.free(0)
    assert ob.metrics.get("kv.pool.blocks_freed").value == 3
    assert ob.metrics.get("kv.pool.blocks_in_use").last == 0.0
    assert ob.metrics.get("kv.pool.blocks_in_use").peak == 3.0


def test_requests_submitted_before_attach_obs_keep_latency_stats():
    """The t_enqueue regression: submit() only stamped the enqueue time
    when observability was already attached, so requests queued before a
    post-warm-up attach_obs silently vanished from the TTFT and e2e
    histograms. Stamps are now unconditional: requests submitted *before*
    attach_obs still land in both histograms after it."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged")
    reqs = _requests(cfg, 3, max_new=3)
    for r in reqs:
        eng.submit(r)                    # queued with NO obs attached
    ob = obs_lib.Observability(trace=True)
    eng.attach_obs(ob)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500
    assert all(r.done for r in reqs)
    m = ob.metrics
    assert m.get("engine.ttft_ms").count == 3
    assert m.get("engine.e2e_ms").count == 3
    for r in reqs:
        assert 0 < r.t_enqueue <= r.t_admit <= r.t_first <= r.t_finish


def test_attach_obs_after_warmup():
    """attach_obs swaps the handle mid-lifetime: the new registry sees
    only post-attach traffic and no compile events for warm shapes."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(4))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged")
    _serve(eng, _requests(cfg, 2, max_new=2))          # warm, unobserved
    ob = obs_lib.Observability()
    eng.attach_obs(ob)
    reqs = _requests(cfg, 2, max_new=4, seed=1)
    _serve(eng, reqs)
    m = ob.metrics
    assert m.get("engine.requests.submitted").value == 2
    assert m.get("engine.compiles.prefill").value == 0
    assert m.get("engine.compiles.decode").value == 0
