"""Quantized paged-KV conformance (cfg.kv_quant, core/kv_quant.py): the
block-scaled int8 / q2_14 pool formats across every layer that touches
them — the quantize-at-write helpers, the gather dequant, the Pallas
kernel's in-VMEM CORDIC dequant against the gather oracle, the serving
engine's token streams, the pool-bytes accounting the bench section
gates, and the fail-fast validation surface.

The dequantize is the CORDIC linear-rotation multiply applied
elementwise (codes * scale), so the kernel and gather paths must agree
bit-for-bit on the dequantized operands; only the attend's f32
reduction order differs, bounded by the same ATOL as the unquantized
kernel suite.

CI runs this file once per datapath backend via REPRO_TEST_BACKEND in
{"jnp", "pallas_interpret"} (rides the paged-attention kernel
conformance step), so a dequant drift in one backend's decode path is
attributed to the backend that drifted.  Unset (tier-1), the exact
softmax runs.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import kv_quant as kvq
from repro.kernels import paged_attention as PA
from repro.kernels import ref as kref
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")
assert _BACKEND in _SOFTMAX_BY_BACKEND, \
    f"REPRO_TEST_BACKEND={_BACKEND!r} not in {sorted(filter(None, _SOFTMAX_BY_BACKEND))}"
SOFTMAX_IMPL = _SOFTMAX_BY_BACKEND[_BACKEND]

#: same f32 contraction-order tolerance as test_paged_attention.py: the
#: dequantized operands are bit-identical between kernel and oracle,
#: only the online-softmax reduction order differs.
ATOL = 2e-5

FORMATS = ("int8", "q2_14")


def _cfg(arch: str = "yi-9b"):
    return dataclasses.replace(configs.get_smoke(arch, act_impl="exact"),
                               softmax_impl=SOFTMAX_IMPL)


# ---------------------------------------------------------------------------
# core/kv_quant.py: quantize/dequantize roundtrip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FORMATS)
def test_roundtrip_error_within_half_step(fmt):
    """The exact product codes * scale lands within half a quantization
    step (scale * format resolution / 2) of x for every element — the
    per-block amax scale maps the block exactly onto the code range —
    and the production dequantize (the CORDIC linear-rotation multiply)
    tracks that exact product to the multiply's own Q-format precision."""
    from repro.core import fixed_point as fp

    spec = kvq.spec_for(fmt)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(6, 16, 2, 8)).astype(np.float32))
    scale = kvq.block_scale(x, spec)
    assert scale.shape == (6, 1, 2, 1)
    codes = kvq.quantize(x, spec, scale)
    assert codes.dtype == spec.code_dtype
    exact = fp.dequantize(codes, spec.fmt) * jnp.broadcast_to(scale, x.shape)
    err = float(jnp.max(jnp.abs(exact - x)))
    bound = float(jnp.max(scale)) * spec.fmt.resolution * 0.5 * (1 + 1e-5)
    assert err <= bound, (err, bound)
    # the CORDIC multiply approximates the exact product with relative
    # error at the linear-rotation datapath's Q2.14 resolution
    deq = kvq.dequantize(codes, spec, scale)
    rel = float(jnp.max(jnp.abs(deq - exact))) / max(1e-9,
                                                     float(jnp.max(jnp.abs(x))))
    assert rel <= 2.0 ** -13, rel


def test_spec_for_rejects_unknown_format():
    with pytest.raises(ValueError, match="int8"):
        kvq.spec_for("int4")
    assert kvq.spec_for("none") is None
    assert kvq.spec_for(None) is None


# ---------------------------------------------------------------------------
# Kernel vs gather oracle under quantized pools
# ---------------------------------------------------------------------------
def _quant_case(klen_list, fmt, *, L=4, KH=2, G=2, hd=8, seed=0):
    """Quantized pools/tables/lens for a batch of live lengths: float
    pools are block-scaled and coded exactly as the prefill write path
    does it, so kernel and oracle see production-shaped operands."""
    spec = kvq.spec_for(fmt)
    rng = np.random.default_rng(seed)
    B = len(klen_list)
    M = max(-(-k // L) for k in klen_list if k) if any(klen_list) else 1
    N = 1 + B * M
    q = jnp.asarray(rng.normal(size=(B, KH, G, hd)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(N, L, KH, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(N, L, KH, hd)), jnp.float32)
    ks = kvq.block_scale(kf, spec)
    vs = kvq.block_scale(vf, spec)
    kp = kvq.quantize(kf, spec, ks)
    vp = kvq.quantize(vf, spec, vs)
    tables = np.zeros((B, M), np.int32)
    nxt = 1
    for b, klen in enumerate(klen_list):
        for c in range(-(-klen // L)):
            tables[b, c] = nxt
            nxt += 1
    k_len = jnp.asarray([max(k, 1) for k in klen_list], jnp.int32)
    return q, kp, vp, ks, vs, jnp.asarray(tables), k_len


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("klens", [[1], [4], [5], [16],
                                   [1, 4, 5, 13, 16, 3]])
def test_gqa_kernel_quant_matches_ref(fmt, klens):
    """The kernel's per-chunk in-VMEM dequant against the gather oracle
    (kernels/ref.py dequantizes via the same production helper): f32
    round-off agreement and per-row argmax identity, over the same edge
    geometry the unquantized suite walks (on/off block boundaries,
    single block, mixed batch)."""
    q, kp, vp, ks, vs, tables, k_len = _quant_case(klens, fmt,
                                                   seed=len(klens))
    got = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                                   softmax_impl=SOFTMAX_IMPL, kv_quant=fmt,
                                   k_scale_pool=ks, v_scale_pool=vs,
                                   interpret=True))
    want = np.asarray(kref.paged_attend_gqa_ref(q, kp, vp, tables, k_len,
                                                scale=0.3,
                                                softmax_impl=SOFTMAX_IMPL,
                                                kv_quant=fmt,
                                                k_scale_pool=ks,
                                                v_scale_pool=vs))
    assert np.abs(got - want).max() < ATOL, np.abs(got - want).max()
    np.testing.assert_array_equal(got.reshape(got.shape[0], -1).argmax(-1),
                                  want.reshape(want.shape[0], -1).argmax(-1))
    assert np.isfinite(got).all()


def test_gqa_kernel_quant_vacant_slot():
    """A vacant row (all-zero table -> scratch block 0) rides along under
    quantization like an inactive engine slot: finite output, live rows
    unaffected."""
    q, kp, vp, ks, vs, tables, k_len = _quant_case([5, 0, 9], "int8",
                                                   seed=4)
    assert int(tables[1].max()) == 0
    out = np.asarray(PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                                   softmax_impl=SOFTMAX_IMPL, kv_quant="int8",
                                   k_scale_pool=ks, v_scale_pool=vs,
                                   interpret=True))
    assert np.isfinite(out).all()


def test_gqa_decode_quant_requires_scale_pools():
    """kv_quant and the scale pools come together — the kernel must fail
    fast on a half-wired call instead of attending garbage."""
    q, kp, vp, ks, vs, tables, k_len = _quant_case([5], "int8")
    with pytest.raises(ValueError, match="scale"):
        PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                      softmax_impl=SOFTMAX_IMPL, kv_quant="int8",
                      interpret=True)
    with pytest.raises(ValueError, match="scale"):
        PA.gqa_decode(q, kp, vp, tables, k_len, scale=0.3,
                      softmax_impl=SOFTMAX_IMPL,
                      k_scale_pool=ks, v_scale_pool=vs, interpret=True)


# ---------------------------------------------------------------------------
# kv_dtype validation (the seam kv_quant turned into a real stage)
# ---------------------------------------------------------------------------
def test_canonical_kv_dtype_validates():
    assert PA.canonical_kv_dtype(None) is None
    assert PA.canonical_kv_dtype(jnp.bfloat16) == jnp.dtype(jnp.bfloat16)
    assert PA.canonical_kv_dtype("float32") == jnp.dtype(jnp.float32)
    with pytest.raises(ValueError, match="kv_dtype"):
        PA.canonical_kv_dtype("bogus")
    with pytest.raises(ValueError, match="kv_quant"):
        PA.canonical_kv_dtype(jnp.int8)  # integer storage is kv_quant's job


# ---------------------------------------------------------------------------
# Serving-level token identity + pool accounting (the acceptance bar)
# ---------------------------------------------------------------------------
def _mk_reqs(cfg, n, *, max_new=5, seed=7, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + 2 * i),
                    max_new_tokens=max_new, sampling=sampling)
            for i in range(n)]


def _serve(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, slots=4, max_len=64, seed=0, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs], eng


@pytest.mark.parametrize("fmt", FORMATS)
def test_engine_quant_gather_pallas_tokens_identical(fmt):
    """Per format, the pallas attend (in-kernel dequant) must emit token
    streams bit-identical to the gather attend (pool-side dequant): both
    feed the attend the same dequantized values, so the storage format
    cannot open a kernel-vs-gather gap."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    gather, _ = _serve(cfg, params, _mk_reqs(cfg, 6), kv_impl="paged",
                       kv_quant=fmt, paged_attend_impl="gather")
    pallas, _ = _serve(cfg, params, _mk_reqs(cfg, 6), kv_impl="paged",
                       kv_quant=fmt, paged_attend_impl="pallas")
    assert pallas == gather


def test_engine_kv_quant_none_identical_to_default():
    """kv_quant='none' is the identity configuration: bit-identical
    tokens to an engine that never heard of the knob."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    default, _ = _serve(cfg, params, _mk_reqs(cfg, 4), kv_impl="paged")
    none, _ = _serve(cfg, params, _mk_reqs(cfg, 4), kv_impl="paged",
                     kv_quant="none")
    assert none == default


def test_engine_quant_with_prefix_cache_identical():
    """Prefix-cache sharing keys on token ids, not pool contents, so
    cache-on must stay bit-identical to cache-off under quantization —
    shared blocks carry codes + scales like any other block."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)  # 2 blocks
    tails = [rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
             for _ in range(3)]

    def serve(prefix: bool):
        eng = ServeEngine(cfg, params, slots=4, max_len=64, seed=0,
                          kv_impl="paged", kv_quant="int8",
                          prefix_cache=prefix)
        prime = Request(rid=0, prompt=shared.copy(), max_new_tokens=4)
        eng.submit(prime)
        eng.run()   # prefix blocks cached before the sharing wave admits
        rest = [Request(rid=1 + i, prompt=np.concatenate([shared, t]),
                        max_new_tokens=4) for i, t in enumerate(tails)]
        for r in rest:
            eng.submit(r)
        eng.run()
        return [r.out for r in [prime] + rest], eng

    off, _ = serve(False)
    on, eng = serve(True)
    assert on == off
    assert eng.prefix.hits > 0   # the cache actually engaged


@pytest.mark.parametrize("fmt,min_ratio", [("int8", 2.0), ("q2_14", 1.9)])
def test_pool_bytes_collapse(fmt, min_ratio):
    """Resident pool bytes (codes + scale pools) at MATCHED block count
    must collapse by the format's floor vs the unquantized f32 pool —
    the memory claim the bench section gates, checked here per backend."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, base = _serve(cfg, params, _mk_reqs(cfg, 2, max_new=2),
                     kv_impl="paged")
    _, quant = _serve(cfg, params, _mk_reqs(cfg, 2, max_new=2),
                      kv_impl="paged", kv_quant=fmt)
    assert quant.pager.stats().num_blocks == base.pager.stats().num_blocks
    ratio = base.kv_pool_bytes() / quant.kv_pool_bytes()
    assert ratio >= min_ratio, ratio
    # bytes/token follows the pool: block_bytes is derated the same way
    assert quant.pager.block_bytes < base.pager.block_bytes


# ---------------------------------------------------------------------------
# Fail-fast validation surface
# ---------------------------------------------------------------------------
def test_engine_rejects_unknown_format():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="int8"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="paged",
                    kv_quant="int4")


def test_engine_rejects_dense_plane():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="dense",
                    kv_quant="int8")


def test_engine_rejects_mla():
    """MLA layers page the compressed latent, which has no kv-heads axis
    to scale over — the engine must refuse at construction."""
    cfg = _cfg("deepseek-v2-lite-16b")
    params = tf.init(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="MLA"):
        ServeEngine(cfg, params, slots=1, max_len=32, kv_impl="paged",
                    kv_quant="int8")


# ---------------------------------------------------------------------------
# Transient accounting stays quant-aware
# ---------------------------------------------------------------------------
def test_transient_quant_pallas_invariant_and_below_gather():
    """The kernel's O(block_len) transient contract survives
    quantization: code-width streaming plus the per-chunk f32 dequant
    buffers stay max_len-invariant, while the quantized gather still
    materializes (and dequantizes) the full table."""
    cfg = _cfg()
    tr = lambda impl, ml: PA.decode_transient_bytes(            # noqa: E731
        cfg, max_len=ml, block_len=16, impl=impl, kv_quant="int8")
    assert tr("pallas", 64) == tr("pallas", 1 << 20)
    assert tr("gather", 128) > tr("gather", 64)
    assert tr("pallas", 1 << 20) < tr("gather", 1 << 20)
    # MLA has no quantized plane: the accounting refuses rather than
    # inventing a number for a configuration the engine rejects
    mla = _cfg("deepseek-v2-lite-16b")
    with pytest.raises(ValueError, match="GQA"):
        PA.decode_transient_bytes(mla, max_len=64, block_len=16,
                                  impl="gather", kv_quant="int8")
