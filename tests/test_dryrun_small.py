"""Dry-run machinery tests.

The full 512-device dry-run needs a fresh process (XLA device count locks at
first jax init), so the production meshes are exercised via subprocess for
one representative cell; the sharding-rule logic is tested in-process.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# sharding rules (in-process, mesh over 1 device is fine for spec logic)
# ---------------------------------------------------------------------------
def _mesh_16x16_abstract():
    """AbstractMesh carries only names/shapes — perfect for spec logic.

    The constructor signature changed across jax releases: <=0.4.35 took
    (sizes, names); 0.4.36+ takes a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh((("data", 16), ("model", 16)))
    except (TypeError, ValueError):
        return AbstractMesh((16, 16), ("data", "model"))


def test_spec_divisibility_fallback():
    mesh = _mesh_16x16_abstract()
    # kv_heads = 4 on a 16-way model axis must fall back to replicated
    spec = shd.spec_for_axes(("embed", "kv_heads", None), (4096, 4, 128), mesh)
    assert spec == PS(None, None) or spec == PS()
    # divisible dims shard
    spec = shd.spec_for_axes(("embed", "heads", None), (4096, 32, 128), mesh)
    assert spec == PS(None, "model")


def test_spec_one_axis_use():
    mesh = _mesh_16x16_abstract()
    # experts and mlp both want "model": only the first gets it
    spec = shd.spec_for_axes(("experts", "embed", "mlp"), (64, 2048, 1408), mesh)
    assert spec == PS("model",)


def test_batch_spec_modes():
    mesh = _mesh_16x16_abstract()
    assert shd.batch_spec(mesh, 256, 4096) == PS(("data",), None)
    # batch=1 long-context: sequence sharding
    assert shd.batch_spec(mesh, 1, 524288) == PS(None, ("data",))
    # batch=1, seq=1: fully replicated
    assert shd.batch_spec(mesh, 1, 1) == PS()


def test_shape_applicability_rules():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == cfg.sub_quadratic
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[s])[0]
    assert configs.get_config("xlstm-1.3b").sub_quadratic
    assert configs.get_config("zamba2-1.2b").sub_quadratic
    assert sum(configs.get_config(a).sub_quadratic for a in configs.ARCH_IDS) == 2


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
def test_collective_bytes_parser():
    text = """
  %ag = bf16[4,1024,128]{2,1,0} all-gather(bf16[4,64,128]{2,1,0} %p), dims={1}
  %ar.1 = f32[2048]{0} all-reduce(f32[2048]{0} %x), to_apply=%sum
  %a2a = f32[16,32]{1,0} all-to-all(f32[16,32]{1,0} %y), dimensions={0}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %z)
  %ar-start = f32[8]{0} all-reduce-start(f32[8]{0} %w), to_apply=%sum
  %ar-done = f32[8]{0} all-reduce-done(f32[8]{0} %ar-start)
"""
    got = hlo.collective_bytes(text)
    assert got["op_counts"]["all-gather"] == 1
    assert got["op_counts"]["all-reduce"] == 2   # sync + async start
    ag = 4 * 1024 * 128 * 2
    ar = 2048 * 4 + 8 * 4
    a2a = 16 * 32 * 4
    cp = 100
    assert got["per_kind_bytes"]["all-gather"] == ag
    assert got["per_kind_bytes"]["all-reduce"] == ar
    assert got["weighted_bytes"] == pytest.approx(2 * ar + ag + a2a + cp)


def test_roofline_terms():
    t = hlo.roofline_terms(197e12, 819e9, 50e9)  # 1s each by construction
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t2 = hlo.roofline_terms(1e12, 900e9, 1e9)
    assert t2["dominant"] == "memory_s"


# ---------------------------------------------------------------------------
# one real dry-run cell through the actual 512-device path (subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    out = tmp_path / "cell.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "internvl2-1b",
         "--shape", "decode_32k", "--multi-pod", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(out))[0]
    assert rec["status"] == "ok"
    assert rec["devices"] == 512
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
