"""Prefix-cache tests (serve/prefix_cache.py + the refcounted KVPager +
the resumed-prefill path in serve/engine.py):

* refcount lifecycle — alloc mints at refcount 1, retain/release adjust,
  free-at-zero only, scratch block refcount-pinned (never retained,
  released, or handed out), shared pins transfer through
  ``alloc(..., shared=)``;
* radix index unit behavior — insert/match at block granularity, the
  (plen-1)//block_len match cap, mid-edge partial matches, edge splits
  on divergence, duplicate inserts keeping the incumbent block ids;
* eviction — LRU vs FIFO victim order over refcount-one leaves, blocks
  still bound by a live slot never evicted, evict_until backpressure
  fallback when nothing is evictable;
* COW regression — the bytes of a shared pool block never change while a
  sibling request prefills/decodes through the shared prefix (resumed
  prefill writes only at positions >= its block-aligned start, decode
  only past the pinned length — both land in the sibling's own blocks);
* bit-identity — the acceptance bar: cache-on serving emits token
  streams bit-identical to cache-off, greedy AND seeded sampling, GQA
  and MLA attention, gather and pallas paged decode, chunked and
  unchunked; dense engines reject prefix_cache at init.

MoE carve-out (as in tests/test_scheduler.py): the MLA identity runs use
MLA attention with the dense FFN (block_pattern mla_dense) — capacity-
factor MoE routing depends on the dispatch width, so it is not invariant
to how a prompt is split, prefix-resume included.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serve import kv_pager as kvp
from repro.serve.engine import Request, ServeEngine
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampling import SamplingParams

_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")
assert _BACKEND in _SOFTMAX_BY_BACKEND, \
    f"REPRO_TEST_BACKEND={_BACKEND!r} not in " \
    f"{sorted(filter(None, _SOFTMAX_BY_BACKEND))}"


# ---------------------------------------------------------------------------
# Refcount lifecycle (pure host logic, no jax)
# ---------------------------------------------------------------------------
def test_alloc_mints_refcount_one_and_free_at_zero():
    p = kvp.KVPager(num_blocks=6, block_len=4, slots=2)
    blocks = p.alloc(0, 3)
    assert all(p.refcount(b) == 1 for b in blocks)
    p.retain(blocks[:1])
    assert p.refcount(blocks[0]) == 2
    # slot free drops one ref: the retained block stays resident
    assert p.free(0) == 2
    assert p.refcount(blocks[0]) == 1
    assert p.blocks_in_use == 1
    assert p.release(blocks[:1]) == 1
    assert p.blocks_in_use == 0
    assert p.blocks_free == 5


def test_scratch_block_refcount_pinned():
    p = kvp.KVPager(num_blocks=4, block_len=4, slots=1)
    assert p.refcount(kvp.SCRATCH_BLOCK) == 1
    with pytest.raises(RuntimeError, match="scratch"):
        p.retain([kvp.SCRATCH_BLOCK])
    with pytest.raises(RuntimeError, match="scratch"):
        p.release([kvp.SCRATCH_BLOCK])
    # exhaust the pool: scratch is still never handed out
    got = p.alloc(0, 3)
    assert kvp.SCRATCH_BLOCK not in got
    assert kvp.SCRATCH_BLOCK not in p._free


def test_retain_release_nonresident_raises():
    p = kvp.KVPager(num_blocks=4, block_len=4, slots=1)
    with pytest.raises(RuntimeError, match="non-resident"):
        p.retain([2])
    with pytest.raises(RuntimeError, match="non-resident"):
        p.release([2])


def test_alloc_shared_transfers_pins():
    """alloc(shared=...) budgets only the fresh blocks and adopts the
    caller's pins on the shared prefix — free(slot) then drops exactly
    one reference per block."""
    p = kvp.KVPager(num_blocks=8, block_len=4, slots=2)
    a = p.alloc(0, 3)
    p.retain(a[:2])                          # the "cache's" pins
    fresh = p.alloc(1, 2, shared=a[:2])      # pins transfer to slot 1
    assert len(fresh) == 2 and set(fresh).isdisjoint(a)
    assert p.owned(1) == tuple(a[:2] + fresh)
    assert p.refcount(a[0]) == 2             # slot 0 + slot 1
    assert p.blocks_shared == 2
    p.free(0)
    assert p.refcount(a[0]) == 1             # slot 1 keeps the prefix alive
    assert p.refcount(a[2]) == 0             # unshared block freed
    assert p.free(1) == 4
    assert p.blocks_in_use == 0


def test_all_or_nothing_preserved_with_shared_prefix():
    """Backpressure still counts only the unshared footprint: a request
    whose fresh-block need exceeds the free list holds nothing, and the
    shared pins stay with the caller to unwind."""
    p = kvp.KVPager(num_blocks=6, block_len=4, slots=2)
    a = p.alloc(0, 4)
    p.retain(a[:2])
    assert p.alloc(1, 2, shared=a[:2]) is None     # only 1 free block
    assert p.owned(1) == ()
    assert p.stats().alloc_failures == 1
    assert p.refcount(a[0]) == 2                   # pin untouched


# ---------------------------------------------------------------------------
# Radix index: insert / match / split at block granularity
# ---------------------------------------------------------------------------
def _pager_and_cache(num_blocks=32, block_len=4, policy="lru"):
    p = kvp.KVPager(num_blocks=num_blocks, block_len=block_len, slots=8)
    return p, PrefixCache(p, block_len, policy=policy)


def _toks(*vals):
    return np.asarray(vals, np.int32)


def test_match_on_empty_cache_is_miss():
    _, c = _pager_and_cache()
    assert c.match(_toks(*range(12))) == []
    assert c.hits == 0


def test_insert_then_match_at_block_granularity():
    p, c = _pager_and_cache(block_len=4)
    toks = _toks(*range(11))                 # 2 full blocks + partial
    blocks = p.alloc(0, 3)
    assert c.insert(toks, blocks) == 2       # only full prompt blocks
    assert p.refcount(blocks[0]) == 2        # slot + cache
    assert p.refcount(blocks[2]) == 1        # partial block never indexed
    # same prompt: both full blocks match (cap (11-1)//4 = 2)
    got = c.match(toks)
    assert got == blocks[:2]
    assert p.refcount(blocks[0]) == 3        # match pinned it for the caller
    p.release(got)


def test_match_cap_leaves_one_token_to_prefill():
    """A prompt fully covered by indexed blocks still matches at most
    (plen-1)//B blocks, so the logits that emit the first token exist."""
    p, c = _pager_and_cache(block_len=4)
    toks = _toks(*range(8))                  # exactly 2 blocks
    c.insert(toks, p.alloc(0, 2))
    got = c.match(toks)
    assert len(got) == 1                     # (8-1)//4 = 1, never 2
    p.release(got)


def test_match_stops_at_divergence_and_partial_edge():
    p, c = _pager_and_cache(block_len=2)
    blocks = p.alloc(0, 3)
    c.insert(_toks(1, 2, 3, 4, 5, 6), blocks)
    # diverges in the second block: only block 0 matches
    got = c.match(_toks(1, 2, 9, 9, 5, 6, 7))
    assert got == blocks[:1]
    p.release(got)


def test_insert_splits_edge_on_divergence():
    """Two prompts sharing one block then diverging split the edge: the
    shared block stays indexed once, both suffixes are reachable."""
    p, c = _pager_and_cache(block_len=2)
    a = p.alloc(0, 3)
    c.insert(_toks(1, 2, 3, 4, 5, 6), a)
    b = p.alloc(1, 3)
    assert c.insert(_toks(1, 2, 7, 8, 9, 10), b) == 2   # suffix only
    assert p.refcount(a[0]) == 2            # slot 0 + cache, nothing else
    assert p.refcount(b[0]) == 1            # duplicate of a[0]: not indexed
    ga = c.match(_toks(1, 2, 3, 4, 5, 6, 99))
    gb = c.match(_toks(1, 2, 7, 8, 9, 10, 99))
    assert ga == a and gb == [a[0]] + b[1:]
    p.release(ga)
    p.release(gb)


def test_insert_duplicate_keeps_incumbent_blocks():
    p, c = _pager_and_cache(block_len=4)
    toks = _toks(*range(9))
    a = p.alloc(0, 2)
    assert c.insert(toks, a) == 2
    b = p.alloc(1, 2)
    assert c.insert(toks, b) == 0           # incumbent wins, no new pins
    assert p.refcount(b[0]) == 1
    got = c.match(toks)
    assert got == a[:2]
    p.release(got)


# ---------------------------------------------------------------------------
# Eviction
# ---------------------------------------------------------------------------
def test_evict_lru_order_and_live_blocks_survive():
    p, c = _pager_and_cache(num_blocks=9, block_len=2)
    a = p.alloc(0, 2)
    c.insert(_toks(1, 2, 3, 4), a)
    b = p.alloc(1, 2)
    c.insert(_toks(9, 8, 7, 6), b)
    p.free(0)                               # a now cache-only (refcount 1)
    p.free(1)                               # b too
    got = c.match(_toks(9, 8, 7, 6, 0))     # touch b: a becomes LRU victim
    p.release(got)
    # pool: 8 allocatable, 4 resident (cache), 4 free; want 6 fresh
    assert c.evict_until(6)
    assert p.blocks_free >= 6
    assert p.refcount(a[0]) == 0            # LRU leaf evicted first
    assert p.refcount(b[0]) == 1            # recently-matched edge kept


def test_evict_fifo_order():
    p, c = _pager_and_cache(num_blocks=9, block_len=2, policy="fifo")
    a = p.alloc(0, 2)
    c.insert(_toks(1, 2, 3, 4), a)
    b = p.alloc(1, 2)
    c.insert(_toks(9, 8, 7, 6), b)
    p.free(0)
    p.free(1)
    got = c.match(_toks(1, 2, 3, 4, 0))     # touching a does NOT save it
    p.release(got)
    assert c.evict_until(6)
    assert p.refcount(a[0]) == 0            # oldest-inserted evicted first
    assert p.refcount(b[0]) == 1


def test_evict_never_touches_slot_bound_blocks():
    """Blocks a live slot still references (refcount >= 2) are not
    evictable; evict_until reports failure instead of reclaiming them."""
    p, c = _pager_and_cache(num_blocks=5, block_len=2)
    a = p.alloc(0, 2)
    c.insert(_toks(1, 2, 3, 4), a)          # slot 0 alive: refcounts 2
    assert not c.evict_until(4)             # nothing evictable
    assert p.refcount(a[0]) == 2
    p.free(0)                               # cache-only now
    assert c.evict_until(4)
    assert p.blocks_free == 4


def test_evicted_prefix_no_longer_matches():
    p, c = _pager_and_cache(num_blocks=5, block_len=2)
    a = p.alloc(0, 2)
    c.insert(_toks(1, 2, 3, 4), a)
    p.free(0)
    assert c.evict_until(4)
    assert c.match(_toks(1, 2, 3, 4, 5)) == []


# ---------------------------------------------------------------------------
# Engine integration: COW + bit-identity
# ---------------------------------------------------------------------------
def _cfg(arch="yi-9b"):
    cfg = dataclasses.replace(configs.get_smoke(arch, act_impl="exact"),
                              softmax_impl=_SOFTMAX_BY_BACKEND[_BACKEND])
    if arch == "deepseek-v2-lite-16b":
        cfg = dataclasses.replace(
            cfg, block_pattern=("mla_dense",) * cfg.num_layers)
    return cfg


def _mk_reqs(cfg, *, seed=7, shared_len=24):
    """Mixed requests over two shared system prompts + unique tails,
    mixed greedy/sampling so both decode variants and the per-request
    key streams run through the resumed-prefill path."""
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, cfg.vocab_size, shared_len)
                   for _ in range(2)]
    kinds = [SamplingParams(greedy=True), SamplingParams(temperature=2.5),
             SamplingParams(temperature=1.5, top_k=8), None]
    reqs = []
    for i, tail_len in enumerate([5, 11, 2, 8, 15, 4]):
        tail = rng.integers(0, cfg.vocab_size, tail_len)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([sys_prompts[i % 2], tail]),
            max_new_tokens=5, sampling=kinds[i % len(kinds)]))
    return reqs


def _serve(cfg, params, reqs, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("kv_impl", "paged")
    eng = ServeEngine(cfg, params, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.out for r in reqs]


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("chunk", [None, 16])
def test_prefix_cache_bit_identical(arch, chunk):
    """The acceptance bar: cache-on serving emits streams bit-identical
    to cache-off — GQA and MLA, chunked and unchunked, mixed sampling —
    while actually hitting (requests admitted after the first wave share
    the warm system-prompt blocks)."""
    cfg = _cfg(arch)
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, base = _serve(cfg, params, _mk_reqs(cfg), prefill_chunk=chunk)
    eng, got = _serve(cfg, params, _mk_reqs(cfg), prefill_chunk=chunk,
                      prefix_cache=True)
    assert got == base
    assert eng.prefix.hits >= 1             # sharing actually happened
    assert eng.prefix.hit_blocks >= 1


def test_prefix_cache_bit_identical_pallas():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, base = _serve(cfg, params, _mk_reqs(cfg))
    eng, got = _serve(cfg, params, _mk_reqs(cfg), prefix_cache=True,
                      paged_attend_impl="pallas")
    assert got == base
    assert eng.prefix.hits >= 1


def test_prefix_cache_rejected_on_dense_plane():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="dense",
                    prefix_cache=True)


def test_cow_shared_block_bytes_never_mutate():
    """The COW regression: while a sibling request prefills + decodes
    through a shared prefix, the shared pool blocks' bytes stay
    bit-identical — the sibling's writes all land in its own fresh
    blocks."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab_size, 24)
    a = Request(rid=0, prompt=np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, 3)]), max_new_tokens=12)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      block_len=8, prefix_cache=True)
    eng.submit(a)
    eng.step()                              # a prefilled + indexed
    shared_blocks = [int(x) for x in eng.pager.owned(0)[:3]]  # 24 // 8

    def pool_bytes():
        out = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                eng._caches)[0]:
            if getattr(path[-1], "key", "").endswith("_pool"):
                arr = np.asarray(leaf)
                # stacked segments carry leading layer axes
                arr = arr.reshape((-1,) + arr.shape[arr.ndim - 4:]) \
                    if arr.ndim > 4 else arr[None]
                out.append(arr[:, shared_blocks].copy())
        assert out
        return out

    before = pool_bytes()
    b = Request(rid=1, prompt=np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, 7)]), max_new_tokens=12)
    eng.submit(b)
    for _ in range(6):
        eng.step()                          # b resumes through the prefix
    assert len(b.out) >= 1
    assert eng.prefix.hit_blocks >= 3       # b actually shared the blocks
    after = pool_bytes()
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)
    eng.run()
    assert a.done and b.done


def test_finished_lender_prefix_survives_for_later_hits():
    """The lender finishing (slot freed) must not invalidate the cache:
    the blocks stay resident under the cache's reference and later
    requests still hit and emit identical tokens."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 24)
    mk = lambda rid, tl: Request(                       # noqa: E731
        rid=rid, prompt=np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, tl)]),
        max_new_tokens=4)
    tails = [(0, 3), (1, 7), (2, 5)]
    base_eng = ServeEngine(cfg, params, slots=1, max_len=64,
                           kv_impl="paged", block_len=8)
    rng2 = np.random.default_rng(3)
    shared2 = rng2.integers(0, cfg.vocab_size, 24)
    base_reqs = [Request(rid=r, prompt=np.concatenate(
        [shared2, rng2.integers(0, cfg.vocab_size, t)]), max_new_tokens=4)
        for r, t in tails]
    for r in base_reqs:
        base_eng.submit(r)
    base_eng.run()
    eng = ServeEngine(cfg, params, slots=1, max_len=64, kv_impl="paged",
                      block_len=8, prefix_cache=True)
    reqs = [mk(r, t) for r, t in tails]
    for r in reqs:                          # slots=1: strictly sequential,
        eng.submit(r)                       # every lender frees before the
    eng.run()                               # next request admits
    assert [r.out for r in reqs] == [r.out for r in base_reqs]
    assert eng.prefix.hits == 2


def test_eviction_under_pressure_keeps_serving():
    """A pool too small to hold the cache + a full working set forces
    evict_until on admission; every request still completes and tokens
    match the cache-off run."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(6))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 24 + t)
               for t in (3, 5, 7, 2, 6)]   # distinct prompts: cache fills
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=4)  # noqa: E731
                  for i, p in enumerate(prompts)]
    # 13 allocatable blocks; each request needs 4 (32 positions / 8)
    _, base = _serve(cfg, params, mk(), slots=2, block_len=8,
                     num_blocks=14)
    eng, got = _serve(cfg, params, mk(), slots=2, block_len=8,
                      num_blocks=14, prefix_cache=True)
    assert got == base
    assert eng.prefix.evicted_blocks >= 1   # pressure actually evicted


def test_prefix_metrics_emitted():
    """prefix.hit_tokens / kv.pool.blocks_saved / prefix.blocks_shared
    and engine.prefill.tokens land in the attached registry, and the
    prefill-token count actually collapses on the warm cache."""
    from repro import obs as obs_lib

    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    runs = {}
    for on in (False, True):
        obs = obs_lib.Observability()
        eng, _ = _serve(cfg, params, _mk_reqs(cfg), prefix_cache=on,
                        obs=obs)
        snap = {k: obs.metrics.get(k).value
                for k in ("engine.prefill.tokens", "prefix.hit_tokens",
                          "kv.pool.blocks_saved")}
        runs[on] = snap
    assert runs[False]["prefix.hit_tokens"] == 0
    assert runs[True]["prefix.hit_tokens"] >= 16
    assert runs[True]["kv.pool.blocks_saved"] >= 1
    assert (runs[True]["engine.prefill.tokens"]
            < runs[False]["engine.prefill.tokens"])
