"""Generalized CORDIC engine: bit-identity with the paper pipeline, the
mode x direction function library, and the activations-registry exposure."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed_point as fp
from repro.core.activations import get_activation
from repro.core import cordic as C
from repro.cordic_engine import (
    CIRC_ROTATION,
    HYP_ROTATION,
    HYP_VECTORING,
    LIN_VECTORING,
    CordicSchedule,
    functions as F,
)
from repro.cordic_engine import core as eng

f32 = lambda v: jnp.asarray(v, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Bit-identity of the engine specialization with the paper pipeline
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_sigmoid_bit_identical_all_codes():
    """Engine-specialized sigmoid == the independent kernel transcription of
    the seed Q2.14 pipeline, over ALL 2^16 input codes (in- and out-of-domain
    — the datapath is deterministic everywhere)."""
    from repro.kernels import cordic_act as K

    xq = jnp.arange(-(1 << 15), 1 << 15, dtype=jnp.int32)
    via_engine = np.asarray(C.sigmoid_mr_q(xq, C.PAPER_SCHEDULE, C.PAPER_FIXED))
    seed_transcription = np.asarray(
        K._cordic_sigmoid_q(xq, C.PAPER_SCHEDULE, C.PAPER_FIXED))
    np.testing.assert_array_equal(via_engine, seed_transcription)


def test_engine_rotation_is_mr_hrc():
    """rotate_q with the paper schedule == mr_hrc_q (cosh/sinh codes)."""
    zq = fp.quantize(jnp.linspace(-0.5, 0.5, 4097, dtype=jnp.float32), fp.Q2_14)
    c1, s1, _ = eng.rotate_q(zq, HYP_ROTATION, C.PAPER_FIXED)
    c2, s2, _ = C.mr_hrc_q(zq)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_paper_schedule_bridges():
    """MRSchedule.rotation/.division expose the generalized schedules."""
    assert C.PAPER_SCHEDULE.rotation == HYP_ROTATION
    assert C.PAPER_SCHEDULE.division == CordicSchedule("linear", tuple(range(1, 15)))
    assert abs(HYP_ROTATION.x0 - C.PAPER_SCHEDULE.x0) < 1e-15


# ---------------------------------------------------------------------------
# Function library accuracy (fixed datapath, dyadic range reduction)
# ---------------------------------------------------------------------------
def test_exp_fixed_relative_error():
    x = jnp.linspace(-10.0, 10.0, 8001, dtype=jnp.float32)
    got = np.asarray(F.exp_fixed(x), np.float64)
    want = np.exp(np.asarray(x, np.float64))
    assert np.abs(got / want - 1.0).max() < 2e-3


def test_exp_float_algorithmic_error():
    x = jnp.linspace(-6.0, 6.0, 4001, dtype=jnp.float32)
    got = np.asarray(F.exp_float(x), np.float64)
    want = np.exp(np.asarray(x, np.float64))
    assert np.abs(got / want - 1.0).max() < 1e-4


def test_log_fixed_error():
    x = jnp.asarray(np.geomspace(1e-3, 1e3, 4001), jnp.float32)
    got = np.asarray(F.log_fixed(x), np.float64)
    want = np.log(np.asarray(x, np.float64))
    assert np.abs(got - want).max() < 2e-3


def test_atanh_fixed_error():
    t = jnp.linspace(-0.75, 0.75, 2001, dtype=jnp.float32)
    got = np.asarray(F.atanh_fixed(t), np.float64)
    want = np.arctanh(np.asarray(t, np.float64))
    assert np.abs(got - want).max() < 1e-3


def test_divide_fixed_full_range():
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.uniform(-100, 100, 4096), jnp.float32)
    x = jnp.asarray(np.sign(rng.uniform(-1, 1, 4096))
                    * np.exp(rng.uniform(np.log(1e-2), np.log(1e2), 4096)),
                    jnp.float32)
    got = np.asarray(F.divide_fixed(y, x), np.float64)
    want = np.asarray(y, np.float64) / np.asarray(x, np.float64)
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
    assert rel.max() < 2e-3


def test_divide_zero_operands():
    assert float(F.divide_fixed(f32(0.0), f32(3.0))) == 0.0
    assert float(F.divide_fixed(f32(2.0), f32(0.0))) == 0.0


def test_reciprocal_fixed():
    x = jnp.asarray(np.geomspace(0.05, 50, 1001), jnp.float32)
    got = np.asarray(F.reciprocal_fixed(x), np.float64)
    rel = np.abs(got * np.asarray(x, np.float64) - 1.0)
    assert rel.max() < 2e-3


def test_multiply_fixed_full_range():
    """Linear-rotation multiply: rel error at the divide's accuracy class."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(-100, 100, 4096), jnp.float32)
    b = jnp.asarray(np.sign(rng.uniform(-1, 1, 4096))
                    * np.exp(rng.uniform(np.log(1e-3), np.log(1e3), 4096)),
                    jnp.float32)
    got = np.asarray(F.multiply_fixed(a, b), np.float64)
    want = np.asarray(a, np.float64) * np.asarray(b, np.float64)
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
    assert rel.max() < 2e-3


def test_multiply_float_algorithmic_error():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(-10, 10, 2048), jnp.float32)
    b = jnp.asarray(rng.uniform(-10, 10, 2048), jnp.float32)
    got = np.asarray(F.multiply_float(a, b), np.float64)
    want = np.asarray(a, np.float64) * np.asarray(b, np.float64)
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
    assert rel.max() < 2e-4


def test_multiply_zero_operands_and_broadcast():
    assert float(F.multiply_fixed(f32(0.0), f32(3.0))) == 0.0
    assert float(F.multiply_fixed(f32(5.0), f32(0.0))) == 0.0
    # broadcasting: (V,) logits times a scalar reciprocal (the sampler shape)
    v = jnp.linspace(-4.0, 4.0, 33)
    out = F.multiply_fixed(v, f32(0.5))
    assert out.shape == v.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(v) * 0.5, atol=2e-3)


def test_multiply_reciprocal_compose_as_division():
    """multiply(y, reciprocal(x)) tracks divide(y, x) — the temperature
    datapath (1/T via R2-LVC, then linear rotation) stays consistent."""
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.uniform(-50, 50, 1024), jnp.float32)
    x = jnp.asarray(np.exp(rng.uniform(np.log(0.1), np.log(10), 1024)),
                    jnp.float32)
    via_mul = np.asarray(F.multiply_fixed(y, F.reciprocal_fixed(x)), np.float64)
    want = np.asarray(y, np.float64) / np.asarray(x, np.float64)
    rel = np.abs(via_mul - want) / np.maximum(np.abs(want), 1e-9)
    assert rel.max() < 4e-3


def test_sincos_fixed_error():
    t = jnp.linspace(-8.0, 8.0, 4001, dtype=jnp.float32)
    s, c = F.sincos_fixed(t)
    td = np.asarray(t, np.float64)
    assert np.abs(np.asarray(s, np.float64) - np.sin(td)).max() < 1.5e-3
    assert np.abs(np.asarray(c, np.float64) - np.cos(td)).max() < 1.5e-3
    # pythagorean identity survives the quadrant logic
    assert np.abs(np.asarray(s) ** 2 + np.asarray(c) ** 2 - 1.0).max() < 3e-3


def test_circular_rotation_gain():
    assert abs(CIRC_ROTATION.gain - math.prod(
        math.sqrt(1 + 4.0 ** (-j)) for j in range(14))) < 1e-12
    assert CIRC_ROTATION.angle_range > math.pi / 4


def test_hyp_vectoring_schedule_has_repeats():
    js = HYP_VECTORING.r2_js
    assert js.count(4) == 2 and js.count(13) == 2


def test_softplus_elu_gelu_fixed_error():
    x = jnp.linspace(-8.0, 8.0, 4001, dtype=jnp.float32)
    xd = np.asarray(x, np.float64)
    sp = np.asarray(F.softplus_fixed(x), np.float64)
    assert np.abs(sp - np.logaddexp(0.0, xd)).max() < 2e-3
    el = np.asarray(F.elu_fixed(x), np.float64)
    want_elu = np.where(xd > 0, xd, np.expm1(xd))
    assert np.abs(el - want_elu).max() < 1e-3
    ge = np.asarray(F.gelu_erf_fixed(x), np.float64)
    want_gelu = np.asarray(jax.nn.gelu(x, approximate=False), np.float64)
    assert np.abs(ge - want_gelu).max() < 3e-3


def test_softmax_fixed_matches_exact():
    logits = jax.random.normal(jax.random.PRNGKey(3), (16, 257)) * 4.0
    got = np.asarray(F.softmax_fixed(logits))
    want = np.asarray(jax.nn.softmax(logits))
    assert np.abs(got - want).max() < 1e-2
    assert np.abs(got.sum(-1) - 1.0).max() < 5e-3


# ---------------------------------------------------------------------------
# Registry exposure + differentiability (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["exp", "softplus", "elu", "gelu_erf"])
@pytest.mark.parametrize("impl", ["exact", "cordic_float", "cordic_fixed"])
def test_registry_exposes_engine_kinds(kind, impl):
    act = get_activation(kind, impl)
    x = jnp.linspace(-3.0, 3.0, 64)
    y = act(x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    g = jax.grad(lambda v: jnp.sum(act(v)))(x)
    assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("kind,deriv", [
    ("exp", lambda x: np.exp(x)),
    ("softplus", lambda x: 1.0 / (1.0 + np.exp(-x))),
    ("elu", lambda x: np.where(x > 0, 1.0, np.exp(x))),
])
def test_registry_jvp_matches_analytic(kind, deriv):
    act = get_activation(kind, "cordic_fixed")
    x = jnp.linspace(-2.0, 2.0, 41)
    g = np.asarray(jax.vmap(jax.grad(act))(x), np.float64)
    want = deriv(np.asarray(x, np.float64))
    assert np.abs(g - want).max() < 5e-3


def test_engine_kinds_jit_and_vmap():
    act = get_activation("exp", "cordic_fixed")
    x = jnp.linspace(-1.0, 1.0, 32).reshape(4, 8)
    a = jax.jit(act)(x)
    b = jax.vmap(act)(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
