"""Docs lane: the markdown tree must not rot.

Two contracts, both pure-host (no jax import):

1. Intra-repo references resolve — markdown links ``[text](path)`` and
   backticked file paths in README.md + docs/*.md point at files that
   exist.
2. ``docs/observability.md`` and the metric-registration code agree in
   BOTH directions: every metric name documented exists in
   ``src/repro/obs/`` / ``serve/engine.py`` / ``serve/kv_pager.py``,
   and every name registered there is documented. Dynamic names are
   compared as wildcard-normalized patterns (``engine.phase.<name>_ms``
   in the doc == ``f"engine.phase.{name}_ms"`` in code).
"""
from __future__ import annotations

import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
OBS_DOC = ROOT / "docs" / "observability.md"

#: markdown files whose links and path references must resolve
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

#: files whose metric registrations the doc must mirror
METRIC_SOURCE_FILES = [
    *sorted((ROOT / "src" / "repro" / "obs").glob("*.py")),
    ROOT / "src" / "repro" / "serve" / "engine.py",
    ROOT / "src" / "repro" / "serve" / "kv_pager.py",
]

#: a documented metric name starts with one of these
METRIC_PREFIXES = ("engine.", "kv.pool.", "kv.quant.", "prefix.",
                   "fixed_point.")

_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TICK_RE = re.compile(r"`([^`\n]+)`")
# registration call with the name literal on the same line, e.g.
#   m.counter("engine.steps", ...)   metrics.gauge("kv.pool...", ...)
#   registry.counter(f"fixed_point.saturation.clips{{fmt={fmt}}}", ...)
_REG_RE = re.compile(r"\.(?:counter|gauge|histogram)\(\s*(f?)\"([^\"]+)\"")
_PATH_EXT = (".py", ".md", ".yml", ".yaml", ".json", ".txt", ".ini",
             ".npz", ".cfg", ".toml")


def _strip_fences(text: str) -> str:
    return _FENCE_RE.sub("", text)


def _normalize_doc_name(name: str) -> str:
    """``engine.phase.<name>_ms`` -> ``engine.phase.*_ms``;
    ``...{fmt=...}`` -> ``...{fmt=*}``."""
    name = re.sub(r"<[^>]*>", "*", name)
    return name.replace("...", "*")


def _normalize_code_name(name: str, is_fstring: bool) -> str:
    """f-string replacement fields -> ``*``; ``{{``/``}}`` -> literal.
    Fields are identifier-shaped, so ``{{fmt={fmt}}}`` normalizes field
    first (``{{fmt=*}}``) then unescapes to ``{fmt=*}``."""
    if is_fstring:
        name = re.sub(r"\{[A-Za-z_][A-Za-z0-9_.\[\]]*\}", "*", name)
        name = name.replace("{{", "{").replace("}}", "}")
    return name


def _doc_metric_names() -> set:
    text = _strip_fences(OBS_DOC.read_text())
    out = set()
    for m in _TICK_RE.finditer(text):
        name = m.group(1)
        if name.startswith(METRIC_PREFIXES) and "/" not in name \
                and " " not in name:
            out.add(_normalize_doc_name(name))
    return out


def _code_metric_names() -> set:
    out = set()
    for path in METRIC_SOURCE_FILES:
        for m in _REG_RE.finditer(path.read_text()):
            name = _normalize_code_name(m.group(2), bool(m.group(1)))
            if name.startswith(METRIC_PREFIXES):
                out.add(name)
    return out


# -- 1. references resolve ---------------------------------------------------
def test_markdown_links_resolve():
    missing = []
    for doc in DOC_FILES:
        for m in _LINK_RE.finditer(_strip_fences(doc.read_text())):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(("http://", "https://",
                                               "mailto:")):
                continue
            if not (doc.parent / target).exists():
                missing.append(f"{doc.relative_to(ROOT)} -> {target}")
    assert not missing, f"broken markdown links: {missing}"


def test_backticked_paths_exist():
    """Backticked repo paths in the docs tree must exist (root-relative,
    or src/repro-relative for the short ``serve/engine.py`` style)."""
    missing = []
    for doc in DOC_FILES:
        for m in _TICK_RE.finditer(_strip_fences(doc.read_text())):
            ref = m.group(1)
            if "/" not in ref or " " in ref or "*" in ref \
                    or ref.startswith(("/", "<", "http")) \
                    or not ref.endswith(_PATH_EXT):
                continue
            if not ((ROOT / ref).exists()
                    or (ROOT / "src" / "repro" / ref).exists()):
                missing.append(f"{doc.relative_to(ROOT)} -> {ref}")
    assert not missing, f"dangling path references: {missing}"


# -- 2. metric names: doc <-> code, both directions --------------------------
def test_doc_metrics_exist_in_code():
    doc, code = _doc_metric_names(), _code_metric_names()
    assert doc, "no metric names parsed from docs/observability.md"
    phantom = doc - code
    assert not phantom, (
        f"documented in docs/observability.md but registered nowhere in "
        f"{[str(p.relative_to(ROOT)) for p in METRIC_SOURCE_FILES]}: "
        f"{sorted(phantom)}")


def test_code_metrics_documented():
    doc, code = _doc_metric_names(), _code_metric_names()
    assert code, "no metric registrations parsed from source"
    undocumented = code - doc
    assert not undocumented, (
        f"registered in code but missing from docs/observability.md: "
        f"{sorted(undocumented)}")


def test_known_series_present():
    """Spot-check the series the benchmarks gate on, so a refactor that
    silently breaks the regexes above cannot pass both directions by
    parsing empty sets of the same wrong shape."""
    doc = _doc_metric_names()
    for name in ("engine.ttft_ms", "engine.prefill.tokens",
                 "prefix.hit_tokens", "prefix.blocks_shared",
                 "kv.pool.blocks_saved", "kv.pool.blocks_in_use",
                 "kv.pool.bytes_in_use", "kv.quant.code_bits",
                 "kv.quant.bytes_per_token", "engine.phase.*_ms",
                 "fixed_point.saturation.clips{fmt=*}"):
        assert name in doc, f"{name} missing from docs/observability.md"
