"""Property-based tests for the CORDIC system invariants.

Runs under hypothesis when available; on a clean environment (hypothesis is
an optional dep) the same properties are checked over a deterministic value
grid spanning each strategy's bounds — so the seed suite never fails to
collect.
"""
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # deterministic-grid fallback
    class _FloatGrid:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def grid(self, n):
            # odd n: includes both endpoints and (for symmetric ranges) 0
            return np.linspace(self.lo, self.hi, n, dtype=np.float64)

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _FloatGrid(min_value, max_value)

    def settings(**_kw):
        return lambda fn: fn

    def given(*strats):
        def deco(fn):
            n = 13 if len(strats) == 1 else 7
            cases = list(itertools.product(*[s.grid(n) for s in strats]))

            def wrapper():
                for args in cases:
                    fn(*(float(a) for a in args))

            # no functools.wraps: pytest must see the zero-arg signature
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

from repro.core import cordic as C
from repro.core import fixed_point as fp
from repro.core import sigmoid as S

SCHED = C.PAPER_SCHEDULE
CFG = C.PAPER_FIXED

f32 = lambda v: jnp.asarray(v, dtype=jnp.float32)

unit_inputs = st.floats(min_value=-1.0, max_value=1.0, allow_nan=False,
                        allow_infinity=False, width=32)
half_inputs = st.floats(min_value=-0.5, max_value=0.5, allow_nan=False,
                        allow_infinity=False, width=32)


@settings(max_examples=200, deadline=None)
@given(half_inputs)
def test_hrc_computes_sinh_cosh(z):
    """MR-HRC float: x_N ~ cosh(z), y_N ~ sinh(z) (paper Fig. 2, stage 1)."""
    c, s, zr = C.mr_hrc_f(f32(z), SCHED)
    assert abs(float(c) - math.cosh(z)) < 5e-4
    assert abs(float(s) - math.sinh(z)) < 5e-4


@settings(max_examples=200, deadline=None)
@given(half_inputs)
def test_residual_contracts_through_pipeline(z):
    """|residual| after R2+R4 is below the radix-4 terminal step bound."""
    _, _, zr = C.mr_hrc_f(f32(z), SCHED)
    # terminal radix-4 step: atanh(2*4^-7) plus SRT half-interval slack
    bound = math.atanh(2 * 4.0 ** -7) + 0.5 * 4.0 ** -7 + 1e-6
    assert abs(float(zr)) < 4 * bound


@settings(max_examples=200, deadline=None)
@given(half_inputs)
def test_r2_residual_within_r4_range(z):
    """Stage handoff: R2 residual always inside R4 admissible range."""
    res = float(C.r2_residual_f(f32(z), SCHED))
    assert res <= SCHED.r4_range + 1e-7


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-0.984375, max_value=0.984375, allow_nan=False, width=32),
       st.floats(min_value=0.625, max_value=1.25, allow_nan=False, width=32))
def test_lvc_division(ratio, x0):
    """R2-LVC computes y/x for any |y/x| <= 2 domain point (float).

    Hard bound: after the last iteration |y_N| <= x*2^-14, so the quotient
    error is <= 2^-14 ~ 6.1e-5 plus f32 noise."""
    y0 = ratio * x0
    z = C.r2_lvc_f(f32(x0), f32(y0), SCHED.lvc_js)
    assert abs(float(z) - ratio) < 2.0 ** -14 + 1e-5


@settings(max_examples=200, deadline=None)
@given(unit_inputs)
def test_sigmoid_fixed_error_bound(x):
    """Pointwise |error| of the Q2.14 pipeline <= 1e-3 everywhere in-domain."""
    y = float(S.sigmoid_cordic_fixed(f32(x)))
    assert abs(y - 1.0 / (1.0 + math.exp(-x))) < 1e-3


@settings(max_examples=100, deadline=None)
@given(unit_inputs)
def test_sigmoid_symmetry(x):
    """sigma(-x) = 1 - sigma(x) within 2 output ULPs (odd-symmetric datapath)."""
    a = float(S.sigmoid_cordic_fixed(f32(x)))
    b = float(S.sigmoid_cordic_fixed(f32(-x)))
    # shift truncation (floor) is sign-asymmetric, so the residual asymmetry
    # is a few ULPs rather than zero — measured worst case 8 ULP over the
    # whole code grid (truncation bias accumulating across 26 stages).
    assert abs((a + b) - 1.0) <= 8.5 * fp.Q2_14.resolution


def test_sigmoid_monotone_on_grid():
    """Quasi-monotonicity on the full representable input grid (2^15 codes).

    Truncation noise produces isolated glitches of a few ULPs (measured
    min step -3 ULP on 4/32768 codes); the coarse trend must be strictly
    increasing and glitches bounded."""
    xq = jnp.arange(-(1 << 14), (1 << 14) + 1, dtype=jnp.int32)
    yq = np.asarray(C.sigmoid_mr_q(xq, SCHED, CFG))
    dy = np.diff(yq)
    assert dy.min() >= -4            # glitches bounded
    assert (dy < 0).sum() <= 64      # and rare
    coarse = yq[::256]
    assert np.all(np.diff(coarse) > 0)


@settings(max_examples=100, deadline=None)
@given(unit_inputs)
def test_no_wraparound_in_domain(x):
    """All datapath registers stay inside Q2.14 (-2, 2): wrap never fires.

    Checked by running the same pipeline in a 24-bit format with identical
    fraction bits: if 16-bit wrapped anywhere, outputs would diverge by >2.
    """
    xq = fp.quantize(f32(x), fp.Q2_14)
    y16 = C.sigmoid_mr_q(xq, SCHED, C.FixedConfig(fmt=fp.Q2_14))
    wide = C.FixedConfig(fmt=fp.QFormat(total_bits=24, frac_bits=14))
    y24 = C.sigmoid_mr_q(xq, SCHED, wide)
    assert int(y16) == int(y24)


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-8.0, max_value=8.0, allow_nan=False, width=32))
def test_wide_range_error(x):
    y = float(S.sigmoid_cordic_wide(f32(x)))
    assert abs(y - 1.0 / (1.0 + math.exp(-x))) < 6e-3


@settings(max_examples=50, deadline=None)
@given(unit_inputs)
def test_gradient_matches_analytic(x):
    """custom_jvp: d/dx of the registry sigmoid == s(1-s) from the primal."""
    from repro.core.activations import get_activation

    act = get_activation("sigmoid", "cordic_fixed", range_mode="clamp")
    g = float(jax.grad(lambda v: act(v))(f32(x)))
    s = float(act(f32(x)))
    assert abs(g - s * (1 - s)) < 1e-6


def test_digit_selection_bounds():
    """R4 SRT digit selection keeps the scaled residual in [-8/3, 8/3]-ish:
    after selecting sigma on w = 4^j z, the post-step |w'| <= 2 (next scale)."""
    rng = np.random.default_rng(0)
    for j in SCHED.r4_js:
        w = rng.uniform(-2.6, 2.6, size=4096).astype(np.float32)  # admissible w
        z = jnp.asarray(w) * (4.0 ** -j)
        s = C._r4_digit_f(z, j)
        z_next = z - jnp.sign(s) * jnp.where(
            jnp.abs(s) == 2, math.atanh(2 * 4.0 ** -j),
            jnp.where(jnp.abs(s) == 1, math.atanh(4.0 ** -j), 0.0))
        w_next = np.asarray(z_next) * (4.0 ** (j + 1))
        assert np.abs(w_next).max() <= 2.7  # stays admissible for next iter
