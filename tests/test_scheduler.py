"""Iteration-level scheduler tests (serve/scheduler.py + the chunked /
multi-request prefill paths in serve/engine.py):

* scheduler unit behavior — chunk geometry validation, single-shot vs
  chunked admission widths, continuations-before-admissions ordering,
  the max_prefill_tokens budget (with guaranteed progress), FIFO
  backpressure via the admit callback;
* chunked-prefill bit-identity — the acceptance bar: chunked (and
  multi-row batched) serving emits token streams bit-identical to the
  unchunked engine, greedy AND seeded sampling, GQA and MLA attention,
  dense and paged KV planes;
* TTFT flatness — a short prompt submitted behind a long chunking prompt
  gets its first token within a bounded number of iterations instead of
  waiting out the whole long prefill;
* compile bounds — chunking/batching keep the prefill jit cache bounded
  by bucket x chunk-width x pow2-batch variants, decode stays at <= 2;
* submit()-validation regressions — over-long and empty prompts are
  rejected cleanly at submit instead of raising out of step(), budgets
  are clamped once at submit, the queue is a deque, and lifecycle
  timestamps are stamped whether observability is attached or not.

MoE carve-out: capacity-factor MoE (models/moe.py) sizes its per-expert
queues from the dispatch width (C = ceil(S*K*cap/E)), so routing — like
under any batch-size change — is not invariant to how a prompt is split
into chunks. The MLA identity tests therefore run MLA attention with the
dense FFN (block_pattern mla_dense), which is chunk-exact; full MoE archs
serve chunked with numerically-close but not bitwise-equal streams.
"""
import dataclasses
import os
from collections import deque

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import IterationScheduler

# like tests/test_serving.py: the conformance CI lane re-runs this file
# once per datapath backend with the matching attention softmax, so a
# chunked-identity drift in one backend is attributed there
_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")
assert _BACKEND in _SOFTMAX_BY_BACKEND, \
    f"REPRO_TEST_BACKEND={_BACKEND!r} not in " \
    f"{sorted(filter(None, _SOFTMAX_BY_BACKEND))}"


def _cfg(arch="yi-9b"):
    cfg = dataclasses.replace(configs.get_smoke(arch, act_impl="exact"),
                              softmax_impl=_SOFTMAX_BY_BACKEND[_BACKEND])
    if arch == "deepseek-v2-lite-16b":
        # MLA attention with the dense FFN: chunk-exact (see module
        # docstring for the MoE capacity carve-out)
        cfg = dataclasses.replace(
            cfg, block_pattern=("mla_dense",) * cfg.num_layers)
    return cfg


# ---------------------------------------------------------------------------
# Scheduler unit behavior (pure host logic, no jax)
# ---------------------------------------------------------------------------
def _sched(**kw):
    kw.setdefault("buckets", (16, 32, 64))
    kw.setdefault("block_len", 16)
    kw.setdefault("max_len", 64)
    return IterationScheduler(**kw)


def _req(rid, plen):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32))


def test_scheduler_validates_chunk_geometry():
    with pytest.raises(ValueError, match="bucketed"):
        _sched(buckets=None, prefill_chunk=16)
    with pytest.raises(ValueError, match="multiple of block_len"):
        _sched(prefill_chunk=10)
    with pytest.raises(ValueError, match="chunk coverage"):
        IterationScheduler(buckets=(16, 32, 48), block_len=16, max_len=48,
                           prefill_chunk=32)
    with pytest.raises(ValueError, match="max_prefill_tokens"):
        _sched(max_prefill_tokens=0)


def test_scheduler_single_shot_and_admission_width():
    s = _sched(prefill_chunk=16)
    assert s.single_shot(10) and s.admission_width(10) == 16
    assert s.single_shot(16) and s.admission_width(16) == 16
    assert not s.single_shot(17) and s.admission_width(17) == 16
    assert not s.single_shot(40) and s.admission_width(40) == 16
    # chunking off: every prompt is single-shot at its bucket width
    u = _sched()
    assert u.single_shot(40) and u.admission_width(40) == 64
    # recurrent (bucketless): exact length, never chunked
    r = _sched(buckets=None)
    assert r.single_shot(23) and r.admission_width(23) == 23


def test_scheduler_chunk_wider_than_smallest_bucket():
    # plen 20 -> bucket 32 > chunk 32? no: chunk 32, bucket_for(20)=32
    # equal is single-shot; plen 10 -> bucket 16 <= chunk 32 single-shot
    s = _sched(prefill_chunk=32)
    assert s.single_shot(20) and s.admission_width(20) == 32
    assert s.single_shot(33) is False and s.admission_width(33) == 32


def test_scheduler_plan_continuations_before_admissions():
    s = _sched(prefill_chunk=16)
    s.enqueue(_req(0, 40))          # 3 chunks
    s.enqueue(_req(1, 5))           # single-shot
    slots = iter(range(8))
    rows = s.plan(lambda r: next(slots))
    assert [(r.req.rid, r.start, r.final, r.fresh) for r in rows] == \
        [(0, 0, False, True), (1, 0, True, True)]
    assert set(s.chunking) == {0}
    rows = s.plan(lambda r: next(slots))
    assert [(r.req.rid, r.start, r.final) for r in rows] == [(0, 16, False)]
    rows = s.plan(lambda r: next(slots))
    assert [(r.req.rid, r.start, r.final) for r in rows] == [(0, 32, True)]
    assert s.chunking == {} and s.plan(lambda r: next(slots)) == []


def test_scheduler_budget_caps_rows_but_guarantees_progress():
    s = _sched(prefill_chunk=16, max_prefill_tokens=16)
    s.enqueue(_req(0, 40))
    s.enqueue(_req(1, 5))
    slots = iter(range(8))
    rows = s.plan(lambda r: next(slots))       # budget: chunk0 of rid 0 only
    assert [(r.req.rid, r.start) for r in rows] == [(0, 0)]
    rows = s.plan(lambda r: next(slots))       # continuation first, still 16
    assert [(r.req.rid, r.start) for r in rows] == [(0, 16)]
    rows = s.plan(lambda r: next(slots))
    assert [(r.req.rid, r.start) for r in rows] == [(0, 32)]
    rows = s.plan(lambda r: next(slots))       # queue finally drains
    assert [(r.req.rid, r.start) for r in rows] == [(1, 0)]
    # a budget smaller than one row still schedules that row (progress)
    t = _sched(prefill_chunk=16, max_prefill_tokens=1)
    t.enqueue(_req(0, 5))
    assert len(t.plan(lambda r: 0)) == 1


def test_scheduler_admit_backpressure_preserves_fifo():
    s = _sched(prefill_chunk=16)
    s.enqueue(_req(0, 5))
    s.enqueue(_req(1, 5))
    assert s.plan(lambda r: None) == []        # nothing seatable
    assert [r.rid for r in s.queue] == [0, 1]  # head did not rotate
    rows = s.plan(lambda r: 3 if r.rid == 0 else None)
    assert [r.req.rid for r in rows] == [0]    # head seated, next waits
    assert [r.rid for r in s.queue] == [1]


def test_scheduler_drop_slot_forgets_continuation():
    s = _sched(prefill_chunk=16)
    s.enqueue(_req(0, 40))
    s.plan(lambda r: 2)
    assert 2 in s.chunking
    s.drop_slot(2)
    assert s.chunking == {}


# ---------------------------------------------------------------------------
# Chunked / batched prefill bit-identity with the unchunked engine
# ---------------------------------------------------------------------------
def _mk_reqs(cfg, *, seed=7):
    """Mixed lengths spanning single-shot and multi-chunk prompts, mixed
    greedy/sampling so both decode variants and the per-request key
    streams are on the hot path."""
    rng = np.random.default_rng(seed)
    kinds = [SamplingParams(greedy=True), SamplingParams(temperature=2.5),
             SamplingParams(temperature=1.5, top_k=8), None]
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
                    max_new_tokens=5, sampling=kinds[i % len(kinds)])
            for i, plen in enumerate([5, 40, 17, 33, 9, 24])]


def _serve(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, slots=4, max_len=64, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.out for r in reqs]


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("kv_impl", ["dense", "paged"])
def test_chunked_prefill_bit_identical(arch, kv_impl):
    """The acceptance bar for chunked prefill: identical token streams to
    the unchunked engine for the same mixed-length mixed-sampling request
    set — GQA (yi-9b) and MLA (deepseek MLA attention), dense and paged.
    Paged runs also exercise multi-row batching (prefill_batch defaults
    to slots when chunking a paged engine)."""
    cfg = _cfg(arch)
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, base = _serve(cfg, params, _mk_reqs(cfg), kv_impl=kv_impl)
    eng, chunked = _serve(cfg, params, _mk_reqs(cfg), kv_impl=kv_impl,
                          prefill_chunk=16)
    assert chunked == base
    # chunking actually happened (prompts 40/17/33/24 span >1 chunk)
    assert eng.scheduler.prefill_chunk == 16


def test_chunked_prefill_batch_and_budget_variants():
    """Scheduling knobs never change tokens: single-row chunking, forced
    multi-row batching, and a tight token budget all reproduce the
    unchunked stream on the paged plane."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(3))
    _, base = _serve(cfg, params, _mk_reqs(cfg), kv_impl="paged")
    for kw in ({"prefill_chunk": 16, "prefill_batch": 1},
               {"prefill_chunk": 16, "prefill_batch": 4},
               {"prefill_chunk": 32, "max_prefill_tokens": 32}):
        _, got = _serve(cfg, params, _mk_reqs(cfg), kv_impl="paged", **kw)
        assert got == base, kw


def test_chunked_dense_matches_manual_stream():
    """Dense chunking holds the partial cache host-side until the final
    chunk; the emitted stream still matches the batch=1 unchunked run."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(5))
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, 37)
    reqs = lambda: [Request(rid=0, prompt=prompt,
                            max_new_tokens=6)]          # noqa: E731
    _, base = _serve(cfg, params, reqs(), kv_impl="dense")
    _, got = _serve(cfg, params, reqs(), kv_impl="dense", prefill_chunk=16)
    assert got == base


# ---------------------------------------------------------------------------
# TTFT flatness: interleaving chunks with decode
# ---------------------------------------------------------------------------
def test_short_request_first_token_not_blocked_by_long_prefill():
    """A short prompt submitted behind a 64-token (4-chunk) prompt gets
    its first token on the very first iteration — admitted alongside the
    long prompt's first chunk instead of queued behind its whole prefill —
    and keeps decoding every iteration while the long prompt streams in."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    long = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 64),
                   max_new_tokens=1)
    short = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 4),
                    max_new_tokens=8)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      prefill_chunk=16)
    eng.submit(long)
    eng.submit(short)
    eng.step()
    assert len(short.out) >= 1                 # first token: iteration 1
    assert 0 in eng.scheduler.chunking         # long prompt still mid-prefill
    eng.step()
    assert len(short.out) >= 2                 # decode interleaves chunks
    assert 0 in eng.scheduler.chunking
    eng.run()
    assert long.done and short.done
    assert len(long.out) == 1 and len(short.out) == 8


def test_mid_prefill_slot_excluded_from_decode():
    """A slot mid-chunking never emits decode tokens: its request's out
    stays empty until the final chunk lands."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 48),
                  max_new_tokens=4)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                      prefill_chunk=16)
    eng.submit(req)
    assert eng.step() > 0                      # chunk 0: prefill-only
    assert req.out == [] and 0 in eng.scheduler.chunking
    assert eng.step() > 0                      # chunk 1: still mid-prefill
    assert req.out == []
    # final chunk lands, then the slot joins that same iteration's decode
    eng.step()
    assert len(req.out) >= 1 and 0 not in eng.scheduler.chunking
    eng.run()
    assert len(req.out) == 4


# ---------------------------------------------------------------------------
# Compile bounds under chunking/batching
# ---------------------------------------------------------------------------
def test_chunked_compile_counts_bounded():
    """Chunking keeps prefill compiles bounded by width variants (buckets
    <= chunk, plus the chunk itself) x pow2 batch dims, and decode at 2 —
    serving 7 distinct prompt lengths with mixed sampling never compiles
    per-length."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=4, max_len=64, kv_impl="paged",
                      prefill_chunk=16)
    assert eng.buckets == (16, 32, 64)
    rng = np.random.default_rng(0)
    for i, plen in enumerate([3, 5, 9, 17, 25, 40, 64]):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen),
                           max_new_tokens=3,
                           sampling=(SamplingParams(temperature=2.0)
                                     if i % 2 else None)))
    done = eng.run()
    assert len(done) == 7
    counts = eng.compile_counts()
    # every row is 16 wide (buckets <= chunk collapse onto the chunk
    # width); batch dims are pow2 in [1, slots] -> at most 3 variants
    assert counts["prefill"] <= 3, counts
    assert counts["decode"] <= 2, counts


def test_unchunked_defaults_keep_legacy_bound():
    """With the knobs off the jit cache is bit-for-bit the legacy shape:
    prefill <= len(buckets), decode <= 2."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=4, max_len=64, kv_impl="paged")
    rng = np.random.default_rng(0)
    for i, plen in enumerate([3, 17, 40]):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen),
                           max_new_tokens=3))
    eng.run()
    counts = eng.compile_counts()
    assert counts["prefill"] <= len(eng.buckets)
    assert counts["decode"] <= 2


# ---------------------------------------------------------------------------
# submit() validation + queue regressions
# ---------------------------------------------------------------------------
def test_overlong_prompt_rejected_at_submit_not_step():
    """An over-max_len prompt used to raise ValueError out of bucket_for
    deep inside step(), killing the loop with other requests in flight;
    it must be rejected at submit and the loop must keep serving."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged")
    rng = np.random.default_rng(0)
    ok = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 5),
                 max_new_tokens=4)
    too_long = Request(rid=1,
                       prompt=rng.integers(0, cfg.vocab_size, 65),
                       max_new_tokens=4)
    eng.submit(ok)
    eng.submit(too_long)
    assert too_long.done and too_long.out == []
    assert "max_len" in too_long.error
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1]
    assert ok.error is None and len(ok.out) == 4


def test_empty_prompt_rejected_at_submit():
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    bad = Request(rid=0, prompt=np.zeros(0, np.int32))
    eng.submit(bad)
    assert bad.done and "empty" in bad.error
    assert eng.run() == [bad]


def test_queue_is_deque_and_budget_clamped_once_at_submit():
    """The admission-scan regression: the queue was a list popped at index
    0 and every _admit re-clamped every queued budget (O(n^2) across a
    burst). Now it is a deque and the clamp happens exactly once, at
    submit — observable immediately, before any step."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    assert isinstance(eng._queue, deque)
    req = Request(rid=0, prompt=np.zeros(40, np.int32) + 3,
                  max_new_tokens=500)
    eng.submit(req)
    assert req.max_new_tokens == 64 - 40 + 1   # clamped at submit
    assert len(eng._queue) == 1


def test_lifecycle_timestamps_stamped_without_obs():
    """Requests served by an obs-less engine still carry absolute
    lifecycle timestamps (the attach-after-warmup path depends on
    t_enqueue existing for requests submitted before attach_obs)."""
    cfg = _cfg()
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    req = Request(rid=0, prompt=np.asarray([3, 5, 7], np.int32),
                  max_new_tokens=3)
    eng.submit(req)
    assert req.t_enqueue > 0                   # stamped before any obs
    eng.run()
    assert 0 < req.t_enqueue <= req.t_admit <= req.t_first <= req.t_finish


def test_chunking_requires_bucketed_arch():
    """Recurrent archs prefill at exact length; asking for chunking is a
    config error at engine construction, not a silent fallback."""
    cfg = configs.get_smoke("xlstm-1.3b", act_impl="exact")
    params = tf.init(cfg, jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="bucketed"):
        ServeEngine(cfg, params, slots=1, max_len=32, prefill_chunk=16)
