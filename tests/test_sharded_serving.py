"""Mesh-sharded serving conformance: the tensor-parallel ServeEngine.

Three layers of coverage:

1. Device-count-independent unit tests for the sharding seeds —
   distributed.sharding.spec_for_axes (divisibility fallback, one-mesh-
   axis-per-tensor, rule-order precedence, all via AbstractMesh so no
   real devices are needed) and launch.mesh (make_host_mesh ValueError,
   mesh_or_none never building a trivial mesh). These always run, tier-1
   included.

2. The serving contract on a forced-multi-device CPU mesh: TP=2 and TP=4
   emit tokens bit-identical to the TP=1 single-device engine — greedy +
   seeded sampling mixed in one batch, GQA + MLA, dense + paged +
   paged-pallas, chunked + unchunked prefill — and decode stays exactly
   ONE dispatch per step regardless of tp. Skipped below 4 devices; CI's
   sharded-conformance job runs with
   XLA_FLAGS=--xla_force_host_platform_device_count=8.

3. The collective schedule, asserted on the compiled decode HLO via
   launch.hlo_analysis.collective_bytes: exactly one all-gather per
   decode step at the logits/vocab boundary (the partitioner may realize
   it on the logits or on the vocab-sharded lm_head table — both are the
   single vocab-boundary gather), NO collective inside the attention
   datapath (no all-to-all / collective-permute / reduce-scatter, and
   every all-reduce is an activation-sized Megatron row-parallel
   projection reduce, orders of magnitude below any KV-sized tensor).

Like tests/test_serving.py this file honors REPRO_TEST_BACKEND so the
sharded lane composes with the per-backend conformance matrix.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as PS

from repro import configs
from repro import obs as repro_obs
from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

_SOFTMAX_BY_BACKEND = {None: "exact", "jnp": "cordic_fixed",
                       "pallas_interpret": "cordic_pallas"}
_BACKEND = os.environ.get("REPRO_TEST_BACKEND")

_NDEV = jax.device_count()
multi_device = pytest.mark.skipif(
    _NDEV < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


# ---------------------------------------------------------------------------
# 1a. spec_for_axes unit tests (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------
def _amesh(data=1, model=4):
    return AbstractMesh((("data", data), ("model", model)))


def test_spec_divisibility_fallback_replicates():
    # kv_heads=4 on a 16-way model axis: 4 % 16 != 0 -> that dim must
    # fall back to replicated instead of failing or splitting unevenly
    mesh = _amesh(model=16)
    spec = shd.spec_for_axes(("kv_heads", "embed"), (4, 64), mesh)
    assert spec == PS()
    # divisible case takes the axis
    spec = shd.spec_for_axes(("kv_heads", "embed"), (16, 64), mesh)
    assert spec == PS("model")


def test_spec_one_mesh_axis_per_tensor():
    # two logical axes both mapping to "model": only the first dim may
    # consume it (a mesh axis used twice in one PartitionSpec is illegal)
    mesh = _amesh(model=4)
    spec = shd.spec_for_axes(("heads", "mlp"), (8, 8), mesh)
    assert spec == PS("model")            # trailing None trimmed
    parts = tuple(spec) + (None,) * (2 - len(tuple(spec)))
    assert parts.count("model") == 1


def test_spec_rule_order_precedence():
    mesh = _amesh(model=4)
    # DEFAULT_RULES maps vocab->model and embed->None: position decides
    assert shd.spec_for_axes(("vocab", "embed"), (32, 8), mesh) == PS("model")
    assert shd.spec_for_axes(("embed", "vocab"), (8, 32), mesh) == \
        PS(None, "model")
    # a custom rule list can retarget a logical axis entirely
    rules = (("vocab", None), ("embed", "model"))
    assert shd.spec_for_axes(("vocab", "embed"), (32, 8), mesh,
                             rules=rules) == PS(None, "model")
    # unknown logical axes replicate
    assert shd.spec_for_axes(("nonesuch", None), (32, 8), mesh) == PS()


def test_kv_cache_shardings_shapes():
    # paged pool leaves shard dim -2 (the KH axis); tables/lens replicate
    mesh = _amesh(model=2)
    cache = {
        "k_pool": jax.ShapeDtypeStruct((9, 8, 4, 16), jnp.float32),
        "v_pool": jax.ShapeDtypeStruct((9, 8, 4, 16), jnp.float32),
        "tables": jax.ShapeDtypeStruct((4, 8), jnp.int32),
        "lens": jax.ShapeDtypeStruct((4,), jnp.int32),
        "c_kv_pool": jax.ShapeDtypeStruct((9, 8, 32), jnp.float32),
    }
    sh = shd.kv_cache_shardings(cache, mesh)
    assert sh["k_pool"].spec == PS(None, None, "model")
    assert sh["v_pool"].spec == PS(None, None, "model")
    assert sh["tables"].spec == PS()
    assert sh["lens"].spec == PS()
    assert sh["c_kv_pool"].spec == PS()     # MLA latent: head-less
    # non-divisible KH falls back to replicated, tokens still correct
    sh = shd.kv_cache_shardings(
        {"k_pool": jax.ShapeDtypeStruct((9, 8, 3, 16), jnp.float32)},
        mesh)
    assert sh["k_pool"].spec == PS()


# ---------------------------------------------------------------------------
# 1b. launch.mesh satellites
# ---------------------------------------------------------------------------
def test_make_host_mesh_raises_value_error():
    bad = _NDEV + 1 if _NDEV > 1 else 3   # never divides device_count
    with pytest.raises(ValueError, match=str(_NDEV)):
        mesh_lib.make_host_mesh(bad)
    with pytest.raises(ValueError):
        mesh_lib.make_host_mesh(0)


def test_mesh_or_none_single_device_is_none():
    assert mesh_lib.mesh_or_none(1) is None
    assert mesh_lib.mesh_or_none(None) is None


@multi_device
def test_mesh_or_none_builds_model_axis():
    mesh = mesh_lib.mesh_or_none(2)
    assert mesh is not None
    assert mesh.shape["model"] == 2
    assert mesh.shape["data"] == _NDEV // 2
    assert mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# 2. Token bit-identity per shard count
# ---------------------------------------------------------------------------
def _gqa_cfg():
    # KH=4 so pallas head-sharding divides at tp=2 and tp=4
    return ModelConfig(
        name="tp-gqa", family="dense", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=4, d_ff=192, vocab_size=512,
        rope_theta=1e4, dtype="float32",
        softmax_impl=_SOFTMAX_BY_BACKEND[_BACKEND])


def _mla_cfg():
    cfg = configs.get_smoke("deepseek-v2-lite-16b", act_impl="exact")
    return dataclasses.replace(cfg, input_mode="tokens",
                               softmax_impl=_SOFTMAX_BY_BACKEND[_BACKEND])


_PARAMS_CACHE = {}


def _params_for(kind):
    if kind not in _PARAMS_CACHE:
        cfg = _gqa_cfg() if kind == "gqa" else _mla_cfg()
        _PARAMS_CACHE[kind] = (cfg, tf.init(cfg, jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[kind]


def _serve(cfg, params, *, tp, kv_impl, pai, chunk, obs=None):
    """Serve a fixed 6-request trace (greedy + seeded sampling mixed in
    one batch, prompt lengths spanning several buckets) and return the
    emitted token lists in rid order."""
    eng = ServeEngine(cfg, params, slots=3, max_len=64, seed=0,
                      kv_impl=kv_impl, block_len=8, paged_attend_impl=pai,
                      prefill_chunk=chunk, tp=tp, obs=obs)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(4, 40))
        samp = (SamplingParams(greedy=True) if i % 2 == 0
                else SamplingParams(temperature=0.7, top_k=6))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=8, sampling=samp))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in reqs)
    return [r.out for r in sorted(reqs, key=lambda r: r.rid)], eng


@multi_device
@pytest.mark.parametrize("arch", ["gqa", "mla"])
@pytest.mark.parametrize("kv_impl,pai", [
    ("dense", "gather"), ("paged", "gather"), ("paged", "pallas")])
@pytest.mark.parametrize("chunk", [None, 16])
def test_tokens_bit_identical_per_tp(arch, kv_impl, pai, chunk):
    cfg, params = _params_for(arch)
    base, _ = _serve(cfg, params, tp=1, kv_impl=kv_impl, pai=pai,
                     chunk=chunk)
    assert any(len(o) > 1 for o in base)
    for tp in (2, 4):
        got, eng = _serve(cfg, params, tp=tp, kv_impl=kv_impl, pai=pai,
                          chunk=chunk)
        assert eng.tp == tp
        assert got == base, f"tp={tp} tokens diverged from tp=1"


def _serve_shared_prefix(cfg, params, *, tp, pai, prefix):
    """Six requests sharing a 3-block system prompt (greedy + seeded
    sampling mixed); with 3 slots the second wave admits after the first
    completes, so cache-on runs always exercise radix hits."""
    eng = ServeEngine(cfg, params, slots=3, max_len=64, seed=0,
                      kv_impl="paged", block_len=8, paged_attend_impl=pai,
                      prefix_cache=prefix, tp=tp)
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    reqs = []
    for i in range(6):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 13))).astype(np.int32)
        samp = (SamplingParams(greedy=True) if i % 2 == 0
                else SamplingParams(temperature=0.7, top_k=6))
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([sys_prompt, tail]),
                            max_new_tokens=8, sampling=samp))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in reqs)
    return [r.out for r in sorted(reqs, key=lambda r: r.rid)], eng


@multi_device
@pytest.mark.parametrize("pai", ["gather", "pallas"])
def test_prefix_cache_bit_identical_per_tp(pai):
    """The refcounted pager + radix prefix cache are host-side metadata
    with one logical block id space, so block sharing must be invisible
    to sharding: cache-on tokens == cache-off tokens at every tp, and
    == the unsharded cache-on run."""
    cfg, params = _params_for("gqa")
    base, _ = _serve_shared_prefix(cfg, params, tp=1, pai=pai,
                                   prefix=False)
    assert any(len(o) > 1 for o in base)
    for tp in (1, 2, 4):
        got, eng = _serve_shared_prefix(cfg, params, tp=tp, pai=pai,
                                        prefix=True)
        assert eng.prefix is not None and eng.prefix.hits >= 1, \
            f"tp={tp}: trace never hit the radix index"
        assert eng.prefix.hit_blocks >= 1
        assert got == base, f"tp={tp} cache-on tokens diverged"


@multi_device
def test_prefix_shared_blocks_are_shard_local_slices():
    """A shared pool block is one logical id; every shard holds a
    head-slice of it. After a cache hit the lender's and borrower's
    tables reference the same ids — refcounts > 1 on the shared blocks —
    while the pool leaves stay sharded on the kv-heads dim."""
    cfg, params = _params_for("gqa")
    _, eng = _serve_shared_prefix(cfg, params, tp=2, pai="gather",
                                  prefix=True)
    assert eng.prefix.hit_blocks >= 3   # the 3-block system prompt reused
    # every finished slot dropped its references; the survivors are
    # exactly the radix index's blocks (>= the 3 system-prompt blocks),
    # each held by its single cache reference
    assert eng.pager.blocks_in_use == eng.prefix.num_blocks >= 3
    assert eng.pager.blocks_shared == 0


@multi_device
def test_decode_stays_one_dispatch_per_step():
    cfg, params = _params_for("gqa")
    eng = ServeEngine(cfg, params, slots=3, max_len=64, seed=0,
                      kv_impl="paged", block_len=8,
                      paged_attend_impl="pallas", tp=2)
    calls = []
    inner = eng._decode
    eng._decode = lambda *a: (calls.append(1), inner(*a))[1]
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=6))
    decode_steps = 0
    while True:
        n = eng.step()
        if n == 0:
            break
        if len(calls) > decode_steps:
            decode_steps += 1
            assert len(calls) == decode_steps    # exactly one per step
    assert eng.compile_counts()["decode"] <= 2


@multi_device
def test_sharded_pool_layout_and_gauges():
    # the paged pool is physically head-parallel: each shard holds
    # (num_blocks, block_len, KH/tp, hd), and the mesh gauges + the
    # per-step collective span land in the metrics snapshot
    cfg, params = _params_for("gqa")
    ob = repro_obs.Observability()
    _, eng = _serve(cfg, params, tp=2, kv_impl="paged", pai="pallas",
                    chunk=None, obs=ob)
    pool = eng._caches["seg0"]["k_pool"]
    kh = pool.shape[-2]
    # ([layers,] N, L, KH, hd) sharded on the KH axis (dim -2); the
    # trailing-None trim leaves "model" as the spec's last entry
    assert tuple(pool.sharding.spec) == (None,) * (pool.ndim - 2) + ("model",)
    shard_shapes = {s.data.shape for s in pool.addressable_shards}
    assert shard_shapes == {pool.shape[:-2] + (kh // 2, pool.shape[-1])}
    assert ob.metrics.get("engine.mesh.tp").last == 2
    assert ob.metrics.get("engine.mesh.devices").last == _NDEV
    assert ob.metrics.get("engine.phase.collective_ms").count > 0


@multi_device
def test_score_matches_per_tp():
    cfg, params = _params_for("gqa")
    prompt = np.arange(1, 9, dtype=np.int32)
    eng1 = ServeEngine(cfg, params, slots=2, max_len=64, tp=1)
    eng2 = ServeEngine(cfg, params, slots=2, max_len=64, tp=2)
    s1, s2 = eng1.score(prompt), eng2.score(prompt)
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=2e-5)


@multi_device
def test_pallas_head_divisibility_enforced_at_init():
    cfg, params = _params_for("gqa")     # KH=4
    bad_tp = 8 if _NDEV >= 8 else 4
    kh = 4
    if kh % bad_tp == 0:
        pytest.skip("no non-dividing tp available at this device count")
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(cfg, params, slots=2, max_len=64, kv_impl="paged",
                    block_len=8, paged_attend_impl="pallas", tp=bad_tp)


# ---------------------------------------------------------------------------
# 3. Collective schedule on the compiled decode HLO
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("kv_impl,pai", [
    ("dense", "gather"), ("paged", "gather"), ("paged", "pallas")])
def test_decode_collective_schedule(kv_impl, pai):
    cfg, params = _params_for("gqa")
    slots = 3
    eng = ServeEngine(cfg, params, slots=slots, max_len=64, seed=0,
                      kv_impl=kv_impl, block_len=8, paged_attend_impl=pai,
                      tp=2)
    greedy_fn, _ = eng._decode_jits
    args = (eng.params, eng._caches,
            jnp.zeros((slots, 1), jnp.int32), jnp.zeros(slots, jnp.int32),
            jnp.zeros(slots, jnp.int32), jnp.ones(slots, jnp.float32),
            jnp.zeros(slots, jnp.int32), jnp.ones(slots, bool),
            eng._base_key)
    with shd.serving_mesh(eng.mesh):
        hlo = greedy_fn.lower(*args).compile().as_text()
    rep = hlo_analysis.collective_bytes(hlo)
    counts = rep["op_counts"]
    # exactly ONE all-gather per decode step, at the vocab boundary: the
    # partitioner realizes it either on the replicated logits
    # (slots*vocab*4) or on the vocab-sharded lm_head table
    # (vocab*d_model*4) — nothing else in the program is gatherable
    assert counts.get("all-gather", 0) == 1, counts
    vocab_boundary = {slots * cfg.vocab_size * 4.0,
                      cfg.vocab_size * cfg.d_model * 4.0}
    assert rep["per_kind_bytes"]["all-gather"] in vocab_boundary, rep
    # nothing reshards inside the datapath
    for kind in ("all-to-all", "collective-permute", "reduce-scatter"):
        assert counts.get(kind, 0) == 0, counts
    # all-reduces are the Megatron row-parallel projection reduces:
    # activation-sized (slots x d_model), orders of magnitude below any
    # KV/pool-sized tensor — i.e. no collective inside attention itself
    n_ar = counts.get("all-reduce", 0)
    if n_ar:
        per_op = rep["per_kind_bytes"]["all-reduce"] / n_ar
        assert per_op <= 4 * slots * cfg.d_model * 4, rep
