"""Host-side paged-KV unit tests: the bucket policy and the block
allocator (serve/kv_pager.py), plus the prefill tail-write trim (pool
write traffic for bucket-pad positions past the last real block).
Device-side decode behavior (pool writes, table gathers, bit-identity
with the dense path) lives in tests/test_serving.py."""
import numpy as np
import pytest

from repro.serve import kv_pager as kvp


# ---------------------------------------------------------------------------
# Bucket policy
# ---------------------------------------------------------------------------
def test_bucket_lengths_geometric_and_block_aligned():
    b = kvp.bucket_lengths(256, block_len=16)
    assert b == (16, 32, 64, 128, 256)
    assert all(x % 16 == 0 for x in b)
    assert b[-1] == 256
    assert b == tuple(sorted(b))


def test_bucket_lengths_non_power_of_two_max():
    b = kvp.bucket_lengths(96, block_len=16)
    assert b == (16, 32, 64, 96)
    assert all(x % 16 == 0 for x in b)


def test_bucket_lengths_small_max():
    assert kvp.bucket_lengths(8, block_len=4) == (8,)
    assert kvp.bucket_lengths(16, block_len=16) == (16,)


def test_bucket_lengths_block_len_above_min_bucket():
    b = kvp.bucket_lengths(256, block_len=64)
    assert b == (64, 128, 256)
    assert all(x % 64 == 0 for x in b)


def test_bucket_count_is_logarithmic():
    b = kvp.bucket_lengths(4096, block_len=16)
    assert len(b) <= 10          # 16..4096 doubling: 9 buckets
    assert b[-1] == 4096


def test_bucket_for_rounds_up():
    b = (16, 32, 64)
    assert kvp.bucket_for(1, b) == 16
    assert kvp.bucket_for(16, b) == 16
    assert kvp.bucket_for(17, b) == 32
    assert kvp.bucket_for(64, b) == 64
    with pytest.raises(ValueError, match="exceeds"):
        kvp.bucket_for(65, b)


def test_blocks_needed():
    assert kvp.blocks_needed(1, 16) == 1
    assert kvp.blocks_needed(16, 16) == 1
    assert kvp.blocks_needed(17, 16) == 2
    assert kvp.blocks_needed(64, 16) == 4


# ---------------------------------------------------------------------------
# KVPager allocator
# ---------------------------------------------------------------------------
def test_scratch_block_reserved():
    p = kvp.KVPager(num_blocks=5, block_len=16, slots=2)
    got = p.alloc(0, 4)
    assert got is not None
    assert kvp.SCRATCH_BLOCK not in got          # block 0 never handed out
    assert sorted(got) == [1, 2, 3, 4]
    assert p.blocks_free == 0


def test_alloc_free_roundtrip_and_stats():
    p = kvp.KVPager(num_blocks=9, block_len=16, slots=4)
    a = p.alloc(0, 3)
    b = p.alloc(1, 2)
    assert len(a) == 3 and len(b) == 2
    assert set(a).isdisjoint(b)
    assert p.blocks_in_use == 5
    assert p.owned(0) == tuple(a)
    assert p.free(0) == 3
    assert p.blocks_in_use == 2
    assert p.owned(0) == ()
    st = p.stats()
    assert st.peak_in_use == 5 and st.allocs == 2 and st.alloc_failures == 0
    assert st.blocks_free + st.blocks_in_use == st.num_blocks - 1


def test_alloc_is_all_or_nothing():
    p = kvp.KVPager(num_blocks=4, block_len=16, slots=2)
    assert p.alloc(0, 2) is not None
    assert p.alloc(1, 2) is None                 # only 1 left: holds nothing
    assert p.blocks_in_use == 2
    assert p.stats().alloc_failures == 1
    assert p.alloc(1, 1) is not None             # the 1 left still works


def test_double_alloc_same_slot_raises():
    p = kvp.KVPager(num_blocks=4, block_len=16, slots=2)
    p.alloc(0, 1)
    with pytest.raises(RuntimeError, match="already holds"):
        p.alloc(0, 1)


def test_free_vacant_slot_is_noop():
    p = kvp.KVPager(num_blocks=4, block_len=16, slots=2)
    assert p.free(1) == 0


def test_freed_blocks_are_reusable():
    p = kvp.KVPager(num_blocks=3, block_len=16, slots=1)
    first = p.alloc(0, 2)
    p.free(0)
    second = p.alloc(0, 2)
    assert sorted(first) == sorted(second)       # full reuse of the pool


# ---------------------------------------------------------------------------
# Prefill tail-write trim: bucket-pad positions past the last real block
# must not burn pool write traffic (their content is never read — pad keys
# are causally invisible to the last real position and decode overwrites
# pad positions before the length mask exposes them).
# ---------------------------------------------------------------------------
def _trim_engine(block_len=4, slots=1):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import transformer as tf
    from repro.serve.engine import ServeEngine

    cfg = configs.get_smoke("yi-9b", act_impl="exact")
    params = tf.init(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, slots=slots, max_len=64, kv_impl="paged",
                      block_len=block_len)
    return cfg, params, eng, jnp


def test_prefill_tail_writes_skipped():
    """Prompt len 5 in a 16-wide bucket at block_len=4: blocks 0-1 hold
    real positions (ceil(5/4) = 2), blocks 2-3 are pure bucket pad — the
    prefill must leave those pool blocks untouched (sentinel-flooded pool
    entries survive bit-exactly), proving the pad-tail write traffic is
    gone, while the scratch block absorbs the redirected writes."""
    import jax

    from repro.serve.engine import Request
    from repro.serve import kv_pager as kv

    cfg, params, eng, jnp = _trim_engine()
    sentinel = 7.75
    eng._caches = jax.tree_util.tree_map_with_path(
        lambda p, leaf: jnp.full_like(leaf, sentinel)
        if getattr(p[-1], "key", "").endswith("_pool") else leaf,
        eng._caches)
    eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32) + 1,
                       max_new_tokens=4))
    eng._prefill_phase()
    owned = eng.pager.owned(0)
    assert len(owned) >= 4                       # 16-pos bucket + decode room

    def pool_views(leaf):
        # stacked segments carry leading layer axes before the block axis
        if leaf.shape[0] == eng.pager.num_blocks:
            yield leaf
        else:
            for sub in leaf:
                yield from pool_views(sub)

    pools = [v for p, leaf in
             jax.tree_util.tree_flatten_with_path(eng._caches)[0]
             if getattr(p[-1], "key", "").endswith("_pool")
             for v in pool_views(np.asarray(leaf))]
    assert pools
    for pool in pools:
        # real blocks written, tail blocks still wall-to-wall sentinel
        assert not (pool[list(owned[:2])] == sentinel).all()
        assert (pool[list(owned[2:4])] == sentinel).all()
        # the redirected pad writes landed in scratch block 0
        assert not (pool[kv.SCRATCH_BLOCK] == sentinel).all()


def test_prefill_tail_trim_does_not_change_tokens():
    """No output change: a trimmed paged engine emits the same stream as
    the dense engine for a prompt whose bucket has a pad tail."""
    import jax

    from repro.serve.engine import Request

    cfg, params, eng, jnp = _trim_engine()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 5)

    def serve(kv_impl):
        from repro.serve.engine import ServeEngine

        e = ServeEngine(cfg, params, slots=1, max_len=64, kv_impl=kv_impl,
                        block_len=4)
        r = Request(rid=0, prompt=prompt, max_new_tokens=8)
        e.submit(r)
        e.run()
        return r.out

    assert serve("paged") == serve("dense")
