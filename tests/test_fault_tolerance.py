"""Fault-tolerance integration: failure-injected training restarts from
checkpoints and reproduces the non-failing run bitwise; straggler detection
flags injected delays; elastic restore re-shards onto a different mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import manager as ckpt
from repro.distributed.fault_tolerance import (FailureInjector,
                                               HeartbeatMonitor,
                                               StragglerDetector)
from repro.train import loop as loop_lib
from repro.train import step as step_lib
from repro.optim import adamw


def _tiny_cfg():
    return configs.get_smoke("internvl2-1b", act_impl="exact")


def test_restart_reproduces_clean_run(tmp_path):
    cfg = _tiny_cfg()
    # token-mode tiny config for the loop
    import dataclasses

    cfg = dataclasses.replace(cfg, input_mode="tokens")
    lc = loop_lib.LoopConfig(total_steps=12, ckpt_every=4,
                             ckpt_dir=str(tmp_path / "clean"), log_every=100)
    clean = loop_lib.run(cfg, lc, log=lambda *_: None)

    lc2 = loop_lib.LoopConfig(total_steps=12, ckpt_every=4,
                              ckpt_dir=str(tmp_path / "faulty"), log_every=100)
    inj = FailureInjector(fail_at_steps=[6, 9])
    faulty = loop_lib.run(cfg, lc2, injector=inj, log=lambda *_: None)

    assert faulty["restarts"] == 2
    # the final loss must match the clean run exactly (deterministic replay)
    assert clean["final_loss"] == pytest.approx(faulty["final_loss"], rel=1e-6)


def test_loss_decreases(tmp_path):
    import dataclasses

    cfg = dataclasses.replace(_tiny_cfg(), input_mode="tokens")
    lc = loop_lib.LoopConfig(total_steps=30, ckpt_every=100,
                             ckpt_dir=str(tmp_path), log_every=100)
    out = loop_lib.run(cfg, lc, log=lambda *_: None)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(warmup=5, threshold=3.0)
    flagged = []
    for i in range(50):
        dt = 0.1 + 0.001 * (i % 3)
        if i == 30:
            dt = 1.5
        if det.observe(i, dt):
            flagged.append(i)
    assert flagged == [30]
    assert det.events[0]["step"] == 30


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=10.0, clock=lambda: t[0])
    mon.beat("host0")
    mon.beat("host1")
    t[0] = 5.0
    mon.beat("host0")
    t[0] = 12.0
    assert mon.dead() == ["host1"]


def test_elastic_restore_reshards(tmp_path):
    """Checkpoint saved unsharded restores onto a 2-device mesh sharded."""
    from jax.sharding import NamedSharding, PartitionSpec as PS

    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, state)

    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, PS("data", None))}
    like = {"w": jnp.zeros((4, 4), jnp.float32)}
    rest, _ = ckpt.restore(str(tmp_path), 1, like, shardings=sh)
    assert rest["w"].sharding.spec == PS("data", None)
    np.testing.assert_array_equal(np.asarray(rest["w"]), np.asarray(state["w"]))
