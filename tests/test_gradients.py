"""Gradient conformance for every custom_jvp/custom_vjp rule.

The quantized forwards are step functions, so ``check_grads``-style
numerical differencing of the primal is meaningless; instead each rule is
checked against ``jax.grad`` of the *float reference* (jax.nn / jnp
transcendental), first AND second order, including the tails near the
convergence boundaries where the paper's range normalization matters.

Covered rules:
  * activation-registry wrappers (sigmoid/tanh + engine kinds, all impls)
  * kernels.ops custom_jvp ops (sigmoid/sigmoid_wide/tanh/silu/silu_mul,
    exp/log/softplus/elu/gelu_erf, softmax/log_softmax)
  * cordic_engine.functions.softmax / log_softmax custom_jvp
  * train.losses.token_nll custom_vjp (analytic softmax - onehot backward)

CI runs this file once per backend via REPRO_TEST_BACKEND in
{"jnp", "pallas_interpret"}; unset, both run.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import get_activation
from repro.cordic_engine import functions as F
from repro.train import losses

_ALL = ("jnp", "pallas_interpret")
_SEL = os.environ.get("REPRO_TEST_BACKEND")
BACKENDS = [b for b in _ALL if _SEL in (None, b)]

#: impl selected per backend for registry / loss dispatch.
_IMPL = {"jnp": "cordic_fixed", "pallas_interpret": "cordic_pallas"}
_LOSS_IMPL = {"jnp": "cordic", "pallas_interpret": "cordic_pallas"}


def _grad1(f, x):
    return np.asarray(jax.vmap(jax.grad(f))(x), np.float64)


def _grad2(f, x):
    return np.asarray(jax.vmap(jax.grad(jax.grad(f)))(x), np.float64)


# ---------------------------------------------------------------------------
# Unary activation kinds: first- and second-order vs the float reference
# ---------------------------------------------------------------------------
_REFS = {
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "silu": jax.nn.silu,
    "exp": jnp.exp,
    "softplus": jax.nn.softplus,
    "elu": jax.nn.elu,
    "gelu_erf": lambda x: jax.nn.gelu(x, approximate=False),
}

#: interior test points (well inside every kind's reduced domain)
_X_IN = jnp.linspace(-2.5, 2.5, 41)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", sorted(_REFS))
def test_activation_grad_first_order(kind, backend):
    act = get_activation(kind, _IMPL[backend])
    got = _grad1(act, _X_IN)
    want = _grad1(_REFS[kind], _X_IN)
    assert np.abs(got - want).max() < 1e-2, kind


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", sorted(_REFS))
def test_activation_grad_second_order(kind, backend):
    """grad-of-grad flows through the output-derived jvp rules analytically."""
    act = get_activation(kind, _IMPL[backend])
    x = jnp.linspace(-2.0, 2.0, 17)
    got = _grad2(act, x)
    want = _grad2(_REFS[kind], x)
    assert np.abs(got - want).max() < 3e-2, kind
    assert np.isfinite(got).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_sigmoid_tails_range_normalized(backend):
    """|x| in [5, 7.9]: the dyadic range extension keeps sigma' accurate
    where the clamped paper domain would flatline."""
    act = get_activation("sigmoid", _IMPL[backend], range_mode="reduce")
    x = jnp.concatenate([jnp.linspace(-7.9, -5.0, 16), jnp.linspace(5.0, 7.9, 16)])
    got = _grad1(act, x)
    want = _grad1(jax.nn.sigmoid, x)
    # derivative magnitude out here is <= 6.7e-3; match to ~1e-3 abs
    assert np.abs(got - want).max() < 1.5e-3


@pytest.mark.parametrize("backend", BACKENDS)
def test_exp_tail_relative_grad(backend):
    """Near the dyadic-reduction seam ln2/2 and at large |x|, exp' = exp
    must hold in relative terms (the 2^k scale is exact)."""
    act = get_activation("exp", _IMPL[backend])
    seam = 0.5 * float(np.log(2.0))
    x = jnp.asarray([-20.0, -4.0, -seam - 1e-3, -seam + 1e-3, seam - 1e-3,
                     seam + 1e-3, 4.0, 20.0], jnp.float32)
    got = _grad1(act, x)
    want = np.exp(np.asarray(x, np.float64))
    assert (np.abs(got / want - 1.0)).max() < 5e-3


def test_tanh_convergence_boundary():
    """tanh at |z| -> 0.5 (the R2-HRC convergence edge, paper eq. (5))."""
    act = get_activation("tanh", "cordic_fixed", range_mode="clamp")
    z = jnp.linspace(0.46, 0.499, 12)
    got = _grad1(act, z)
    want = _grad1(jnp.tanh, z)
    assert np.abs(got - want).max() < 1e-2


# ---------------------------------------------------------------------------
# softmax / log_softmax custom_jvp
# ---------------------------------------------------------------------------
def _row_logits(shape=(6, 97), seed=0, scale=4.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


@pytest.mark.parametrize("backend", BACKENDS)
def test_log_softmax_grad(backend):
    x = _row_logits()
    w = jax.random.normal(jax.random.PRNGKey(1), x.shape)
    fn = F.log_softmax if backend == "jnp" else __import__(
        "repro.kernels.ops", fromlist=["ops"]).log_softmax
    g = jax.grad(lambda v: jnp.sum(fn(v) * w))(x)
    g_ref = jax.grad(lambda v: jnp.sum(jax.nn.log_softmax(v) * w))(x)
    assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() < 2e-2


def test_softmax_second_order():
    x = _row_logits((3, 33), seed=2, scale=2.0)
    w = jax.random.normal(jax.random.PRNGKey(3), x.shape)

    def scalar(fn):
        return lambda v: jnp.sum(fn(v) * w)

    h = jax.grad(lambda v: jnp.sum(jax.grad(scalar(F.softmax))(v) * w))(x)
    h_ref = jax.grad(lambda v: jnp.sum(jax.grad(scalar(jax.nn.softmax))(v) * w))(x)
    assert np.abs(np.asarray(h) - np.asarray(h_ref)).max() < 5e-2


# ---------------------------------------------------------------------------
# token_nll custom_vjp (the training loss)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_token_nll_grad_matches_exact(backend):
    impl = _LOSS_IMPL[backend]
    logits = _row_logits((2, 9, 61), seed=4, scale=3.0)
    labels = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0, 61)

    def loss(l, i):
        return jnp.mean(losses.token_nll(l, labels, i))

    v, g = jax.value_and_grad(lambda l: loss(l, impl))(logits)
    v_ref, g_ref = jax.value_and_grad(lambda l: loss(l, "exact"))(logits)
    assert abs(float(v) - float(v_ref)) / float(v_ref) < 1e-3
    assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() < 1e-4


@pytest.mark.parametrize("backend", BACKENDS)
def test_token_nll_backward_is_softmax_minus_onehot(backend):
    """The vjp must be exactly g * (exp(primal logp) - onehot)."""
    impl = _LOSS_IMPL[backend]
    logits = _row_logits((4, 23), seed=6, scale=3.0)
    labels = jax.random.randint(jax.random.PRNGKey(7), (4,), 0, 23)
    g = jax.random.normal(jax.random.PRNGKey(8), (4,))

    _, vjp = jax.vjp(lambda l: losses.token_nll(l, labels, impl), logits)
    (dlogits,) = vjp(g)
    logp = losses.log_softmax_fn(impl)(logits)
    onehot = jax.nn.one_hot(labels, 23)
    want = g[..., None] * (jnp.exp(logp) - onehot)
    np.testing.assert_allclose(np.asarray(dlogits), np.asarray(want),
                               atol=1e-6, rtol=1e-5)


def test_token_nll_second_order_and_jit():
    logits = _row_logits((3, 17), seed=9, scale=2.0)
    labels = jax.random.randint(jax.random.PRNGKey(10), (3,), 0, 17)

    def loss(l):
        return jnp.mean(losses.token_nll(l, labels, "cordic"))

    h = jax.jit(jax.grad(lambda l: jnp.sum(jax.grad(loss)(l) ** 2)))(logits)
    h_ref = jax.grad(lambda l: jnp.sum(jax.grad(
        lambda v: jnp.mean(losses.token_nll(v, labels, "exact")))(l) ** 2))(logits)
    assert np.isfinite(np.asarray(h)).all()
    assert np.abs(np.asarray(h) - np.asarray(h_ref)).max() < 1e-3


def test_cross_entropy_masking():
    logits = _row_logits((2, 5, 11), seed=11)
    labels = jnp.zeros((2, 5), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0, 0], [1, 1, 1, 1, 0]], jnp.float32)
    got = losses.cross_entropy(logits, labels, mask, impl="cordic")
    nll = losses.token_nll(logits, labels, "cordic")
    want = float(jnp.sum(nll * mask) / 6.0)
    assert float(got) == pytest.approx(want, rel=1e-6)


# ---------------------------------------------------------------------------
# End-to-end: 20-step training trajectory parity (acceptance criterion)
# ---------------------------------------------------------------------------
def _tiny_cfg(loss_impl):
    from repro.configs.base import ModelConfig

    return ModelConfig(name="grad-tiny", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=256, act_impl="exact", loss_impl=loss_impl,
                       rope_theta=1e4, dtype="float32")


def _run_tiny(loss_impl, steps=20):
    from repro.data.pipeline import DataConfig, SyntheticLMDataset
    from repro.optim import adamw
    from repro.train import step as step_lib

    cfg = _tiny_cfg(loss_impl)
    ds = SyntheticLMDataset(DataConfig(vocab_size=256, seq_len=32,
                                       global_batch=4, seed=0))
    opt = adamw.AdamWConfig(lr=1e-2)
    state = step_lib.init_state(cfg, jax.random.PRNGKey(0), opt)
    train = jax.jit(step_lib.make_train_step(cfg, opt, warmup_steps=2,
                                             total_steps=steps))
    # overfit one fixed batch: guarantees visible loss descent in 20 steps,
    # which is what makes trajectory *divergence* between impls detectable
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    out = []
    for _ in range(steps):
        state, m = train(state, batch)
        out.append(float(m["loss"]))
    return out


def test_training_trajectory_parity_cordic_loss():
    """cfg.loss_impl="cordic" must track the jax.nn baseline within 2%
    over 20 steps (the PR acceptance criterion, on a CPU-sized model)."""
    ref = _run_tiny("exact")
    got = _run_tiny("cordic")
    rel = [abs(a - b) / abs(b) for a, b in zip(got, ref)]
    assert max(rel) < 0.02, (max(rel), got[-1], ref[-1])
    # and training actually made progress
    assert got[-1] < got[0]
