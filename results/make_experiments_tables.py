"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results JSONs.

    PYTHONPATH=src python results/make_experiments_tables.py
"""
import glob
import json
import sys

GB = 1024 ** 3
ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load():
    recs = []
    for p in sorted(glob.glob("results/dryrun_*.json")):
        recs.extend(json.load(open(p)))
    return recs


def dryrun_table(recs):
    print("### Dry-run matrix (status / compile time / per-device arg bytes / "
          "collective mix)\n")
    print("| arch | shape | 16x16 | 2x16x16 | args GiB/dev (1-pod) | "
          "collectives per step (1-pod, corrected) |")
    print("|---|---|---|---|---|---|")
    by = {}
    for r in recs:
        by.setdefault((r["arch"], r["shape"]), {})[bool(r.get("multi_pod"))] = r
    for (arch, shape), d in sorted(by.items(), key=lambda kv: (kv[0][0], ORDER[kv[0][1]])):
        sp = d.get(False, {})
        mp = d.get(True, {})

        def cell(r):
            if not r:
                return "—"
            if r["status"] == "ok":
                return f"OK ({r['compile_s']:.0f}s)"
            if r["status"] == "skipped":
                return "skip"
            return "ERROR"

        args = "—"
        colls = "—"
        if sp.get("status") == "ok":
            ma = sp.get("memory_analysis", {})
            if "argument_size_in_bytes" in ma:
                # memory_analysis on the CPU backend reports whole-module
                # argument bytes; per-device = /devices
                args = f"{ma['argument_size_in_bytes'] / sp['devices'] / GB:.2f}"
            cc = sp["collective"]["op_counts"]
            colls = ", ".join(f"{k}x{int(v)}" for k, v in sorted(cc.items())) or "none"
        print(f"| {arch} | {shape} | {cell(sp)} | {cell(mp)} | {args} | {colls} |")
    print()
    skips = [r for r in recs if r["status"] == "skipped" and not r.get("multi_pod")]
    for r in sorted(skips, key=lambda r: r["arch"]):
        print(f"* skip: **{r['arch']} × {r['shape']}** — {r['reason']}")


if __name__ == "__main__":
    recs = load()
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    print(f"<!-- {n_ok} ok / {n_skip} skipped / {n_err} errors -->\n")
    dryrun_table(recs)
