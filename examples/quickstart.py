"""Quickstart: the paper's MR-HRC CORDIC sigmoid in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Evaluates sigmoid with the bit-accurate 16-bit Q2.14 pipeline and prints
   the paper-comparison accuracy table (Table 2 reproduction).
2. Shows the convergence arithmetic of Sec. 3.1 (ranges / residuals).
3. Runs the Pallas TPU kernel (interpret mode on CPU) and verifies it is
   bit-identical to the oracle.
4. Uses the activation through the registry inside a tiny SwiGLU MLP with
   gradients flowing through the quantized forward.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cordic as C
from repro.core import sigmoid as S
from repro.core.activations import get_activation
from repro.core.errors import error_stats

print("=" * 72)
print("1) 16-bit Q2.14 MR-HRC sigmoid vs exact, x in [-1, 1]")
print("=" * 72)
for name in ("proposed_mr_hrc_q2.14", "r2_cordic_q2.14 [9]", "pwl_8seg [11]",
             "lut_256 [10]", "mr_hrc_float (algorithmic)"):
    st = error_stats(jax.jit(S.TABLE2_METHODS[name]), S.sigmoid_exact, -1, 1)
    print(f"  {name:32s} MAE={st['mae']:.3e}  max={st['max']:.3e}")
print(f"  paper reports MAE 4.23e-4 for the proposed design; ours is better "
      f"(full 14-iter LVC) and matches the paper at LVC j<=9.")

print()
print("=" * 72)
print("2) Convergence arithmetic (paper Sec. 3.1)")
print("=" * 72)
s = C.PAPER_SCHEDULE
print(f"  radix-2 range  sum atanh(2^-j), j=2..9  = {s.r2_range:.6f} (>= 0.5)")
z = jnp.linspace(-0.5, 0.5, 50001, dtype=jnp.float32)
print(f"  radix-2 stage worst residual            = "
      f"{float(jnp.max(C.r2_residual_f(z))):.6f} (paper: 0.0061)")
print(f"  radix-4 admissible start range (j=4..7) = {s.r4_range:.6f} "
      f"(paper: 0.0104)")
lo, hi = s.r4_gain_bounds
print(f"  radix-4 cumulative gain in [{lo:.8f}, {hi:.1f}]  -> scale-free at "
      f"16 bits (1-gain < 2^-14)")
print(f"  K_h = {s.r2_gain:.6f}; x0 = 1/K_h = {s.x0:.6f} (absorbed, free)")

print()
print("=" * 72)
print("3) Pallas TPU kernel (interpret on CPU) — bit-exact vs oracle")
print("=" * 72)
from repro.kernels import ops, ref

x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, (8, 512)), jnp.float32)
got = ops.sigmoid(x)
want = ref.sigmoid_ref(x)
same = np.array_equal(np.round(np.asarray(got) * 2 ** 14),
                      np.round(np.asarray(want) * 2 ** 14))
print(f"  kernel vs pure-jnp Q2.14 oracle on (8,512): bit-identical = {same}")

print()
print("=" * 72)
print("4) Training through the quantized activation (custom_jvp)")
print("=" * 72)
silu = get_activation("silu", "cordic_fixed", range_mode="reduce")
w = jnp.asarray(np.random.default_rng(1).normal(0, 0.5, (16, 16)), jnp.float32)


def loss(w):
    h = silu(x[:, :16] @ w)
    return jnp.mean(jnp.square(h - 0.25))


g = jax.grad(loss)(w)
print(f"  loss={float(loss(w)):.5f}  |grad|={float(jnp.abs(g).mean()):.5f} "
      f"(finite: {bool(np.isfinite(np.asarray(g)).all())})")
print("\nDone. See examples/train_lm.py for the end-to-end LM training driver.")
