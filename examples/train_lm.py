"""End-to-end training driver: a ~100M-param LM with CORDIC activations.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--act cordic_fixed]

Builds a 12-layer/512-wide llama-style model (~100M params with the 32k
vocab), trains it on the deterministic synthetic corpus for a few hundred
steps with the full production stack — AdamW + cosine schedule, microbatch
accumulation, async checkpointing, straggler detection — and prints the
loss curve. The SwiGLU gates run through the paper's Q2.14 MR-HRC pipeline
(act_impl=cordic_fixed) by default; pass --act exact to compare curves.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataIterator, SyntheticLMDataset
from repro.checkpoint import manager as ckpt
from repro.distributed.fault_tolerance import StragglerDetector
from repro.optim import adamw
from repro.train import step as step_lib


def build_cfg(act_impl: str, loss_impl: str = "exact",
              small: bool = False) -> ModelConfig:
    if small:
        # CI/acceptance config: ~20-step CPU runs through the CORDIC loss
        return ModelConfig(
            name="train-demo-small", family="dense",
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=512, vocab_size=2048, act_impl=act_impl,
            loss_impl=loss_impl, rope_theta=1e4, dtype="float32",
        )
    return ModelConfig(
        name="train-demo-100m", family="dense",
        num_layers=16, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=2048, vocab_size=32768, act_impl=act_impl,
        loss_impl=loss_impl, rope_theta=1e4, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--data-vocab", type=int, default=2048,
                    help="synthetic stream uses a subset of the model vocab "
                         "so structure is learnable within a CPU-budget run")
    ap.add_argument("--act", default="cordic_fixed",
                    choices=["exact", "cordic_float", "cordic_fixed", "cordic_pallas"])
    ap.add_argument("--loss", default="exact",
                    choices=["exact", "cordic", "cordic_pallas"],
                    help="cross-entropy log-softmax datapath (cfg.loss_impl)")
    ap.add_argument("--small", action="store_true",
                    help="2-layer/128-wide config for quick CPU parity runs")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg(args.act, args.loss, small=args.small)
    n_params = cfg.param_counts()["total"]
    print(f"[train_lm] model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"act_impl={cfg.act_impl}, loss_impl={cfg.loss_impl}")

    data_cfg = DataConfig(vocab_size=args.data_vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=42)
    it = DataIterator(SyntheticLMDataset(data_cfg))

    opt_cfg = adamw.AdamWConfig(lr=3e-4, weight_decay=0.01)
    state = step_lib.init_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    train_step = jax.jit(step_lib.make_train_step(
        cfg, opt_cfg, accum=args.accum, warmup_steps=args.steps // 10,
        total_steps=args.steps), donate_argnums=(0,))

    saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
    det = StragglerDetector()
    losses = []
    t_start = time.time()
    for step in range(args.steps):
        batch_np = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        det.observe(step, dt)
        losses.append(loss)
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(f"  step {step:4d}  loss {loss:7.4f}  "
                  f"gnorm {float(metrics['grad_norm']):6.3f}  "
                  f"{dt * 1e3:6.0f} ms  {tok_s / 1e3:5.1f}k tok/s")
        if (step + 1) % 100 == 0:
            saver.save(step + 1, state, extra={"data_step": it.state()["step"]})
    saver.wait()

    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    uniform = float(np.log(args.data_vocab))
    print(f"[train_lm] loss: first10={first:.3f} last10={last:.3f} "
          f"(uniform={uniform:.2f}); wall={time.time() - t_start:.0f}s; "
          f"stragglers={len(det.events)}")
    assert last < first, "training did not reduce loss"
    print("[train_lm] OK — loss decreased through the CORDIC activation path.")


if __name__ == "__main__":
    main()
