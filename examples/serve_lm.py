"""Batched serving demo: continuous batching with CORDIC activations.

    PYTHONPATH=src python examples/serve_lm.py [--requests 8] [--slots 4] \
        [--temperature 0.8] [--top-k 40]

Loads a small GQA LM (optionally from a train_lm.py checkpoint), submits a
queue of prompt requests, and serves them through the slot-based engine:
bucket-padded prefill per admission (compiles bounded by the bucket count,
not by distinct prompt lengths), then one jitted decode call per engine
step for all slots at once — slots refilled from the queue as requests
finish. ``--kv-impl paged`` swaps the per-slot dense caches for a global
block pool with per-slot block tables (serve/kv_pager.py); emitted tokens
are bit-identical either way. ``--paged-attend-impl pallas`` additionally
swaps the paged decode's full-table gather for the block-walking Pallas
kernel (kernels/paged_attention.py): each slot walks only its *live* KV
blocks — one block in VMEM per grid step, online softmax in f32 scratch —
so the per-step transient working set no longer scales with max_len, and
the emitted tokens are unchanged. ``--prefill-chunk`` turns on the
iteration-level scheduler's chunked prefill (serve/scheduler.py): long
prompts stream in as block-aligned chunks interleaved with decode steps,
so short requests' TTFT stays flat behind a long prompt — emitted tokens
still bit-identical. Sampling runs on the CORDIC datapath
too: temperature scaling is the linear-rotation multiply by the R2-LVC
reciprocal of T, with per-request temperature/top-k/greedy mixes in the
same batch. All sigmoid-family gates run the Q2.14 MR-HRC pipeline.
``--prefix-cache`` (paged only) turns on the radix-tree prompt-prefix
cache: the demo shares a system prompt across requests, so later
admissions bind the earlier request's KV blocks (refcounted, shared)
and resume prefill at the first uncached block — same tokens, fewer
prefill FLOPs and pool blocks (``--prefix-eviction lru|fifo`` picks
the reclaim order under pool pressure).
``--tp N`` shards the engine tensor-parallel over the mesh's ``model``
axis (params Megatron-style, the paged KV pool on its kv-heads dim); N
must divide the visible device count — on CPU force devices first, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=2 ... --tp 2`` — and
emitted tokens stay bit-identical to the unsharded engine.
``--metrics-json``/``--trace-out`` attach the repro.obs observability
layer: TTFT/TPOT/e2e latency histograms with p50/p99 readout, queue and
pool gauges, and a Chrome-trace (Perfetto-loadable) request-lifecycle
timeline — emitted tokens are bit-identical with or without it.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import obs as repro_obs
from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--act", default="cordic_fixed")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy (default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filtering; 0 = full vocab")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-impl", default="dense", choices=["dense", "paged"],
                    help="decode KV layout: dense per-slot buffers or the "
                         "paged global block pool (bit-identical tokens)")
    ap.add_argument("--block-len", type=int, default=16,
                    help="positions per KV block / prefill bucket granularity")
    ap.add_argument("--paged-attend-impl", default="gather",
                    choices=["gather", "pallas"],
                    help="paged decode attend: 'gather' assembles the full "
                         "block-table gather (dense-shaped transient), "
                         "'pallas' walks live blocks in place with the "
                         "paged-attention kernel (O(block-len) transient, "
                         "same tokens). Requires --kv-impl paged")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8", "q2_14"],
                    help="paged-pool storage format: K/V quantized at "
                         "pool-write time, dequantized at every read via "
                         "the CORDIC linear-rotation multiply (int8 ~4x / "
                         "q2_14 ~2x fewer resident pool bytes). Requires "
                         "--kv-impl paged")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: prompts longer than this stream "
                         "in as block-aligned chunks interleaved with "
                         "decode (same tokens). 0 = off")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="max prefill rows per multi-row paged dispatch "
                         "(0 = auto)")
    ap.add_argument("--max-prefill-tokens", type=int, default=0,
                    help="per-iteration prefill token budget (0 = unlimited)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prompt-prefix cache over the paged "
                         "pool: admissions sharing full prompt KV blocks "
                         "with an earlier request reuse them instead of "
                         "recomputing (same tokens). Requires --kv-impl "
                         "paged; the demo shares a system prompt across "
                         "requests so hits occur")
    ap.add_argument("--prefix-eviction", default="lru",
                    choices=["lru", "fifo"],
                    help="prefix-cache eviction order over idle cached "
                         "blocks under pool pressure")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree over the mesh 'model' "
                         "axis (must divide the visible device count; "
                         "bit-identical tokens). 0/1 = unsharded")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine metrics snapshot (TTFT/TPOT "
                         "histograms, queue/pool gauges, counters) here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace (Perfetto-loadable) JSON of "
                         "request lifecycles + engine phase spans here")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-demo", family="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        d_ff=768, vocab_size=4096, act_impl=args.act,
        rope_theta=1e4, dtype="float32",
    )
    print(f"[serve_lm] model {cfg.param_counts()['total'] / 1e6:.1f}M params, "
          f"act_impl={cfg.act_impl}, slots={args.slots}, "
          f"kv_impl={args.kv_impl}, kv_quant={args.kv_quant}, "
          f"T={args.temperature}, top_k={args.top_k}")
    params = tf.init(cfg, jax.random.PRNGKey(0))

    # temperature <= 0 resolves to greedy inside SamplingParams
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k)
    obs = (repro_obs.Observability(trace=args.trace_out is not None)
           if (args.metrics_json or args.trace_out) else None)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=128,
                      sampling=sampling, seed=args.seed,
                      kv_impl=args.kv_impl, block_len=args.block_len,
                      paged_attend_impl=args.paged_attend_impl,
                      kv_quant=args.kv_quant,
                      prefill_chunk=args.prefill_chunk or None,
                      prefill_batch=args.prefill_batch or None,
                      max_prefill_tokens=args.max_prefill_tokens or None,
                      prefix_cache=args.prefix_cache,
                      prefix_eviction=args.prefix_eviction,
                      tp=args.tp or None,
                      obs=obs)
    if eng.mesh is not None:
        print(f"[serve_lm] mesh: {dict(eng.mesh.shape)} over "
              f"{eng.mesh.size} devices (tokens bit-identical to --tp 1)")
    rng = np.random.default_rng(0)
    # shared system prompt (two full KV blocks) so --prefix-cache has
    # something to hit; empty when the cache is off
    sys_prompt = (rng.integers(0, cfg.vocab_size,
                               2 * args.block_len).astype(np.int32)
                  if args.prefix_cache else np.zeros(0, np.int32))
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        tail = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        r = Request(rid=i, prompt=np.concatenate([sys_prompt, tail]),
                    max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.time()
    steps = 0
    while eng.step():
        steps += 1
    wall = time.time() - t0
    done = eng.run()
    total_new = sum(len(r.out) for r in reqs)
    print(f"[serve_lm] served {len(done)} requests / {total_new} tokens in "
          f"{steps} engine steps ({steps} batched decode dispatches), "
          f"{wall:.1f}s ({total_new / wall:.1f} tok/s on host CPU)")
    if eng.pager is not None:
        st = eng.pager.stats()
        print(f"[serve_lm] pool: peak {st.peak_in_use}/{st.num_blocks - 1} "
              f"blocks x {eng.block_len} positions "
              f"(dense would pin {args.slots * 128 // eng.block_len})")
    if eng.prefix is not None:
        print(f"[serve_lm] prefix cache ({eng.prefix.policy}): "
              f"{eng.prefix.hits} hits / {eng.prefix.hit_blocks} blocks "
              f"reused, {eng.prefix.evicted_blocks} evicted")
    if obs is not None:
        ttft = obs.metrics.get("engine.ttft_ms")
        print(f"[serve_lm] ttft p50/p99 {ttft.quantile(0.5):.1f}/"
              f"{ttft.quantile(0.99):.1f} ms over {ttft.count} requests")
        if args.metrics_json:
            obs.metrics.to_json(args.metrics_json)
            print(f"[serve_lm] wrote metrics -> {args.metrics_json}")
        if args.trace_out:
            obs.trace.export(args.trace_out)
            print(f"[serve_lm] wrote Chrome trace -> {args.trace_out} "
                  f"(load at ui.perfetto.dev)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> out={r.out}")
    assert all(r.done for r in reqs)
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    print("[serve_lm] OK — all requests completed.")


if __name__ == "__main__":
    main()
