"""Serving throughput benchmark: decode tok/s vs slot count.

The ServeEngine issues exactly one jitted vmapped decode per step, so slot
count should buy near-linear decode throughput on dispatch-bound hosts (the
old engine looped one jitted call per slot — slots bought nothing). This
benchmark measures it instead of asserting it: steady-state decode tok/s at
slots in {1, 4, 8}, every configuration serving the same request workload
per slot, written to BENCH_serving.json:

    {"slots": {"1": {"tok_s": ..., ...}, "4": ..., "8": ...},
     "monotone": true, ...}

CLI: ``python benchmarks/serving.py --smoke [--out BENCH_serving.json]``
uses a smaller model + shorter generations for CI. Timing excludes compile:
a warm-up engine run compiles prefill + decode before the measured pass.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

SLOT_COUNTS = (1, 4, 8)


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="serve-bench-smoke", family="dense",
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=384, vocab_size=1024, act_impl="exact",
            rope_theta=1e4, dtype="float32",
        )
    return ModelConfig(
        name="serve-bench", family="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        d_ff=768, vocab_size=4096, act_impl="cordic_fixed",
        rope_theta=1e4, dtype="float32",
    )


def _requests(cfg, n: int, max_new: int, plen: int = 8):
    # fixed prompt length: one prefill compile, decode dominates the timing
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve_once(cfg, params, slots: int, *, requests_per_slot: int,
                max_new: int, sampling: SamplingParams):
    eng = ServeEngine(cfg, params, slots=slots, max_len=64, sampling=sampling)
    reqs = _requests(cfg, slots * requests_per_slot, max_new)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    steps = 0
    while eng.step():
        steps += 1
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    assert all(r.done for r in reqs)
    return toks, steps, wall


def bench(smoke: bool) -> dict:
    cfg = _cfg(smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    requests_per_slot = 2
    max_new = 8 if smoke else 32
    sampling = SamplingParams(greedy=True)

    per_slots = {}
    for slots in SLOT_COUNTS:
        # warm-up pass compiles prefill + the batched decode for this slot
        # count; the measured pass then times steady-state serving only
        _serve_once(cfg, params, slots, requests_per_slot=1, max_new=2,
                    sampling=sampling)
        toks, steps, wall = _serve_once(
            cfg, params, slots, requests_per_slot=requests_per_slot,
            max_new=max_new, sampling=sampling)
        per_slots[str(slots)] = {
            "tok_s": round(toks / wall, 2),
            "tokens": toks,
            "engine_steps": steps,
            "decode_dispatches": steps,
            "wall_s": round(wall, 3),
        }
        print(f"[serving] slots={slots}: {toks} tok / {steps} steps / "
              f"{wall:.2f}s = {toks / wall:.1f} tok/s")

    rates = [per_slots[str(s)]["tok_s"] for s in SLOT_COUNTS]
    return {
        "model": cfg.name,
        "mode": "smoke" if smoke else "full",
        "slot_counts": list(SLOT_COUNTS),
        "slots": per_slots,
        "monotone": all(a < b for a, b in zip(rates, rates[1:])),
        "speedup_8_over_1": round(rates[-1] / rates[0], 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check-monotone", action="store_true",
                    help="exit non-zero unless tok/s strictly improves with "
                         "slot count (off by default: CI hosts are noisy)")
    args = ap.parse_args(argv)

    res = bench(args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"[serving] wrote {args.out}: "
          f"{json.dumps({k: v['tok_s'] for k, v in res['slots'].items()})} "
          f"tok/s, x{res['speedup_8_over_1']} at 8 slots")
    if args.check_monotone and not res["monotone"]:
        print("[serving] FAIL: tok/s not monotone in slot count", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
