"""Serving throughput benchmark + regression gate: decode tok/s vs slot
count — dense, paged-gather, and paged-pallas (the block-walking
paged-attention kernel) side by side.

The ServeEngine issues exactly one jitted decode per step, so slot count
should buy near-linear decode throughput on dispatch-bound hosts; the paged
engine must deliver the same tokens from a block pool instead of dense
per-slot buffers without giving that throughput back. This benchmark
measures all three decode planes and **fails the build** when they
regress: steady-state decode tok/s at slots in {1, 4, 8} per impl, every
configuration serving the same request workload per slot, written to
BENCH_serving.json:

    {"impls": {"dense": {"slots": {"1": {"tok_s": ...}, ...}, ...},
               "paged": {..., "pool": {"peak_blocks": ...}},
               "paged_pallas": {...}},
     "transient": {"64": {"gather": ..., "pallas": ...}, "128": {...}}}

``transient`` records the per-row decode-attend working set in bytes
(kernels.paged_attention.decode_transient_bytes, derived from the same
shapes the kernel's BlockSpecs are built from) at two max_len values: the
gather path must scale linearly with max_len, the pallas path must NOT
scale at all — that invariance is gated below, which is the benchmark's
teeth for the kernel (on this CPU container the kernel runs in interpret
mode, so its *absolute* tok/s measures the interpreter, not the datapath;
it is recorded for visibility but the gather-class tok/s gates are the
perf contract and the transient metric is the kernel's).

Like benchmarks/accuracy.py, the gate is a hard CI failure, not a record:
every metric in BASELINES must be present (a renamed metric must not
silently disable its gate) and must stay above
``max(FLOOR_TOK_S, baseline * (1 - TOLERANCE))``. Baselines are this
revision's smoke numbers on a dev host; the tolerance absorbs CI-runner
noise while still catching a serialized decode loop or a paged gather
going quadratic (both are >2x collapses, far past any plausible jitter).

Beyond steady-state tok/s, the benchmark drives the repro.obs
observability layer (this is the serving-latency entry point — the old
benchmarks/latency.py evaluator microbench lives here too, see run()):

``poisson``
    An *open-loop* Poisson arrival trace (exponential inter-arrival gaps,
    arrivals fire on schedule whether or not the engine keeps up — the
    correct load model for latency percentiles; a closed loop would let a
    slow engine throttle its own offered load). Reports p50/p99 TTFT and
    TPOT out of the engine's log-bucketed histograms, goodput (tokens of
    requests whose TTFT met the SLO, per wall second), queue-depth peak,
    batch-occupancy mean, pool-occupancy peak, and the compile counters
    (must stay 0 during the measured window — the engine is warmed
    first). These are *gated*: a missing or non-finite metric fails the
    build (PR-4 gate style); absolute latency is host-dependent and not
    thresholded.

``mixed_chunked``
    The chunked-prefill acceptance trace: ONE seeded open-loop arrival
    trace mixing long (~bucket-max) and short prompts, served twice on
    the paged engine — unchunked (legacy single-shot admission) and
    chunked (``prefill_chunk`` + multi-row batched prefill). Gated on
    both axes of the contract: the two runs' token streams must be
    bit-identical (scheduling must never change outputs), and the
    short-request p99 TTFT must improve by >= MIN_SHORT_TTFT_SPEEDUP
    (the point of chunking: a long prompt streams in across iterations
    instead of stalling every short request behind its full-width
    prefill). The speedup is a ratio of two runs on the same host in the
    same process, so it holds on any runner class.

``prefix_cache``
    The prefix-caching acceptance trace: a synthetic "N users, 5 system
    prompts" open-loop Poisson workload (every prompt = one of five
    112-token system prompts + a short unique user tail, greedy and
    seeded sampling mixed), served cache-off and cache-on
    (``prefix_cache=True``) on the paged engine. Gated on all three
    axes of ROADMAP item 2's contract: the two runs' token streams must
    be bit-identical, and both the prefill-token count and the pool
    peak-block occupancy must collapse by >= MIN_PREFIX_COLLAPSE (the
    point of the radix cache: the shared system prompt prefills once
    and its blocks are shared, not recomputed and duplicated, per
    user). Both runs first serve one priming request per system prompt
    to completion — production system prompts are long-lived, so the
    steady state measured is the warm-cache one; the priming tokens
    join the identity check. A TP=1-vs-TP=2 sub-trace (subprocess re-exec, like
    ``sharded``) additionally gates that cache-on tokens stay
    bit-identical under tensor parallelism — block sharing is
    host-side metadata, so the mesh must not see it.

``kv_quant``
    The quantized paged-KV acceptance trace (ROADMAP item 5): one
    seeded greedy request trace served from the unquantized f32 pool
    and from int8 / q2_14 block-scaled pools (K/V quantized at
    pool-write time against per-block-per-head scales, dequantized at
    every read via the CORDIC linear-rotation multiply —
    core/kv_quant.py). Gated per format on the resident-pool bytes
    collapse at matched block count (int8 >= 2x), the greedy token
    match rate vs the unquantized stream, and the tok/s floor; int8
    must additionally be bit-identical between the gather and pallas
    attends (the kernel dequantizes per-chunk in VMEM with the same
    CORDIC multiply) and across TP=1/TP=2 (scale pools shard on the
    kv-heads cut). All gates live in benchmarks/check_bench.py — the
    same checkers CI runs against the uploaded artifact.

``host_overhead_1slot``
    The per-step phase breakdown (admit / dispatch / host_sync /
    sample_copy mean ms) per impl at 1 slot — quantifying the carried
    63-vs-235 tok/s paged-vs-dense low-occupancy gap as dispatch-vs-sync
    host time, so the fix can be judged against a recorded baseline.

``saturation``
    Would-clip counts per FORMAT_PROFILES format (obs.saturation_audit)
    over the model weights and a served log-prob sample — the software
    analogue of the paper's overflow-free Q2.14 claim, and the telemetry
    the quantized-KV roadmap item selects formats with.

CLI: ``python benchmarks/serving.py --smoke [--out BENCH_serving.json]
[--no-check] [--trace-out TRACE.json] [--metrics-json METRICS.json]
[--evaluators]`` — smoke uses a smaller model + shorter generations for
CI; the nightly workflow runs the full (non-smoke) mode, uploads the
artifact, and exports the Poisson run's Chrome trace (Perfetto-loadable)
via --trace-out. Timing excludes compile: a warm-up pass on the *same*
engine compiles prefill + decode before the measured pass (jit caches are
per-engine, so a throwaway warm-up engine would not help), and the
observability handle is attached *after* warm-up so histograms hold only
steady-state samples.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
# the section gates live in benchmarks/check_bench.py (one source of
# truth shared with the CI belt-check step); make the import work from
# any cwd, not just repo root
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from check_bench import (FLOOR_TOK_S, MIN_KVQ_BYTES_RATIO,
                         MIN_KVQ_MATCH_RATE, MIN_PREFIX_COLLAPSE,
                         MIN_SHORT_TTFT_SPEEDUP, check_kv_quant,
                         check_mixed_chunked, check_poisson,
                         check_prefix_cache, check_sharded)
from repro import obs as obs_lib
from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

SLOT_COUNTS = (1, 4, 8)
#: result key -> (kv_impl, paged_attend_impl) engine configuration
IMPLS = {
    "dense": ("dense", "gather"),
    "paged": ("paged", "gather"),
    "paged_pallas": ("paged", "pallas"),
}
IMPL_KEYS = tuple(IMPLS)
#: max_len values the transient working-set metric is recorded at; the
#: pallas entry must be EQUAL at both (no max_len scaling), the gather
#: entry must grow with max_len.
TRANSIENT_MAX_LENS = (64, 128)

#: Smoke-mode tok/s baselines for this revision (idle dev host, CPU). The
#: gate fails a metric below max(FLOOR_TOK_S, baseline * (1 - TOLERANCE))
#: and fails outright when a metric disappears from the results. Absolute
#: tok/s scales with the runner, so the tolerance is wide; the
#: host-invariant teeth are the speedup ratios below.
BASELINES = {
    "dense/1": 168.0,
    "dense/4": 570.0,
    "dense/8": 615.0,
    "paged/1": 210.0,
    "paged/4": 484.0,
    "paged/8": 679.0,
    # interpret-mode kernel numbers: on CPU these measure the Pallas
    # interpreter, not the datapath (see module docstring) — on this dev
    # host the kernel lane still beats the gather lane (it skips the
    # max_len-sized gather materialization), and the wide tolerance below
    # absorbs the rest.
    "paged_pallas/1": 248.0,
    "paged_pallas/4": 513.0,
    "paged_pallas/8": 516.0,
}
TOLERANCE = 0.9         # absolute tok/s soaks up runner-class differences
                        # (a 2-vCPU CI box can be ~5x slower than the dev
                        # host); the collapse classes these still catch —
                        # compile-in-measurement, quadratic gathers — are
                        # >20x, and serialization is caught host-invariantly
                        # by the speedup-ratio gate below
#: (FLOOR_TOK_S — below which the serving loop is broken, not slow —
#: is imported from check_bench.py, shared with the kv_quant gate)
#: 8 slots must beat 1 slot by at least this factor per impl — a RATIO, so
#: it holds on any host speed. One decode dispatch per step buys ~3.5-4x
#: here; a relapse to per-slot dispatch (or a paged gather going quadratic
#: in slots) collapses it to ~1 and fails regardless of runner class.
MIN_SPEEDUP_8_OVER_1 = 1.5
#: the ratio gate applies to the gather-class impls; the interpret-mode
#: kernel's scaling reflects interpreter overhead (grid size grows with
#: slots), so its gates are the tok/s floor + the transient invariance.
SPEEDUP_IMPLS = ("dense", "paged")
#: (MIN_PREFIX_COLLAPSE, MIN_SHORT_TTFT_SPEEDUP, MIN_KVQ_* and the
#: section checkers themselves live in check_bench.py — the single
#: source of truth CI's belt-check step also runs)


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="serve-bench-smoke", family="dense",
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=384, vocab_size=1024, act_impl="exact",
            rope_theta=1e4, dtype="float32",
        )
    return ModelConfig(
        name="serve-bench", family="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        d_ff=768, vocab_size=4096, act_impl="cordic_fixed",
        rope_theta=1e4, dtype="float32",
    )


def _requests(cfg, n: int, max_new: int, plen: int = 8):
    # fixed prompt length: one prefill bucket, decode dominates the timing
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve_once(eng, cfg, *, requests_per_slot: int, max_new: int):
    """One timed serve pass on an existing engine. The warm-up and the
    measured pass MUST share the engine: each ServeEngine wraps its own
    jax.jit objects (that per-instance cache is what compile_counts()
    measures), so a throwaway warm-up engine would leave every compile
    inside the measured wall time."""
    reqs = _requests(cfg, eng.slots * requests_per_slot, max_new)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    steps = 0
    while eng.step():
        steps += 1
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    assert all(r.done for r in reqs)
    return toks, steps, wall


def bench(cfg, params, smoke: bool) -> dict:
    requests_per_slot = 2
    max_new = 8 if smoke else 32
    sampling = SamplingParams(greedy=True)

    impls = {}
    for impl_key, (kv_impl, attend_impl) in IMPLS.items():
        per_slots = {}
        pool = None
        for slots in SLOT_COUNTS:
            eng = ServeEngine(cfg, params, slots=slots, max_len=64,
                              sampling=sampling, kv_impl=kv_impl,
                              paged_attend_impl=attend_impl)
            # warm-up pass compiles prefill + the batched decode for this
            # slot count; the measured pass then times steady-state serving
            _serve_once(eng, cfg, requests_per_slot=1, max_new=2)
            toks, steps, wall = _serve_once(
                eng, cfg, requests_per_slot=requests_per_slot,
                max_new=max_new)
            per_slots[str(slots)] = {
                "tok_s": round(toks / wall, 2),
                "tokens": toks,
                "engine_steps": steps,
                "decode_dispatches": steps,
                "wall_s": round(wall, 3),
            }
            if eng.pager is not None:
                st = eng.pager.stats()
                pool = {"block_len": eng.block_len,
                        "num_blocks": st.num_blocks,
                        "peak_blocks": st.peak_in_use,
                        "dense_equiv_blocks": slots * eng.max_blocks}
            print(f"[serving] impl={impl_key} slots={slots}: {toks} tok / "
                  f"{steps} steps / {wall:.2f}s = {toks / wall:.1f} tok/s")

        rates = [per_slots[str(s)]["tok_s"] for s in SLOT_COUNTS]
        impls[impl_key] = {
            "slots": per_slots,
            "monotone": all(a < b for a, b in zip(rates, rates[1:])),
            "speedup_8_over_1": round(rates[-1] / rates[0], 2),
        }
        if pool is not None:
            impls[impl_key]["pool"] = pool

    # transient decode-attend working set per row (bytes), recorded at two
    # max_len values so the gate can assert the kernel path does not scale
    from repro.kernels import paged_attention as PA

    transient = {
        str(ml): {
            "gather": PA.decode_transient_bytes(cfg, max_len=ml,
                                                block_len=16, impl="gather"),
            "pallas": PA.decode_transient_bytes(cfg, max_len=ml,
                                                block_len=16, impl="pallas"),
        }
        for ml in TRANSIENT_MAX_LENS
    }

    return {
        "model": cfg.name,
        "mode": "smoke" if smoke else "full",
        "slot_counts": list(SLOT_COUNTS),
        "impl_configs": {k: {"kv_impl": kv, "paged_attend_impl": at}
                         for k, (kv, at) in IMPLS.items()},
        "impls": impls,
        "transient": transient,
    }


#: engine phases whose per-step means the host-overhead section records
#: (the poisson-section gated-key list is check_bench.POISSON_GATED)
PHASES = ("admit", "dispatch", "host_sync", "sample_copy")


def _poisson_params(smoke: bool) -> dict:
    # open-loop offered load: high enough that slots contend and the queue
    # builds (the percentiles must reflect queueing, not an idle engine),
    # low enough that the smoke trace stays a few seconds on a CI box
    return (dict(n=16, rate_req_s=8.0, max_new=8, slots=4, slo_ms=2000.0)
            if smoke else
            dict(n=64, rate_req_s=12.0, max_new=32, slots=8, slo_ms=1000.0))


def bench_poisson(cfg, params, smoke: bool, trace_out=None,
                  metrics_json=None) -> dict:
    """Open-loop Poisson arrival trace against the paged engine: arrivals
    fire at pre-drawn wall-clock offsets (exponential gaps, seeded), the
    engine steps continuously, and every latency number is read back out
    of the repro.obs histograms the engine filled. TTFT includes queueing
    (enqueue -> first token), which is the point of open-loop driving."""
    pp = _poisson_params(smoke)
    eng = ServeEngine(cfg, params, slots=pp["slots"], max_len=64,
                      sampling=SamplingParams(greedy=True), kv_impl="paged",
                      paged_attend_impl="gather")
    # warm every compile (prefill bucket + decode) on a NULL-obs engine,
    # then attach observability: the histograms see steady state only
    _serve_once(eng, cfg, requests_per_slot=1, max_new=2)
    ob = obs_lib.Observability(trace=trace_out is not None)
    eng.attach_obs(ob)

    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / pp["rate_req_s"], pp["n"]))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 12))
                                        ).astype(np.int32),
                    max_new_tokens=pp["max_new"])
            for i in range(pp["n"])]

    # open loop: arrivals fire on schedule; an idle engine sleeps up to
    # the next arrival instead of spinning (the arrival time never moves)
    wall = _drive_open_loop(eng, reqs, arrivals)

    m = ob.metrics

    def _q(name):
        h = m.get(name)
        return {"p50": round(h.quantile(0.50), 3),
                "p90": round(h.quantile(0.90), 3),
                "p99": round(h.quantile(0.99), 3),
                "mean": round(h.mean, 3), "count": h.count}

    met = [r for r in reqs
           if (r.t_first - r.t_enqueue) * 1e3 <= pp["slo_ms"]]
    total_toks = sum(len(r.out) for r in reqs)
    st = eng.pager.stats()
    res = {
        **pp,
        "wall_s": round(wall, 3),
        "ttft_ms": _q("engine.ttft_ms"),
        "tpot_ms": _q("engine.tpot_ms"),
        "e2e_ms": _q("engine.e2e_ms"),
        "throughput_tok_s": round(total_toks / wall, 2),
        "goodput_tok_s": round(sum(len(r.out) for r in met) / wall, 2),
        "slo_met_requests": len(met),
        "queue_depth_peak": m.get("engine.queue_depth").peak,
        "batch_occupancy_mean": round(
            m.get("engine.batch_occupancy").mean, 3),
        "pool": {"peak_blocks": st.peak_in_use,
                 "num_blocks": st.num_blocks,
                 "alloc_failures": st.alloc_failures},
        # must be 0: the engine was warmed before obs attached, so any
        # compile here means a shape leaked into the measured window
        "compiles_measured": {
            k: c.value for k, c in
            (("prefill", m.get("engine.compiles.prefill")),
             ("decode", m.get("engine.compiles.decode")))},
    }
    print(f"[serving] poisson: {pp['n']} req @ {pp['rate_req_s']}/s -> "
          f"ttft p50/p99 {res['ttft_ms']['p50']}/{res['ttft_ms']['p99']} ms, "
          f"tpot p50 {res['tpot_ms']['p50']} ms, "
          f"goodput {res['goodput_tok_s']} tok/s ({len(met)}/{pp['n']} in "
          f"SLO), queue peak {res['queue_depth_peak']}")
    if trace_out:
        ob.trace.export(trace_out)
        print(f"[serving] wrote Chrome trace -> {trace_out} "
              f"({len(ob.trace.events)} events; load at ui.perfetto.dev)")
    if metrics_json:
        ob.metrics.to_json(metrics_json)
        print(f"[serving] wrote metrics snapshot -> {metrics_json}")
    return res


def _mixed_trace(cfg, smoke: bool):
    """Seeded mixed long/short request trace + open-loop arrival offsets.
    Longs sit near the engine's largest bucket (their single-shot prefill
    is the stall chunking removes); shorts are prompt-trivial and TTFT-
    sensitive. Deterministic: both engine runs serve identical requests
    at identical offsets."""
    n_long, n_short = (5, 10) if smoke else (8, 24)
    rate = 12.0 if smoke else 18.0
    rng = np.random.default_rng(11)
    kinds = [True] * n_long + [False] * n_short
    rng.shuffle(kinds)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, len(kinds)))

    def reqs():
        r = np.random.default_rng(13)
        out = []
        for i, is_long in enumerate(kinds):
            plen = (int(r.integers(900, 1001)) if is_long
                    else int(r.integers(4, 13)))
            out.append(Request(
                rid=i, prompt=r.integers(0, cfg.vocab_size,
                                         plen).astype(np.int32),
                max_new_tokens=2 if is_long else 8))
        return out

    return kinds, arrivals, reqs


def _drive_open_loop(eng, reqs, arrivals):
    """Open-loop replay: submit each request at its pre-drawn offset while
    stepping continuously (arrivals never wait for the engine)."""
    t0 = time.perf_counter()
    nxt = 0
    while not all(r.done for r in reqs):
        now = time.perf_counter() - t0
        while nxt < len(reqs) and arrivals[nxt] <= now:
            eng.submit(reqs[nxt])
            nxt += 1
        if not eng.step() and nxt < len(reqs):
            time.sleep(max(0.0, min(arrivals[nxt]
                                    - (time.perf_counter() - t0), 0.01)))
    return time.perf_counter() - t0


def bench_mixed_chunked(cfg, params, smoke: bool) -> dict:
    """Chunked vs unchunked on ONE mixed long/short trace (module
    docstring, ``mixed_chunked``): same seeded requests and arrival
    offsets, paged engine both times; report per-class TTFT percentiles,
    the short-request p99 speedup, and whether the token streams are
    bit-identical."""
    # the long prompts' single-shot prefill must dominate a decode step
    # for the stall to be visible: at max_len=1024 the full-width prefill
    # is ~two orders of magnitude over one decode dispatch on this model
    max_len, chunk = 1024, 64
    kinds, arrivals, mk_reqs = _mixed_trace(cfg, smoke)

    def serve(prefill_chunk):
        eng = ServeEngine(cfg, params, slots=4, max_len=max_len,
                          sampling=SamplingParams(greedy=True),
                          kv_impl="paged", prefill_chunk=prefill_chunk)
        # warm every measured shape before TTFT is measured: a burst pass
        # (all slots contended -> widest pow2 row groups compile) plus an
        # open-loop replay of the very trace (the admission cadence the
        # measured run will see, covering the remaining group shapes)
        for r in mk_reqs():
            eng.submit(r)
        eng.run()
        _drive_open_loop(eng, mk_reqs(), arrivals)
        reqs = mk_reqs()
        wall = _drive_open_loop(eng, reqs, arrivals)
        return eng, reqs, wall

    out = {}
    toks = {}
    for key, chunk_arg in (("unchunked", None), ("chunked", chunk)):
        eng, reqs, wall = serve(chunk_arg)
        toks[key] = [list(r.out) for r in reqs]
        ttft = {is_long: [(r.t_first - r.t_enqueue) * 1e3
                          for r, il in zip(reqs, kinds) if il == is_long]
                for is_long in (True, False)}
        out[key] = {
            "wall_s": round(wall, 3),
            "short_ttft_ms": {
                "p50": round(float(np.percentile(ttft[False], 50)), 3),
                "p99": round(float(np.percentile(ttft[False], 99)), 3)},
            "long_ttft_ms": {
                "p50": round(float(np.percentile(ttft[True], 50)), 3),
                "p99": round(float(np.percentile(ttft[True], 99)), 3)},
            "prefill_compiles": eng.compile_counts()["prefill"],
        }
    res = {
        "n_long": sum(kinds), "n_short": len(kinds) - sum(kinds),
        "max_len": max_len, "prefill_chunk": chunk,
        "tokens_identical": int(toks["chunked"] == toks["unchunked"]),
        "short_ttft_p99_speedup": round(
            out["unchunked"]["short_ttft_ms"]["p99"]
            / out["chunked"]["short_ttft_ms"]["p99"], 3),
        **out,
    }
    print(f"[serving] mixed_chunked: short p99 TTFT "
          f"{out['unchunked']['short_ttft_ms']['p99']}ms unchunked -> "
          f"{out['chunked']['short_ttft_ms']['p99']}ms chunked "
          f"(x{res['short_ttft_p99_speedup']}), tokens identical: "
          f"{bool(res['tokens_identical'])}")
    return res


def bench_host_overhead(cfg, params, smoke: bool) -> dict:
    """Per-step phase breakdown at 1 slot per impl — the carried
    63-vs-235 tok/s item made measurable: how much of a paged decode step
    is jit dispatch vs device->host sync vs host bookkeeping, recorded so
    the gap can be judged (and closed) against numbers, not vibes."""
    out = {}
    max_new = 16 if smoke else 64
    for impl_key, (kv_impl, attend_impl) in IMPLS.items():
        eng = ServeEngine(cfg, params, slots=1, max_len=64,
                          sampling=SamplingParams(greedy=True),
                          kv_impl=kv_impl, paged_attend_impl=attend_impl)
        _serve_once(eng, cfg, requests_per_slot=1, max_new=2)   # warm
        ob = obs_lib.Observability()
        eng.attach_obs(ob)
        toks, steps, wall = _serve_once(eng, cfg, requests_per_slot=2,
                                        max_new=max_new)
        entry = {"tok_s": round(toks / wall, 2), "steps": steps}
        for ph in PHASES:
            h = ob.metrics.get(f"engine.phase.{ph}_ms")
            entry[f"{ph}_ms_mean"] = round(h.mean, 4)
        entry["step_ms_mean"] = round(
            ob.metrics.get("engine.step_ms").mean, 4)
        out[impl_key] = entry
        print(f"[serving] host_overhead 1-slot {impl_key}: " +
              " ".join(f"{ph}={entry[f'{ph}_ms_mean']}ms" for ph in PHASES))
    out["paged_over_dense_step_ms"] = round(
        out["paged"]["step_ms_mean"] / out["dense"]["step_ms_mean"], 3)
    return out


def bench_saturation(cfg, params) -> dict:
    """FORMAT_PROFILES would-clip audit over (a) the model weights (the
    init's gaussian tail puts ~1% of elements past the Q2.x ±2 range —
    the number a per-tensor scale would have to absorb) and (b) a served
    teacher-forced log-prob row, which exceeds the range almost entirely
    (log-probs live far below -2): exactly the per-tensor telemetry a
    format-assignment sweep (ROADMAP item 5) consumes, and the serving-
    side analogue of the paper's overflow-free-Q2.14 domain argument."""
    cap = 1 << 16
    weights = np.concatenate([np.asarray(l).ravel()[:cap]
                              for l in jax.tree.leaves(params)])
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, 16).astype(np.int32)
    logprobs = eng.score(prompt)
    reg = obs_lib.MetricsRegistry()
    audit = obs_lib.saturation_audit(
        {"weights": weights, "score_logprobs": logprobs}, registry=reg)
    clips = {name: m.value for name, m in
             ((n, reg.get(n)) for n in reg.names()) if "clips" in name}
    print(f"[serving] saturation: " + ", ".join(
        f"{p}: weights {audit[p]['weights']['clipped']}/"
        f"{audit[p]['weights']['total']}, logprobs "
        f"{audit[p]['score_logprobs']['clipped']}/"
        f"{audit[p]['score_logprobs']['total']}" for p in sorted(audit)))
    return {"profiles": audit, "clip_counters": clips}


def _bench_sharded_inner(smoke: bool) -> dict:
    """TP=1 vs TP=2 serve of the same fixed request trace. Must run in a
    process with >= 2 visible devices (bench_sharded arranges that); the
    gate downstream is token bit-identity + metrics-exist, NOT speedup —
    forced host-CPU shards time-share the same cores, so tok_s_tp2 is a
    topology record, not a performance claim."""
    cfg = _cfg(smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    max_new = 8 if smoke else 16

    def one(tp: int):
        eng = ServeEngine(cfg, params, slots=4, max_len=64, seed=0,
                          kv_impl="paged", block_len=16, tp=tp)
        _serve_once(eng, cfg, requests_per_slot=1, max_new=2)  # warm-up
        reqs = _requests(cfg, 8, max_new)
        for r in reqs[1::2]:  # greedy/sampled mix exercises both RNG paths
            r.sampling = SamplingParams(temperature=0.7, top_k=6)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        while eng.step():
            pass
        wall = time.perf_counter() - t0
        toks = [list(map(int, r.out))
                for r in sorted(reqs, key=lambda r: r.rid)]
        n_tok = sum(len(t) for t in toks)
        axis = (dict(eng.mesh.shape) if eng.mesh is not None
                else {"data": jax.device_count(), "model": 1})
        return toks, round(n_tok / wall, 2), axis

    toks1, tok_s_tp1, _ = one(1)
    toks2, tok_s_tp2, axis2 = one(2)
    identical = int(toks1 == toks2)
    print(f"[serving] sharded: tp=1 {tok_s_tp1} tok/s, tp=2 {tok_s_tp2} "
          f"tok/s, tokens_identical={identical}")
    return {
        "device_count": jax.device_count(),
        "tp": 2,
        "axis_sizes": axis2,
        "tok_s_tp1": tok_s_tp1,
        "tok_s_tp2": tok_s_tp2,
        "tokens_identical": identical,
    }


def bench_sharded(smoke: bool) -> dict:
    """Tensor-parallel conformance section. jax freezes the device count
    at first backend init, so when this process sees a single device the
    measurement re-execs this file under
    XLA_FLAGS=--xla_force_host_platform_device_count=2 and parses the
    child's marker line; with >= 2 devices it runs in-process."""
    if jax.device_count() >= 2:
        return _bench_sharded_inner(smoke)
    import os
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, os.path.abspath(__file__), "--sharded-subprocess"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root)
    for line in proc.stdout.splitlines():
        if line.startswith(_SHARDED_MARKER):
            return json.loads(line[len(_SHARDED_MARKER):])
    return {"error": "sharded subprocess produced no result: "
                     + (proc.stderr or proc.stdout)[-500:]}


#: stdout marker the --sharded-subprocess child prints its JSON after
_SHARDED_MARKER = "SHARDED_JSON:"


def _prefix_trace(cfg, n_users: int, rate_req_s: float, seed: int = 21):
    """The "N users, 5 system prompts" workload: every prompt is one of
    five fixed 112-token (7-block) system prompts plus a 1..15-token
    unique user tail, arriving open-loop Poisson; every other request
    samples (seeded) instead of decoding greedily. Deterministic: both
    engine runs serve identical requests at identical offsets."""
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, cfg.vocab_size, 112).astype(np.int32)
                   for _ in range(5)]
    assign = rng.integers(0, 5, n_users)
    tails = rng.integers(1, 16, n_users)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_req_s, n_users))

    def reqs():
        r = np.random.default_rng(seed + 1)
        out = []
        for i in range(n_users):
            tail = r.integers(0, cfg.vocab_size,
                              int(tails[i])).astype(np.int32)
            out.append(Request(
                rid=i,
                prompt=np.concatenate([sys_prompts[int(assign[i])], tail]),
                max_new_tokens=2,
                sampling=(SamplingParams(temperature=0.7, top_k=6)
                          if i % 2 else None)))
        return out

    def prime():
        # one warm-up request per system prompt, served to completion
        # before the flood: production system prompts are long-lived, so
        # the steady state being measured is the warm-cache one. Both
        # engine runs serve them (identical work; the cache-off engine
        # just recomputes), and their tokens join the identity check.
        return [Request(rid=1_000_000 + j, prompt=sys_prompts[j],
                        max_new_tokens=2) for j in range(len(sys_prompts))]

    return arrivals, reqs, prime


def _serve_prefix(cfg, params, reqs, arrivals, *, prefix: bool,
                  tp=None, prime=None) -> tuple:
    """Priming pass (serve ``prime`` to completion — seeds the radix
    index when the cache is on) followed by one open-loop replay, with an
    attached metrics registry. Returns (section dict, sorted token
    streams incl. priming). Compile walls land in wall_s — recorded, not
    gated — keeping prefill-token and peak-block counts pure measures of
    the trace."""
    obs = obs_lib.Observability()
    eng = ServeEngine(cfg, params, slots=16, max_len=128, seed=0,
                      kv_impl="paged", block_len=16, prefix_cache=prefix,
                      tp=tp, obs=obs)
    prime = list(prime() if callable(prime) else prime or [])
    for r in prime:
        eng.submit(r)
    eng.run()
    wall = _drive_open_loop(eng, reqs, arrivals)
    st = eng.pager.stats()
    m = obs.metrics
    sec = {
        "wall_s": round(wall, 3),
        "prefill_tokens": int(m.get("engine.prefill.tokens").value),
        "prefix_hit_tokens": int(m.get("prefix.hit_tokens").value),
        "blocks_saved": int(m.get("kv.pool.blocks_saved").value),
        "pool_peak_blocks": int(st.peak_in_use),
        "pool_num_blocks": int(st.num_blocks),
    }
    toks = [list(map(int, r.out))
            for r in sorted(prime + list(reqs), key=lambda r: r.rid)]
    return sec, toks


def _bench_prefix_tp_inner(smoke: bool) -> dict:
    """Cache-on/off identity at TP=1 and TP=2 on a short slice of the
    prefix trace. Must run with >= 2 visible devices (bench_prefix_cache
    arranges that). The pager — and with it the radix cache's block
    sharing — is shard-agnostic host metadata, so the gate is pure token
    bit-identity, per tp and across tp."""
    cfg = _cfg(smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    arrivals, mk_reqs, mk_prime = _prefix_trace(cfg, 24, 40.0)
    toks = {}
    for tp in (1, 2):
        for prefix in (False, True):
            _, toks[tp, prefix] = _serve_prefix(cfg, params, mk_reqs(),
                                                arrivals, prefix=prefix,
                                                tp=tp, prime=mk_prime)
    out = {
        "device_count": jax.device_count(),
        "tokens_identical_tp1": int(toks[1, True] == toks[1, False]),
        "tokens_identical_tp2": int(toks[2, True] == toks[2, False]),
        "tokens_identical_across_tp": int(toks[1, True] == toks[2, True]),
    }
    print(f"[serving] prefix_cache tp: identical tp1="
          f"{out['tokens_identical_tp1']} tp2={out['tokens_identical_tp2']} "
          f"across={out['tokens_identical_across_tp']}")
    return out


#: stdout marker the --prefix-subprocess child prints its JSON after
_PREFIX_MARKER = "PREFIX_JSON:"


def bench_prefix_cache(cfg, params, smoke: bool) -> dict:
    """Prefix-caching section (module docstring, ``prefix_cache``): the
    shared-system-prompt Poisson trace cache-off vs cache-on at TP=1,
    plus the TP=1/TP=2 identity sub-trace (re-execed with two forced
    host devices when this process only sees one, like bench_sharded)."""
    # the "1000 users" trace IS the claim being gated, so smoke keeps it:
    # max_new=2 and the shared prefill keep even 1000 users cheap
    n_users = 1000
    rate = 150.0
    arrivals, mk_reqs, mk_prime = _prefix_trace(cfg, n_users, rate)
    out = {}
    toks = {}
    for key, prefix in (("cache_off", False), ("cache_on", True)):
        reqs = mk_reqs()
        out[key], toks[key] = _serve_prefix(cfg, params, reqs, arrivals,
                                            prefix=prefix, prime=mk_prime)
        print(f"[serving] prefix_cache {key}: "
              f"{out[key]['prefill_tokens']} prefill tokens, pool peak "
              f"{out[key]['pool_peak_blocks']} blocks, "
              f"{out[key]['wall_s']}s")
    res = {
        "n_users": n_users,
        "n_system_prompts": 5,
        "system_prompt_len": 112,
        "eviction_policy": "lru",
        "tokens_identical": int(toks["cache_on"] == toks["cache_off"]),
        "prefill_tokens_ratio": round(
            out["cache_off"]["prefill_tokens"]
            / max(1, out["cache_on"]["prefill_tokens"]), 3),
        "peak_blocks_ratio": round(
            out["cache_off"]["pool_peak_blocks"]
            / max(1, out["cache_on"]["pool_peak_blocks"]), 3),
        **out,
    }
    print(f"[serving] prefix_cache: prefill tokens x"
          f"{res['prefill_tokens_ratio']} down, peak blocks x"
          f"{res['peak_blocks_ratio']} down, tokens identical: "
          f"{bool(res['tokens_identical'])}")
    if jax.device_count() >= 2:
        res["tp"] = _bench_prefix_tp_inner(smoke)
        return res
    import os
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, os.path.abspath(__file__), "--prefix-subprocess"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root)
    for line in proc.stdout.splitlines():
        if line.startswith(_PREFIX_MARKER):
            res["tp"] = json.loads(line[len(_PREFIX_MARKER):])
            return res
    res["tp"] = {"error": "prefix tp subprocess produced no result: "
                          + (proc.stderr or proc.stdout)[-500:]}
    return res


#: stdout marker the --kvq-subprocess child prints its JSON after
_KVQ_MARKER = "KVQ_TP_JSON:"


def _kvq_serve(cfg, params, max_new: int, *, kv_quant, attend_impl="gather",
               tp=None):
    """One warmed serve of the fixed kv_quant request trace. Returns
    (sorted token streams, tok/s, resident pool bytes). Every call
    serves the identical seeded requests, so streams are comparable
    across storage formats, attend impls, and TP degrees."""
    eng = ServeEngine(cfg, params, slots=4, max_len=64, seed=0,
                      sampling=SamplingParams(greedy=True), kv_impl="paged",
                      paged_attend_impl=attend_impl, kv_quant=kv_quant,
                      tp=tp)
    _serve_once(eng, cfg, requests_per_slot=1, max_new=2)   # warm compiles
    reqs = _requests(cfg, 8, max_new)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    while eng.step():
        pass
    wall = time.perf_counter() - t0
    toks = [list(map(int, r.out))
            for r in sorted(reqs, key=lambda r: r.rid)]
    n_tok = sum(len(t) for t in toks)
    return toks, round(n_tok / wall, 2), eng.kv_pool_bytes(), eng


def _bench_kvq_tp_inner(smoke: bool) -> dict:
    """int8 token identity at TP=1 vs TP=2 on the kv_quant trace. Must
    run with >= 2 visible devices (bench_kv_quant arranges that). The
    per-block-per-head scale pools shard on the same kv-heads cut as the
    code pools, so the mesh must not perturb a single emitted token."""
    cfg = _cfg(smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    max_new = 8 if smoke else 16
    toks = {tp: _kvq_serve(cfg, params, max_new, kv_quant="int8", tp=tp)[0]
            for tp in (1, 2)}
    out = {
        "device_count": jax.device_count(),
        "tokens_identical_across_tp": int(toks[1] == toks[2]),
    }
    print(f"[serving] kv_quant tp: int8 identical across tp1/tp2 = "
          f"{out['tokens_identical_across_tp']}")
    return out


def bench_kv_quant(cfg, params, smoke: bool) -> dict:
    """Quantized paged-KV acceptance section (ROADMAP item 5): the same
    seeded greedy trace served from an unquantized paged pool and from
    int8 / q2_14 block-scaled pools (quantize-at-write, CORDIC linear-
    rotation dequant at every read). Records, per format: the greedy
    token match rate vs the unquantized stream, resident pool bytes and
    the bytes collapse ratio at MATCHED block count, bytes/token, and
    tok/s. int8 additionally runs the pallas attend (in-kernel dequant
    must be bit-identical to the gather dequant) and a TP=1/TP=2
    identity sub-trace (subprocess re-exec with two forced host devices
    when needed, like ``sharded``). Gated by check_bench.check_kv_quant."""
    max_new = 8 if smoke else 16
    base_toks, base_tok_s, base_bytes, base_eng = _kvq_serve(
        cfg, params, max_new, kv_quant="none")
    total = sum(len(t) for t in base_toks)
    res = {
        "max_new": max_new,
        "n_requests": len(base_toks),
        "baseline": {"tok_s": base_tok_s, "pool_bytes": int(base_bytes),
                     "bytes_per_token": round(
                         base_eng.pager.block_bytes / base_eng.block_len, 2)},
        "formats": {},
    }
    toks_i8 = None
    for fmt in ("int8", "q2_14"):
        toks, tok_s, pool_bytes, eng = _kvq_serve(cfg, params, max_new,
                                                  kv_quant=fmt)
        if fmt == "int8":
            toks_i8 = toks
        matched = sum(a == b for s1, s2 in zip(base_toks, toks)
                      for a, b in zip(s1, s2))
        spec = eng._kv_quant_spec
        res["formats"][fmt] = {
            "match_rate": round(matched / max(1, total), 4),
            "matched_tokens": matched,
            "total_tokens": total,
            "tok_s": tok_s,
            "pool_bytes": int(pool_bytes),
            "pool_bytes_ratio": round(base_bytes / pool_bytes, 3),
            "bytes_per_token": round(
                eng.pager.block_bytes / eng.block_len, 2),
            "code_bits": spec.code_bits,
        }
        print(f"[serving] kv_quant {fmt}: match {matched}/{total} = "
              f"{res['formats'][fmt]['match_rate']}, pool bytes x"
              f"{res['formats'][fmt]['pool_bytes_ratio']} down, "
              f"{tok_s} tok/s")
    toks_pl, _, _, _ = _kvq_serve(cfg, params, max_new, kv_quant="int8",
                                  attend_impl="pallas")
    res["pallas_tokens_identical"] = int(toks_pl == toks_i8)
    print(f"[serving] kv_quant: int8 gather == int8 pallas: "
          f"{bool(res['pallas_tokens_identical'])}")
    if jax.device_count() >= 2:
        res["tp"] = _bench_kvq_tp_inner(smoke)
        return res
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, os.path.abspath(__file__), "--kvq-subprocess"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root)
    for line in proc.stdout.splitlines():
        if line.startswith(_KVQ_MARKER):
            res["tp"] = json.loads(line[len(_KVQ_MARKER):])
            return res
    res["tp"] = {"error": "kv_quant tp subprocess produced no result: "
                          + (proc.stderr or proc.stdout)[-500:]}
    return res


def check_obs_sections(res: dict) -> list:
    """Presence/finiteness gate for the observability-driven sections —
    missing = failure, matching the tok/s gate's missing-metric rule.
    Latency magnitudes are host-dependent, so only existence + finiteness
    are enforced here. The poisson half is check_bench.check_poisson
    (shared with the CI belt-check); host-overhead and saturation shapes
    are benchmark-internal, so they stay here."""
    bad = list(check_poisson(res))

    def _finite(path: str) -> None:
        node = res
        try:
            for part in path.split("."):
                node = node[part]
        except (KeyError, TypeError):
            bad.append((path, float("nan"), "present"))
            return
        try:
            v = float(node)
        except (TypeError, ValueError):
            bad.append((path, float("nan"), "numeric"))
            return
        if not np.isfinite(v):
            bad.append((path, v, "finite"))

    for impl in IMPL_KEYS:
        for ph in PHASES:
            _finite(f"host_overhead_1slot.{impl}.{ph}_ms_mean")
    for prof in ("q2_14", "q2_20", "q2_29"):
        for tensor in ("weights", "score_logprobs"):
            _finite(f"saturation.profiles.{prof}.{tensor}.clipped")
    return bad


def check_thresholds(res: dict) -> list:
    """Returns [(metric, value, limit)] for every regressed metric; a
    BASELINES key missing from the results is itself a failure."""
    bad = []
    for key in sorted(BASELINES):
        impl, slots = key.split("/")
        limit = max(FLOOR_TOK_S, BASELINES[key] * (1.0 - TOLERANCE))
        try:
            value = res["impls"][impl]["slots"][slots]["tok_s"]
        except KeyError:
            bad.append((key, float("nan"), limit))
            continue
        if value < limit:
            bad.append((key, value, limit))
    for impl in SPEEDUP_IMPLS:
        key = f"{impl}/speedup_8_over_1"
        try:
            value = res["impls"][impl]["speedup_8_over_1"]
        except KeyError:
            bad.append((key, float("nan"), MIN_SPEEDUP_8_OVER_1))
            continue
        if value < MIN_SPEEDUP_8_OVER_1:
            bad.append((key, value, MIN_SPEEDUP_8_OVER_1))
    bad.extend(check_transient(res))
    bad.extend(check_obs_sections(res))
    bad.extend(check_mixed_chunked(res))
    bad.extend(check_sharded(res))
    bad.extend(check_prefix_cache(res))
    bad.extend(check_kv_quant(res))
    return bad


def check_transient(res: dict) -> list:
    """The kernel-path acceptance gate: the recorded per-row transient
    working set must be max_len-INVARIANT for the pallas attend, scale
    with max_len for the gather attend, and sit below gather at every
    recorded max_len. A missing entry is itself a failure."""
    bad = []
    try:
        tr = {ml: {im: float(res["transient"][str(ml)][im])
                   for im in ("gather", "pallas")}
              for ml in TRANSIENT_MAX_LENS}
    except KeyError:
        return [("transient/<missing>", float("nan"), float("nan"))]
    lo, hi = min(TRANSIENT_MAX_LENS), max(TRANSIENT_MAX_LENS)
    if tr[hi]["pallas"] != tr[lo]["pallas"]:
        bad.append((f"transient/pallas@{hi}==@{lo}", tr[hi]["pallas"],
                    tr[lo]["pallas"]))
    if tr[hi]["gather"] <= tr[lo]["gather"]:
        bad.append((f"transient/gather@{hi}>@{lo}", tr[hi]["gather"],
                    tr[lo]["gather"]))
    for ml in TRANSIENT_MAX_LENS:
        if tr[ml]["pallas"] >= tr[ml]["gather"]:
            bad.append((f"transient/pallas<gather@{ml}", tr[ml]["pallas"],
                        tr[ml]["gather"]))
    return bad


def run(csv_rows: list, n: int = 1_000_000, reps: int = 5) -> None:
    """Evaluator latency microbench (the benchmarks/run.py CSV protocol;
    formerly benchmarks/latency.py — serving.py is now the single
    latency-measurement entry point): us per call on an n-element tensor
    per sigmoid evaluator, host-CPU wall time. The CORDIC fixed path
    timing on CPU reflects the emulation (26 unrolled integer stages), not
    TPU VPU throughput — the structural VPU op count is in resources.py."""
    import jax.numpy as jnp

    from repro.core import sigmoid as S

    def _time(fn, x) -> float:
        fn(x).block_until_ready()  # compile+warm
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(x).block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6  # us

    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, n), jnp.float32)
    cases = {
        "exact_jnp_sigmoid": jax.jit(S.sigmoid_exact),
        "cordic_float": jax.jit(lambda v: S.sigmoid_cordic_float(v)),
        "cordic_fixed_q2.14": jax.jit(lambda v: S.sigmoid_cordic_fixed(v)),
        "r2_cordic_fixed": jax.jit(lambda v: S.sigmoid_r2_cordic_fixed(v)),
        "pwl_16seg": jax.jit(lambda v: S.sigmoid_pwl_fixed(v, 16)),
        "lut_256": jax.jit(lambda v: S.sigmoid_lut_fixed(v, 256)),
    }
    for name, fn in cases.items():
        us = _time(fn, x)
        csv_rows.append((f"latency/{name}", round(us, 1),
                         f"{n / us:.0f} elem/us-e6; host-CPU measurement"))

    # integer end-to-end path (no float boundary) — the quantized-serving
    # mode
    xq = jnp.asarray(
        np.random.default_rng(1).integers(-(1 << 14), 1 << 14, n), jnp.int32)
    from repro.core.cordic import sigmoid_mr_q

    us = _time(jax.jit(sigmoid_mr_q), xq)
    csv_rows.append(("latency/cordic_fixed_int_io", round(us, 1),
                     "integer in/out (quantized pipeline)"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--no-check", action="store_true",
                    help="record only; skip the regression-threshold gate")
    ap.add_argument("--trace-out", default=None,
                    help="export the Poisson run's request-lifecycle + "
                         "engine-phase Chrome trace (Perfetto-loadable "
                         "JSON) to this path")
    ap.add_argument("--metrics-json", default=None,
                    help="export the Poisson run engine's full metrics-"
                         "registry snapshot to this path")
    ap.add_argument("--evaluators", action="store_true",
                    help="also run the evaluator latency microbench "
                         "(always on in full mode; ~1M-element tensors)")
    ap.add_argument("--sharded-subprocess", action="store_true",
                    help=argparse.SUPPRESS)  # internal: bench_sharded child
    ap.add_argument("--prefix-subprocess", action="store_true",
                    help=argparse.SUPPRESS)  # internal: prefix tp child
    ap.add_argument("--kvq-subprocess", action="store_true",
                    help=argparse.SUPPRESS)  # internal: kv_quant tp child
    args = ap.parse_args(argv)

    if args.sharded_subprocess:
        print(_SHARDED_MARKER + json.dumps(_bench_sharded_inner(args.smoke)))
        return 0
    if args.prefix_subprocess:
        print(_PREFIX_MARKER + json.dumps(_bench_prefix_tp_inner(args.smoke)))
        return 0
    if args.kvq_subprocess:
        print(_KVQ_MARKER + json.dumps(_bench_kvq_tp_inner(args.smoke)))
        return 0

    cfg = _cfg(args.smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    res = bench(cfg, params, args.smoke)
    res["meta"] = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        # the throughput benches above run the unsharded engine: one data
        # row per slot, no model-axis mesh
        "tp": 1,
        "axis_sizes": {"data": jax.device_count(), "model": 1},
        # ...and an unquantized f32 pool; the quantized-KV plane is
        # measured (and gated) in the dedicated kv_quant section below
        "kv_quant": "none",
    }
    res["poisson"] = bench_poisson(cfg, params, args.smoke,
                                   trace_out=args.trace_out,
                                   metrics_json=args.metrics_json)
    res["mixed_chunked"] = bench_mixed_chunked(cfg, params, args.smoke)
    res["host_overhead_1slot"] = bench_host_overhead(cfg, params, args.smoke)
    res["saturation"] = bench_saturation(cfg, params)
    res["sharded"] = bench_sharded(args.smoke)
    res["prefix_cache"] = bench_prefix_cache(cfg, params, args.smoke)
    res["kv_quant"] = bench_kv_quant(cfg, params, args.smoke)
    if args.evaluators or not args.smoke:
        rows: list = []
        run(rows, n=1 << 16 if args.smoke else 1_000_000,
            reps=3 if args.smoke else 5)
        res["evaluator_us"] = {name.split("/", 1)[1]: value
                               for name, value, _ in rows}
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    for impl in IMPL_KEYS:
        r = res["impls"][impl]
        print(f"[serving] {impl}: "
              f"{json.dumps({k: v['tok_s'] for k, v in r['slots'].items()})} "
              f"tok/s, x{r['speedup_8_over_1']} at 8 slots")
    print(f"[serving] wrote {args.out}")

    if not args.no_check and res["mode"] == "smoke":
        bad = check_thresholds(res)
        if bad:
            for name, value, limit in bad:
                # tok/s metrics gate on a lower bound; transient/* entries
                # are byte-valued relation checks — keep the message generic
                print(f"SERVING REGRESSION: {name} = {value:.6g} "
                      f"(limit {limit:.6g})", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
