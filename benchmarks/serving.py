"""Serving throughput benchmark + regression gate: decode tok/s vs slot
count — dense, paged-gather, and paged-pallas (the block-walking
paged-attention kernel) side by side.

The ServeEngine issues exactly one jitted decode per step, so slot count
should buy near-linear decode throughput on dispatch-bound hosts; the paged
engine must deliver the same tokens from a block pool instead of dense
per-slot buffers without giving that throughput back. This benchmark
measures all three decode planes and **fails the build** when they
regress: steady-state decode tok/s at slots in {1, 4, 8} per impl, every
configuration serving the same request workload per slot, written to
BENCH_serving.json:

    {"impls": {"dense": {"slots": {"1": {"tok_s": ...}, ...}, ...},
               "paged": {..., "pool": {"peak_blocks": ...}},
               "paged_pallas": {...}},
     "transient": {"64": {"gather": ..., "pallas": ...}, "128": {...}}}

``transient`` records the per-row decode-attend working set in bytes
(kernels.paged_attention.decode_transient_bytes, derived from the same
shapes the kernel's BlockSpecs are built from) at two max_len values: the
gather path must scale linearly with max_len, the pallas path must NOT
scale at all — that invariance is gated below, which is the benchmark's
teeth for the kernel (on this CPU container the kernel runs in interpret
mode, so its *absolute* tok/s measures the interpreter, not the datapath;
it is recorded for visibility but the gather-class tok/s gates are the
perf contract and the transient metric is the kernel's).

Like benchmarks/accuracy.py, the gate is a hard CI failure, not a record:
every metric in BASELINES must be present (a renamed metric must not
silently disable its gate) and must stay above
``max(FLOOR_TOK_S, baseline * (1 - TOLERANCE))``. Baselines are this
revision's smoke numbers on a dev host; the tolerance absorbs CI-runner
noise while still catching a serialized decode loop or a paged gather
going quadratic (both are >2x collapses, far past any plausible jitter).

CLI: ``python benchmarks/serving.py --smoke [--out BENCH_serving.json]
[--no-check]`` — smoke uses a smaller model + shorter generations for CI;
the nightly workflow runs the full (non-smoke) mode and uploads the
artifact. Timing excludes compile: a warm-up pass on the *same* engine
compiles prefill + decode before the measured pass (jit caches are
per-engine, so a throwaway warm-up engine would not help).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

SLOT_COUNTS = (1, 4, 8)
#: result key -> (kv_impl, paged_attend_impl) engine configuration
IMPLS = {
    "dense": ("dense", "gather"),
    "paged": ("paged", "gather"),
    "paged_pallas": ("paged", "pallas"),
}
IMPL_KEYS = tuple(IMPLS)
#: max_len values the transient working-set metric is recorded at; the
#: pallas entry must be EQUAL at both (no max_len scaling), the gather
#: entry must grow with max_len.
TRANSIENT_MAX_LENS = (64, 128)

#: Smoke-mode tok/s baselines for this revision (idle dev host, CPU). The
#: gate fails a metric below max(FLOOR_TOK_S, baseline * (1 - TOLERANCE))
#: and fails outright when a metric disappears from the results. Absolute
#: tok/s scales with the runner, so the tolerance is wide; the
#: host-invariant teeth are the speedup ratios below.
BASELINES = {
    "dense/1": 168.0,
    "dense/4": 570.0,
    "dense/8": 615.0,
    "paged/1": 210.0,
    "paged/4": 484.0,
    "paged/8": 679.0,
    # interpret-mode kernel numbers: on CPU these measure the Pallas
    # interpreter, not the datapath (see module docstring) — on this dev
    # host the kernel lane still beats the gather lane (it skips the
    # max_len-sized gather materialization), and the wide tolerance below
    # absorbs the rest.
    "paged_pallas/1": 248.0,
    "paged_pallas/4": 513.0,
    "paged_pallas/8": 516.0,
}
TOLERANCE = 0.9         # absolute tok/s soaks up runner-class differences
                        # (a 2-vCPU CI box can be ~5x slower than the dev
                        # host); the collapse classes these still catch —
                        # compile-in-measurement, quadratic gathers — are
                        # >20x, and serialization is caught host-invariantly
                        # by the speedup-ratio gate below
FLOOR_TOK_S = 2.0       # below this the serving loop is broken, not slow
#: 8 slots must beat 1 slot by at least this factor per impl — a RATIO, so
#: it holds on any host speed. One decode dispatch per step buys ~3.5-4x
#: here; a relapse to per-slot dispatch (or a paged gather going quadratic
#: in slots) collapses it to ~1 and fails regardless of runner class.
MIN_SPEEDUP_8_OVER_1 = 1.5
#: the ratio gate applies to the gather-class impls; the interpret-mode
#: kernel's scaling reflects interpreter overhead (grid size grows with
#: slots), so its gates are the tok/s floor + the transient invariance.
SPEEDUP_IMPLS = ("dense", "paged")


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="serve-bench-smoke", family="dense",
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=384, vocab_size=1024, act_impl="exact",
            rope_theta=1e4, dtype="float32",
        )
    return ModelConfig(
        name="serve-bench", family="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        d_ff=768, vocab_size=4096, act_impl="cordic_fixed",
        rope_theta=1e4, dtype="float32",
    )


def _requests(cfg, n: int, max_new: int, plen: int = 8):
    # fixed prompt length: one prefill bucket, decode dominates the timing
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve_once(eng, cfg, *, requests_per_slot: int, max_new: int):
    """One timed serve pass on an existing engine. The warm-up and the
    measured pass MUST share the engine: each ServeEngine wraps its own
    jax.jit objects (that per-instance cache is what compile_counts()
    measures), so a throwaway warm-up engine would leave every compile
    inside the measured wall time."""
    reqs = _requests(cfg, eng.slots * requests_per_slot, max_new)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    steps = 0
    while eng.step():
        steps += 1
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    assert all(r.done for r in reqs)
    return toks, steps, wall


def bench(smoke: bool) -> dict:
    cfg = _cfg(smoke)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    requests_per_slot = 2
    max_new = 8 if smoke else 32
    sampling = SamplingParams(greedy=True)

    impls = {}
    for impl_key, (kv_impl, attend_impl) in IMPLS.items():
        per_slots = {}
        pool = None
        for slots in SLOT_COUNTS:
            eng = ServeEngine(cfg, params, slots=slots, max_len=64,
                              sampling=sampling, kv_impl=kv_impl,
                              paged_attend_impl=attend_impl)
            # warm-up pass compiles prefill + the batched decode for this
            # slot count; the measured pass then times steady-state serving
            _serve_once(eng, cfg, requests_per_slot=1, max_new=2)
            toks, steps, wall = _serve_once(
                eng, cfg, requests_per_slot=requests_per_slot,
                max_new=max_new)
            per_slots[str(slots)] = {
                "tok_s": round(toks / wall, 2),
                "tokens": toks,
                "engine_steps": steps,
                "decode_dispatches": steps,
                "wall_s": round(wall, 3),
            }
            if eng.pager is not None:
                st = eng.pager.stats()
                pool = {"block_len": eng.block_len,
                        "num_blocks": st.num_blocks,
                        "peak_blocks": st.peak_in_use,
                        "dense_equiv_blocks": slots * eng.max_blocks}
            print(f"[serving] impl={impl_key} slots={slots}: {toks} tok / "
                  f"{steps} steps / {wall:.2f}s = {toks / wall:.1f} tok/s")

        rates = [per_slots[str(s)]["tok_s"] for s in SLOT_COUNTS]
        impls[impl_key] = {
            "slots": per_slots,
            "monotone": all(a < b for a, b in zip(rates, rates[1:])),
            "speedup_8_over_1": round(rates[-1] / rates[0], 2),
        }
        if pool is not None:
            impls[impl_key]["pool"] = pool

    # transient decode-attend working set per row (bytes), recorded at two
    # max_len values so the gate can assert the kernel path does not scale
    from repro.kernels import paged_attention as PA

    transient = {
        str(ml): {
            "gather": PA.decode_transient_bytes(cfg, max_len=ml,
                                                block_len=16, impl="gather"),
            "pallas": PA.decode_transient_bytes(cfg, max_len=ml,
                                                block_len=16, impl="pallas"),
        }
        for ml in TRANSIENT_MAX_LENS
    }

    return {
        "model": cfg.name,
        "mode": "smoke" if smoke else "full",
        "slot_counts": list(SLOT_COUNTS),
        "impl_configs": {k: {"kv_impl": kv, "paged_attend_impl": at}
                         for k, (kv, at) in IMPLS.items()},
        "impls": impls,
        "transient": transient,
    }


def check_thresholds(res: dict) -> list:
    """Returns [(metric, value, limit)] for every regressed metric; a
    BASELINES key missing from the results is itself a failure."""
    bad = []
    for key in sorted(BASELINES):
        impl, slots = key.split("/")
        limit = max(FLOOR_TOK_S, BASELINES[key] * (1.0 - TOLERANCE))
        try:
            value = res["impls"][impl]["slots"][slots]["tok_s"]
        except KeyError:
            bad.append((key, float("nan"), limit))
            continue
        if value < limit:
            bad.append((key, value, limit))
    for impl in SPEEDUP_IMPLS:
        key = f"{impl}/speedup_8_over_1"
        try:
            value = res["impls"][impl]["speedup_8_over_1"]
        except KeyError:
            bad.append((key, float("nan"), MIN_SPEEDUP_8_OVER_1))
            continue
        if value < MIN_SPEEDUP_8_OVER_1:
            bad.append((key, value, MIN_SPEEDUP_8_OVER_1))
    bad.extend(check_transient(res))
    return bad


def check_transient(res: dict) -> list:
    """The kernel-path acceptance gate: the recorded per-row transient
    working set must be max_len-INVARIANT for the pallas attend, scale
    with max_len for the gather attend, and sit below gather at every
    recorded max_len. A missing entry is itself a failure."""
    bad = []
    try:
        tr = {ml: {im: float(res["transient"][str(ml)][im])
                   for im in ("gather", "pallas")}
              for ml in TRANSIENT_MAX_LENS}
    except KeyError:
        return [("transient/<missing>", float("nan"), float("nan"))]
    lo, hi = min(TRANSIENT_MAX_LENS), max(TRANSIENT_MAX_LENS)
    if tr[hi]["pallas"] != tr[lo]["pallas"]:
        bad.append((f"transient/pallas@{hi}==@{lo}", tr[hi]["pallas"],
                    tr[lo]["pallas"]))
    if tr[hi]["gather"] <= tr[lo]["gather"]:
        bad.append((f"transient/gather@{hi}>@{lo}", tr[hi]["gather"],
                    tr[lo]["gather"]))
    for ml in TRANSIENT_MAX_LENS:
        if tr[ml]["pallas"] >= tr[ml]["gather"]:
            bad.append((f"transient/pallas<gather@{ml}", tr[ml]["pallas"],
                        tr[ml]["gather"]))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--no-check", action="store_true",
                    help="record only; skip the regression-threshold gate")
    args = ap.parse_args(argv)

    res = bench(args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    for impl in IMPL_KEYS:
        r = res["impls"][impl]
        print(f"[serving] {impl}: "
              f"{json.dumps({k: v['tok_s'] for k, v in r['slots'].items()})} "
              f"tok/s, x{r['speedup_8_over_1']} at 8 slots")
    print(f"[serving] wrote {args.out}")

    if not args.no_check and res["mode"] == "smoke":
        bad = check_thresholds(res)
        if bad:
            for name, value, limit in bad:
                # tok/s metrics gate on a lower bound; transient/* entries
                # are byte-valued relation checks — keep the message generic
                print(f"SERVING REGRESSION: {name} = {value:.6g} "
                      f"(limit {limit:.6g})", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
