"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows:
    accuracy.py     — Table 2 (MAE comparison, unit + wide domains)
    resources.py    — Table 1 (resource model: op counts, ROM, VMEM)
    serving.py      — evaluator latency microbench (host CPU) + integer
                      path (serving.run; the engine-level Poisson/TTFT
                      benchmark is serving.main -> BENCH_serving.json)
    convergence.py  — Sec. 3.1 convergence behaviour & iteration tradeoff

Roofline/dry-run numbers are produced by ``repro.launch.dryrun`` /
``repro.launch.roofline`` (they need the 512-device env) — see EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import accuracy, convergence, resources, serving

    rows: list = []
    for mod in (accuracy, resources, convergence, serving):
        t0 = time.time()
        mod.run(rows)
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)

    print("name,value,derived")
    for name, value, derived in rows:
        if isinstance(value, float):
            print(f"{name},{value:.6g},{derived}")
        else:
            print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
