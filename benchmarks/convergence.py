"""Convergence-behaviour benchmark (paper Sec. 3.1 figures): residual angle
vs iteration count for mixed-radix vs pure radix-2 schedules, and the MAE
vs iteration-budget tradeoff — the quantitative version of the paper's
"faster convergence without scale-factor compensation" claim.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cordic as C
from repro.core import sigmoid as S
from repro.core.errors import error_stats


def run(csv_rows: list) -> None:
    z = jnp.linspace(-0.5, 0.5, 20001, dtype=jnp.float32)

    # residual after the R2 stage and after the full MR pipeline
    res_r2 = float(jnp.max(C.r2_residual_f(z)))
    _, _, zr = C.mr_hrc_f(z)
    res_mr = float(jnp.max(jnp.abs(zr)))
    csv_rows.append(("convergence/r2_stage_max_residual", res_r2,
                     "paper: ~0.0061"))
    csv_rows.append(("convergence/mr_hrc_max_residual", res_mr,
                     "after radix-4 refinement"))
    csv_rows.append(("convergence/r4_admissible_range",
                     C.PAPER_SCHEDULE.r4_range, "paper: 0.0104"))

    # accuracy vs total iteration budget: MR vs pure R2 at equal budgets
    for n_hrc_r2, r4 in ((8, (4, 5, 6, 7)), (8, ())):
        for lvc_n in (9, 14):
            sched = C.MRSchedule(r2_js=tuple(range(2, 2 + n_hrc_r2)), r4_js=r4,
                                 lvc_js=tuple(range(1, lvc_n + 1)))
            st = error_stats(jax.jit(lambda x, s=sched: S.sigmoid_cordic_fixed(x, s)),
                             S.sigmoid_exact, -1, 1)
            tag = f"r2x{n_hrc_r2}+r4x{len(r4)}+lvc{lvc_n}"
            csv_rows.append((f"convergence/mae/{tag}", st["mae"],
                             f"iters={n_hrc_r2 + len(r4) + lvc_n}"))

    # pure radix-2 needs the textbook repeats to reach the same MAE
    st = error_stats(jax.jit(S.sigmoid_r2_cordic_fixed), S.sigmoid_exact, -1, 1)
    csv_rows.append(("convergence/mae/r2_baseline_with_repeats", st["mae"],
                     f"iters={C.R2_BASELINE_SCHEDULE.num_iterations()}"))
