"""Paper Table 2 reproduction: mean absolute error of sigmoid evaluators.

Two regimes are reported:
  (a) the paper's own regime — 16-bit fixed point, inputs in [-1, 1];
  (b) a wide regime [-6, 6] where each baseline uses its natural segment
      domain and the proposed pipeline uses the dyadic range extension —
      this matches how the prior works' published MAEs were measured.

Beyond the paper, the generalized-engine function library (exp, log,
division, sin/cos, softplus/elu/gelu) and the fused CORDIC softmax kernel
are benchmarked against their XLA-transcendental references.

CLI: ``python benchmarks/accuracy.py --smoke [--out BENCH_accuracy.json]``
runs the CI smoke subset (sigmoid/tanh/exp/log-softmax/softmax MAE plus the
Q2.14/Q2.20/Q2.29 format sweep), writes JSON, and **exits non-zero** when
any metric regresses past its stored threshold — the accuracy gate is a
hard CI failure, not just a record.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sigmoid as S
from repro.core.cordic import MRSchedule
from repro.core.errors import error_stats

#: Regression gates: the BENCH_accuracy.json values this revision produces,
#: times a 1.15 safety margin (the metrics are deterministic — fixed grids
#: and PRNG seeds — so any drift past the margin is a real datapath change).
THRESHOLDS = {
    "sigmoid_mae": 6.45e-05 * 1.15,
    "tanh_mae": 1.03e-04 * 1.15,
    "exp_mae": 9.83e-04 * 1.15,
    "softmax_max_abs": 3.15e-04 * 1.15,
    "log_softmax_max_abs": 1.2e-03 * 1.15,
    "fmt_sweep/exp_mae_q2_14": 9.83e-04 * 1.15,
    "fmt_sweep/exp_mae_q2_20": 1.80e-05 * 1.15,
    "fmt_sweep/exp_mae_q2_29": 6.60e-06 * 1.15,
    "fmt_sweep/log_mae_q2_14": 1.78e-04 * 1.15,
    "fmt_sweep/log_mae_q2_20": 3.73e-06 * 1.15,
    "fmt_sweep/log_mae_q2_29": 3.10e-08 * 1.15,
    "fmt_sweep/tanh_mae_q2_14": 1.02e-04 * 1.15,
    "fmt_sweep/tanh_mae_q2_20": 2.00e-06 * 1.15,
    "fmt_sweep/tanh_mae_q2_29": 7.00e-09 * 1.15,
}


def run(csv_rows: list) -> None:
    # --- regime (a): paper domain [-1, 1] ---------------------------------
    for name, fn in S.TABLE2_METHODS.items():
        st = error_stats(jax.jit(fn), S.sigmoid_exact, -1, 1)
        csv_rows.append((f"table2/unit_domain/{name}", st["mae"],
                         f"max={st['max']:.3e}"))

    # paper-provenance row: LVC truncated at j=9 reproduces the printed MAE
    sched9 = MRSchedule(lvc_js=tuple(range(1, 10)))
    st = error_stats(jax.jit(lambda x: S.sigmoid_cordic_fixed(x, sched9)),
                     S.sigmoid_exact, -1, 1)
    csv_rows.append(("table2/unit_domain/proposed_lvc9 (paper 4.23e-4)",
                     st["mae"], f"max={st['max']:.3e}"))

    # --- fixed-point design space: angle-register guard bits + rounding ----
    from repro.core.cordic import FixedConfig

    for guard in (0, 2, 4):
        for rnd in ("trunc", "nearest"):
            cfg = FixedConfig(z_guard=guard, shift_round=rnd)
            st = error_stats(
                jax.jit(lambda x, c=cfg: S.sigmoid_cordic_fixed(x, cfg=c)),
                S.sigmoid_exact, -1, 1)
            csv_rows.append((f"table2/design_space/guard{guard}_{rnd}",
                             st["mae"], f"max={st['max']:.3e}"))

    # --- regime (b): wide domain [-6, 6] ----------------------------------
    wide = {
        "proposed_mr_hrc_wide": lambda x: S.sigmoid_cordic_wide(x),
        "pwl_16seg_wide [7]": lambda x: S.sigmoid_pwl_fixed(x, 16, -6, 6),
        "pwl_8seg_wide [11]": lambda x: S.sigmoid_pwl_fixed(x, 8, -6, 6),
        "poly2_8seg_wide [2]/[8]": lambda x: S.sigmoid_poly2_fixed(x, 8, -6, 6),
        "lut_256_wide [10]": lambda x: S.sigmoid_lut_fixed(x, 256, -6, 6),
        "lut_64_wide [10]": lambda x: S.sigmoid_lut_fixed(x, 64, -6, 6),
    }
    for name, fn in wide.items():
        st = error_stats(jax.jit(fn), S.sigmoid_exact, -6, 6)
        csv_rows.append((f"table2/wide_domain/{name}", st["mae"],
                         f"max={st['max']:.3e}"))

    # --- generalized engine: beyond-sigmoid function library ---------------
    from repro.cordic_engine import functions as F

    engine_rows = [
        ("exp[-4,4]", F.exp_fixed, jnp.exp, -4, 4),
        ("log[0.1,10]", F.log_fixed, jnp.log, 0.1, 10),
        ("reciprocal[0.1,10]", F.reciprocal_fixed, lambda x: 1.0 / x, 0.1, 10),
        ("sin[-pi,pi]", F.sin_fixed, jnp.sin, -np.pi, np.pi),
        ("cos[-pi,pi]", F.cos_fixed, jnp.cos, -np.pi, np.pi),
        ("softplus[-6,6]", F.softplus_fixed, jax.nn.softplus, -6, 6),
        ("elu[-6,6]", F.elu_fixed, jax.nn.elu, -6, 6),
        ("gelu_erf[-6,6]", F.gelu_erf_fixed,
         lambda x: jax.nn.gelu(x, approximate=False), -6, 6),
    ]
    for name, fn, ref, lo, hi in engine_rows:
        st = error_stats(jax.jit(fn), ref, lo, hi)
        csv_rows.append((f"engine/{name}", st["mae"], f"max={st['max']:.3e}"))

    # fused softmax kernel vs jax.nn.softmax (interpret mode on CPU)
    csv_rows.append(("engine/softmax_kernel(64x512)", _softmax_max_err(),
                     "max-abs vs jax.nn.softmax"))


def _softmax_max_err(rows: int = 64, cols: int = 512) -> float:
    from repro.kernels import ops as kops

    logits = jax.random.normal(jax.random.PRNGKey(0), (rows, cols)) * 4.0
    got = np.asarray(kops.softmax(logits), np.float64)
    want = np.asarray(jax.nn.softmax(logits), np.float64)
    return float(np.abs(got - want).max())


def _log_softmax_max_err(rows: int = 64, cols: int = 512) -> float:
    from repro.kernels import ops as kops

    logits = jax.random.normal(jax.random.PRNGKey(1), (rows, cols)) * 4.0
    got = np.asarray(kops.log_softmax(logits), np.float64)
    want = np.asarray(jax.nn.log_softmax(logits), np.float64)
    return float(np.abs(got - want).max())


def format_sweep() -> dict:
    """MAE of exp/log/tanh at each Q2.14/Q2.20/Q2.29 format profile —
    the ROADMAP's wider-format accuracy study, recorded per revision."""
    from repro.cordic_engine import functions as F

    res = {}
    for name, p in F.FORMAT_PROFILES.items():
        x = jnp.linspace(-4.0, 4.0, 4001, dtype=jnp.float32)
        res[f"fmt_sweep/exp_mae_{name}"] = float(np.abs(
            np.asarray(F.exp_fixed(x, sched=p.rotation, cfg=p.cfg), np.float64)
            - np.exp(np.asarray(x, np.float64))).mean())
        xl = jnp.asarray(np.geomspace(0.1, 10.0, 4001), jnp.float32)
        res[f"fmt_sweep/log_mae_{name}"] = float(np.abs(
            np.asarray(F.log_fixed(xl, sched=p.vectoring, cfg=p.cfg), np.float64)
            - np.log(np.asarray(xl, np.float64))).mean())
        z = jnp.linspace(-0.5, 0.5, 4001, dtype=jnp.float32)
        res[f"fmt_sweep/tanh_mae_{name}"] = float(np.abs(
            np.asarray(S.tanh_cordic_fixed(z, p.pipeline, p.cfg), np.float64)
            - np.tanh(np.asarray(z, np.float64))).mean())
    return res


def check_thresholds(res: dict) -> list:
    """Returns [(metric, value, threshold)] for every regressed metric.

    A THRESHOLDS key missing from the results is itself a failure — a
    renamed/removed metric must not silently disable its gate."""
    bad = [(k, res[k], THRESHOLDS[k])
           for k in sorted(THRESHOLDS) if k in res and res[k] > THRESHOLDS[k]]
    bad += [(k, float("nan"), THRESHOLDS[k])
            for k in sorted(THRESHOLDS) if k not in res]
    return bad


def smoke(out_path: str, check: bool = True) -> dict:
    """CI smoke subset: MAE for sigmoid/tanh/exp, softmax/log-softmax
    max-abs, and the wider-format sweep.

    Written as JSON so the CI run leaves a machine-readable accuracy record
    (BENCH_accuracy.json) next to the logs. With ``check`` (the default)
    any metric above its stored threshold aborts with a non-zero exit.
    """
    from repro.cordic_engine import functions as F

    res = {
        "sigmoid_mae": error_stats(jax.jit(S.sigmoid_cordic_fixed),
                                   S.sigmoid_exact, -1, 1)["mae"],
        "tanh_mae": error_stats(jax.jit(S.tanh_cordic_fixed),
                                S.tanh_exact, -0.5, 0.5)["mae"],
        "exp_mae": error_stats(jax.jit(F.exp_fixed), jnp.exp, -4, 4)["mae"],
        "softmax_max_abs": _softmax_max_err(),
        "log_softmax_max_abs": _log_softmax_max_err(),
    }
    res.update(format_sweep())
    with open(out_path, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    if check:
        bad = check_thresholds(res)
        if bad:
            for name, value, limit in bad:
                print(f"ACCURACY REGRESSION: {name} = {value:.6g} "
                      f"> threshold {limit:.6g}", file=sys.stderr)
            raise SystemExit(1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI smoke subset and write JSON")
    ap.add_argument("--no-check", action="store_true",
                    help="record only; skip the regression-threshold gate")
    ap.add_argument("--out", default="BENCH_accuracy.json")
    args = ap.parse_args()
    if args.smoke:
        print(json.dumps(smoke(args.out, check=not args.no_check),
                         indent=2, sort_keys=True))
    else:
        rows: list = []
        run(rows)
        for name, value, derived in rows:
            print(f"{name},{value:.6g},{derived}")
