"""Paper Table 2 reproduction: mean absolute error of sigmoid evaluators.

Two regimes are reported:
  (a) the paper's own regime — 16-bit fixed point, inputs in [-1, 1];
  (b) a wide regime [-6, 6] where each baseline uses its natural segment
      domain and the proposed pipeline uses the dyadic range extension —
      this matches how the prior works' published MAEs were measured.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import sigmoid as S
from repro.core.cordic import MRSchedule
from repro.core.errors import error_stats


def run(csv_rows: list) -> None:
    # --- regime (a): paper domain [-1, 1] ---------------------------------
    for name, fn in S.TABLE2_METHODS.items():
        st = error_stats(jax.jit(fn), S.sigmoid_exact, -1, 1)
        csv_rows.append((f"table2/unit_domain/{name}", st["mae"],
                         f"max={st['max']:.3e}"))

    # paper-provenance row: LVC truncated at j=9 reproduces the printed MAE
    sched9 = MRSchedule(lvc_js=tuple(range(1, 10)))
    st = error_stats(jax.jit(lambda x: S.sigmoid_cordic_fixed(x, sched9)),
                     S.sigmoid_exact, -1, 1)
    csv_rows.append(("table2/unit_domain/proposed_lvc9 (paper 4.23e-4)",
                     st["mae"], f"max={st['max']:.3e}"))

    # --- fixed-point design space: angle-register guard bits + rounding ----
    from repro.core.cordic import FixedConfig

    for guard in (0, 2, 4):
        for rnd in ("trunc", "nearest"):
            cfg = FixedConfig(z_guard=guard, shift_round=rnd)
            st = error_stats(
                jax.jit(lambda x, c=cfg: S.sigmoid_cordic_fixed(x, cfg=c)),
                S.sigmoid_exact, -1, 1)
            csv_rows.append((f"table2/design_space/guard{guard}_{rnd}",
                             st["mae"], f"max={st['max']:.3e}"))

    # --- regime (b): wide domain [-6, 6] ----------------------------------
    wide = {
        "proposed_mr_hrc_wide": lambda x: S.sigmoid_cordic_wide(x),
        "pwl_16seg_wide [7]": lambda x: S.sigmoid_pwl_fixed(x, 16, -6, 6),
        "pwl_8seg_wide [11]": lambda x: S.sigmoid_pwl_fixed(x, 8, -6, 6),
        "poly2_8seg_wide [2]/[8]": lambda x: S.sigmoid_poly2_fixed(x, 8, -6, 6),
        "lut_256_wide [10]": lambda x: S.sigmoid_lut_fixed(x, 256, -6, 6),
        "lut_64_wide [10]": lambda x: S.sigmoid_lut_fixed(x, 64, -6, 6),
    }
    for name, fn in wide.items():
        st = error_stats(jax.jit(fn), S.sigmoid_exact, -6, 6)
        csv_rows.append((f"table2/wide_domain/{name}", st["mae"],
                         f"max={st['max']:.3e}"))
