"""Paper Table 1 analog: hardware-resource model of each evaluator.

FPGA slices/DSPs do not exist on TPU; the transferable quantities are
(DESIGN.md section 2): per-evaluation op counts (adds/shifts/compares —
the paper's own currency, since its datapath is adder-dominated), ROM bits,
iteration/pipeline depth, and — for the Pallas kernel — HLO op statistics
and VMEM tile footprint from the compiled kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cordic as C


def _counts(sched):
    return C.shift_add_op_count(sched)


def run(csv_rows: list) -> None:
    mr = _counts(C.PAPER_SCHEDULE)
    r2 = _counts(C.R2_BASELINE_SCHEDULE)

    for name, r in (("proposed_mr_hrc", mr), ("r2_cordic [9]", r2)):
        csv_rows.append((f"table1/{name}/iterations", r["iterations"], ""))
        csv_rows.append((f"table1/{name}/adders", r["adds"], ""))
        csv_rows.append((f"table1/{name}/shifts", r["shifts"], ""))
        csv_rows.append((f"table1/{name}/compares", r["compares"], ""))
        csv_rows.append((f"table1/{name}/rom_bits", r["rom_bits"], ""))
        csv_rows.append((f"table1/{name}/multipliers", r["multipliers"],
                         "DSP-free datapath"))

    # mixed-radix saving — the paper's Table 1 headline, in iteration terms
    save = 1.0 - mr["iterations"] / r2["iterations"]
    csv_rows.append(("table1/mixed_radix_iteration_saving", round(save, 4),
                     f"{mr['iterations']} vs {r2['iterations']} stages"))
    add_save = 1.0 - mr["adds"] / r2["adds"]
    csv_rows.append(("table1/mixed_radix_adder_saving", round(add_save, 4), ""))

    # Pallas kernel: HLO ops + VMEM footprint of the compiled (interpret) call
    from repro.kernels import cordic_act as K

    x = jnp.zeros((256, 1024), jnp.float32)
    lowered = jax.jit(lambda v: K.act_2d(v, "sigmoid", interpret=True)).lower(x)
    txt = lowered.as_text()
    n_ops = sum(1 for ln in txt.splitlines() if "= " in ln)
    csv_rows.append(("table1/pallas_kernel/stablehlo_lines", n_ops, "256x1024 tile"))
    blk = K.DEFAULT_BLOCK
    vmem = blk[0] * blk[1] * 4
    csv_rows.append(("table1/pallas_kernel/vmem_tile_bytes", vmem,
                     f"block={blk}, ~6 live tiles ~= {6 * vmem / 2**20:.0f} MiB"))
