"""Single source of truth for the serving-benchmark section gates.

Both consumers run the SAME checker functions:

- ``benchmarks/serving.py`` imports them into its internal
  ``check_thresholds`` gate (the benchmark fails its own run), and
- CI's belt-and-braces steps invoke this file directly against the
  uploaded artifact::

      python benchmarks/check_bench.py --bench BENCH_serving.json \\
          --sections poisson,mixed_chunked,prefix_cache,kv_quant

  so the tier-1 and nightly lanes can no longer drift from the
  benchmark's own thresholds (they used to carry near-duplicate inline
  ``python - <<EOF`` blocks with hand-copied constants).

Every checker takes the full results dict and returns a list of
``(metric_path, observed_value, limit)`` tuples — empty means the
section passes; a missing section is itself a failure (a renamed
section must not silently disable its gate). Pure stdlib on purpose:
the CI step that runs this against an artifact must not need jax or
numpy to be importable.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

#: poisson-section keys the smoke gate requires present AND finite
POISSON_GATED = ("ttft_ms.p50", "ttft_ms.p99", "tpot_ms.p50",
                 "tpot_ms.p99", "goodput_tok_s")

#: minimum short-request p99-TTFT improvement the chunked engine must
#: deliver over the unchunked engine on the same mixed trace — a same-
#: process ratio, host-speed-invariant. The workload is built to deliver
#: a wide margin (long prefills dominate the unchunked iteration time);
#: 2x is the contract floor, not the expectation.
MIN_SHORT_TTFT_SPEEDUP = 2.0

#: prefix-cache gate: prefill tokens computed AND pool peak-blocks must
#: each drop by at least this factor cache-on vs cache-off on the
#: shared-system-prompt trace. A RATIO of two runs in one process, so it
#: holds on any runner class; the observed smoke collapse is ~7x
#: (prefill tokens) and ~2.5x (peak blocks).
MIN_PREFIX_COLLAPSE = 2.0

#: below this tok/s the serving loop is broken, not slow (shared with
#: serving.py's absolute-throughput gate)
FLOOR_TOK_S = 2.0

#: kv_quant gate: resident pool bytes must drop by at least this factor
#: vs the unquantized pool at the same block count. int8 lands ~3.9x
#: (4-byte f32 -> 1-byte codes, minus the f32 scale pools); q2_14's
#: int16 codes cap it just under 2x, so its floor is set to what the
#: format can deliver rather than a round number.
MIN_KVQ_BYTES_RATIO = {"int8": 2.0, "q2_14": 1.9}

#: kv_quant gate: greedy-token match rate vs the unquantized engine on
#: the same trace. Floors with wide headroom under the measured smoke
#: numbers (int8 0.875, q2_14 1.0 on this revision's seeded trace), NOT
#: expectations: the smoke model is random-weight (near-uniform logits,
#: so int8's quantization noise flips far more argmaxes than it would
#: on a trained model), and the run is seeded/deterministic, so the
#: measured rate is stable per revision. q2_14 (the paper's format)
#: reproduces the unquantized stream exactly even here.
MIN_KVQ_MATCH_RATE = {"int8": 0.50, "q2_14": 0.90}


def check_poisson(res: dict) -> list:
    """Presence/finiteness gate for the open-loop Poisson latency
    section: the metrics the roadmap work is steered by must exist and
    be finite in the artifact. Latency magnitudes are host-dependent,
    so magnitudes are deliberately not thresholded."""
    bad = []
    for key in POISSON_GATED + ("pool.peak_blocks",):
        path = f"poisson.{key}"
        node = res
        try:
            for part in path.split("."):
                node = node[part]
        except (KeyError, TypeError):
            bad.append((path, float("nan"), "present"))
            continue
        try:
            v = float(node)
        except (TypeError, ValueError):
            bad.append((path, float("nan"), "numeric"))
            continue
        if not math.isfinite(v):
            bad.append((path, v, "finite"))
    return bad


def check_mixed_chunked(res: dict) -> list:
    """The chunked-prefill gate: bit-identical tokens AND the short-
    request p99 TTFT speedup floor. Missing section = failure."""
    sec = res.get("mixed_chunked")
    if not isinstance(sec, dict):
        return [("mixed_chunked/<missing>", float("nan"), float("nan"))]
    bad = []
    if sec.get("tokens_identical") != 1:
        bad.append(("mixed_chunked/tokens_identical",
                    float(sec.get("tokens_identical", float("nan"))), 1.0))
    spd = float(sec.get("short_ttft_p99_speedup", float("nan")))
    if not (spd >= MIN_SHORT_TTFT_SPEEDUP):
        bad.append(("mixed_chunked/short_ttft_p99_speedup", spd,
                    MIN_SHORT_TTFT_SPEEDUP))
    return bad


def check_sharded(res: dict) -> list:
    """Gate for the tensor-parallel section: the TP=2 engine must emit
    bit-identical tokens to TP=1 and both throughput metrics must exist
    and be finite. Deliberately NOT a speedup gate — forced host-CPU
    shards time-share the same cores, so tok_s_tp2 is a topology
    record, not a performance claim."""
    nan = float("nan")
    sh = res.get("sharded")
    if not isinstance(sh, dict) or "error" in sh:
        return [("sharded/<missing>", nan, nan)]
    bad = []
    if sh.get("tokens_identical") != 1:
        bad.append(("sharded/tokens_identical",
                    float(sh.get("tokens_identical", nan)), 1.0))
    for key in ("tok_s_tp1", "tok_s_tp2"):
        v = sh.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            bad.append((f"sharded/{key}",
                        float(v) if isinstance(v, (int, float)) else nan,
                        0.0))
    return bad


def check_prefix_cache(res: dict) -> list:
    """Gate for the prefix-cache section: bit-identical tokens cache-on
    vs cache-off (TP=1, and TP=1/TP=2 in the sub-trace), and >=
    MIN_PREFIX_COLLAPSE collapse of both prefill tokens and pool peak
    blocks. Missing section = failure."""
    nan = float("nan")
    sec = res.get("prefix_cache")
    if not isinstance(sec, dict):
        return [("prefix_cache/<missing>", nan, nan)]
    bad = []
    if sec.get("tokens_identical") != 1:
        bad.append(("prefix_cache/tokens_identical",
                    float(sec.get("tokens_identical", nan)), 1.0))
    for key in ("prefill_tokens_ratio", "peak_blocks_ratio"):
        v = float(sec.get(key, nan))
        if not (v >= MIN_PREFIX_COLLAPSE):
            bad.append((f"prefix_cache/{key}", v, MIN_PREFIX_COLLAPSE))
    tp = sec.get("tp")
    if not isinstance(tp, dict) or "error" in tp:
        bad.append(("prefix_cache/tp/<missing>", nan, nan))
    else:
        for key in ("tokens_identical_tp1", "tokens_identical_tp2",
                    "tokens_identical_across_tp"):
            if tp.get(key) != 1:
                bad.append((f"prefix_cache/tp/{key}",
                            float(tp.get(key, nan)), 1.0))
    return bad


def check_kv_quant(res: dict) -> list:
    """Gate for the quantized paged-KV section (ROADMAP item 5): per
    format, the resident pool must shrink by the format's bytes-ratio
    floor at matched block count, the greedy token stream must match the
    unquantized engine at or above the stored rate, and the lane must
    still serve above the broken-loop tok/s floor. The int8 stream must
    additionally be bit-identical between the gather and pallas attends
    (dequantization is the same CORDIC multiply either side of the
    kernel boundary) and across TP=1/TP=2 (scales shard with the
    kv-heads cut, so the mesh must not perturb a single token)."""
    nan = float("nan")
    sec = res.get("kv_quant")
    if not isinstance(sec, dict):
        return [("kv_quant/<missing>", nan, nan)]
    bad = []
    fmts = sec.get("formats")
    if not isinstance(fmts, dict):
        return [("kv_quant/formats/<missing>", nan, nan)]
    for fmt in sorted(MIN_KVQ_MATCH_RATE):
        f = fmts.get(fmt)
        if not isinstance(f, dict):
            bad.append((f"kv_quant/formats/{fmt}/<missing>", nan, nan))
            continue
        rate = float(f.get("match_rate", nan))
        if not (rate >= MIN_KVQ_MATCH_RATE[fmt]):
            bad.append((f"kv_quant/{fmt}/match_rate", rate,
                        MIN_KVQ_MATCH_RATE[fmt]))
        ratio = float(f.get("pool_bytes_ratio", nan))
        if not (ratio >= MIN_KVQ_BYTES_RATIO[fmt]):
            bad.append((f"kv_quant/{fmt}/pool_bytes_ratio", ratio,
                        MIN_KVQ_BYTES_RATIO[fmt]))
        tok_s = float(f.get("tok_s", nan))
        if not (tok_s >= FLOOR_TOK_S):
            bad.append((f"kv_quant/{fmt}/tok_s", tok_s, FLOOR_TOK_S))
    if sec.get("pallas_tokens_identical") != 1:
        bad.append(("kv_quant/pallas_tokens_identical",
                    float(sec.get("pallas_tokens_identical", nan)), 1.0))
    tp = sec.get("tp")
    if not isinstance(tp, dict) or "error" in tp:
        bad.append(("kv_quant/tp/<missing>", nan, nan))
    elif tp.get("tokens_identical_across_tp") != 1:
        bad.append(("kv_quant/tp/tokens_identical_across_tp",
                    float(tp.get("tokens_identical_across_tp", nan)), 1.0))
    return bad


#: --sections name -> checker; serving.py's check_thresholds runs the
#: same functions, so adding a section here gates it in BOTH consumers
SECTION_CHECKS = {
    "poisson": check_poisson,
    "mixed_chunked": check_mixed_chunked,
    "sharded": check_sharded,
    "prefix_cache": check_prefix_cache,
    "kv_quant": check_kv_quant,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate recorded serving-benchmark sections (the same "
                    "checkers serving.py runs internally).")
    ap.add_argument("--bench", required=True,
                    help="path to a BENCH_serving*.json artifact")
    ap.add_argument("--sections", required=True,
                    help="comma-separated subset of: "
                         + ", ".join(sorted(SECTION_CHECKS)))
    args = ap.parse_args(argv)

    sections = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [s for s in sections if s not in SECTION_CHECKS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; "
                 f"known: {sorted(SECTION_CHECKS)}")

    with open(args.bench) as f:
        res = json.load(f)

    failures = []
    for s in sections:
        bad = SECTION_CHECKS[s](res)
        status = "OK" if not bad else f"FAIL ({len(bad)})"
        print(f"[check_bench] {s}: {status}")
        failures.extend(bad)
    if failures:
        for name, value, limit in failures:
            lim = limit if isinstance(limit, str) else f"{limit:.6g}"
            print(f"BENCH GATE FAILED: {name} = {value:.6g} (limit {lim})",
                  file=sys.stderr)
        return 1
    print(f"[check_bench] all {len(sections)} section(s) passed "
          f"({args.bench})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
