"""Latency/throughput microbench: CPU wall-time of each evaluator (paper's
Fmax/pipeline-latency analog is structural; here we measure what this host
can measure and derive the TPU-side VPU-op roofline).

Reported per evaluator: us per call on a 1M-element tensor, plus derived
elements/s. The CORDIC fixed path timing on CPU reflects the emulation (26
unrolled integer stages), not TPU VPU throughput — the structural VPU op
count is in resources.py; both are recorded.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sigmoid as S

N = 1_000_000
REPS = 5


def _time(fn, x) -> float:
    fn(x).block_until_ready()  # compile+warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn(x).block_until_ready()
    return (time.perf_counter() - t0) / REPS * 1e6  # us


def run(csv_rows: list) -> None:
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, N), jnp.float32)
    cases = {
        "exact_jnp_sigmoid": jax.jit(S.sigmoid_exact),
        "cordic_float": jax.jit(lambda v: S.sigmoid_cordic_float(v)),
        "cordic_fixed_q2.14": jax.jit(lambda v: S.sigmoid_cordic_fixed(v)),
        "r2_cordic_fixed": jax.jit(lambda v: S.sigmoid_r2_cordic_fixed(v)),
        "pwl_16seg": jax.jit(lambda v: S.sigmoid_pwl_fixed(v, 16)),
        "lut_256": jax.jit(lambda v: S.sigmoid_lut_fixed(v, 256)),
    }
    for name, fn in cases.items():
        us = _time(fn, x)
        csv_rows.append((f"latency/{name}", round(us, 1),
                         f"{N / us:.0f} elem/us-e6; host-CPU measurement"))

    # integer end-to-end path (no float boundary) — the quantized-serving mode
    xq = jnp.asarray(np.random.default_rng(1).integers(-(1 << 14), 1 << 14, N),
                     jnp.int32)
    from repro.core.cordic import sigmoid_mr_q

    us = _time(jax.jit(sigmoid_mr_q), xq)
    csv_rows.append(("latency/cordic_fixed_int_io", round(us, 1),
                     "integer in/out (quantized pipeline)"))
