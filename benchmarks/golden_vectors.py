"""Generate the golden-vector conformance set for the integer datapaths.

Writes one ``.npz`` per function into ``tests/golden/``, each holding an
input-code -> output-code map of the bit-accurate pipeline:

    sigmoid  all 2^16 Q2.14 codes -> sigmoid_mr_q codes (paper pipeline)
    tanh     all 2^16 Q2.14 codes -> tanh_mr_q codes
    exp      all 2^16 angle codes -> cosh+sinh codes of the MR-HRC rotation
             (the e^r core of exp/softmax; deterministic out-of-domain too)
    log      mantissa codes m in [0.5, 1) -> hyperbolic-vectoring
             2*atanh((m-1)/(m+1)) accumulator codes (the log leg)

``--profile q2_20|q2_29|all`` additionally freezes the *wider-format*
profiles (functions.FORMAT_PROFILES: format-sized schedules at 20/29
fraction bits). Their code spaces (2^22 / 2^31) are too large to sweep
exhaustively, so the profile vectors store explicit ``x`` codes alongside
``y``: a full-range stride sweep (every Q2.14-aligned code, i.e. the 2^16
paper-format lattice embedded in the wider format) plus a dense window
around 0 exercising the low-order bits the stride lattice misses.

The files are checked in; tests/test_golden_vectors.py asserts that the
jnp engine path (and the Pallas kernel path, where a kernel entry exists)
reproduces them bit-exactly, so a refactor of the iteration core cannot
silently drift from the paper's published 4.23e-4 MAE behavior — at any
format. Regenerate (only after an *intentional* datapath change) with:

    PYTHONPATH=src python benchmarks/golden_vectors.py [--profile all]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np
import jax.numpy as jnp

from repro.core import cordic as C
from repro.core import fixed_point as fp
from repro.cordic_engine import core as eng
from repro.cordic_engine.functions import FORMAT_PROFILES
from repro.cordic_engine.schedule import HYP_ROTATION, HYP_VECTORING

#: mantissa code range for the log leg: m = code * 2^-14 in [0.5, 1).
LOG_M_LO, LOG_M_HI = 1 << 13, 1 << 14
ONE_Q = 1 << 14

#: dense-window half-width for the profile vectors (low-bit coverage).
DENSE_HALF = 1 << 12


def generate() -> dict:
    """Returns {name: (out_codes int16 array, meta dict)}."""
    all_codes = jnp.arange(-(1 << 15), 1 << 15, dtype=jnp.int32)
    cfg = C.PAPER_FIXED

    sig = np.asarray(C.sigmoid_mr_q(all_codes, C.PAPER_SCHEDULE, cfg), np.int16)
    tah = np.asarray(C.tanh_mr_q(all_codes, C.PAPER_SCHEDULE, cfg), np.int16)

    c, s, _ = eng.rotate_q(all_codes, HYP_ROTATION, cfg)
    ex = np.asarray(fp.add(c, s, cfg.fmt), np.int16)    # e^r codes

    mq = jnp.arange(LOG_M_LO, LOG_M_HI, dtype=jnp.int32)
    # (x0, y0) = (m+1, m-1): exact dyadic offsets, both inside Q2.14
    lg = np.asarray(eng.vector_q(mq + ONE_Q, mq - ONE_Q, HYP_VECTORING, cfg),
                    np.int16)

    fmt = str(cfg.fmt)
    return {
        "sigmoid": (sig, dict(fmt=fmt, domain="all 2^16 codes",
                              pipeline="sigmoid_mr_q(PAPER_SCHEDULE)")),
        "tanh": (tah, dict(fmt=fmt, domain="all 2^16 codes",
                           pipeline="tanh_mr_q(PAPER_SCHEDULE)")),
        "exp": (ex, dict(fmt=fmt, domain="all 2^16 angle codes",
                         pipeline="cosh+sinh of rotate_q(HYP_ROTATION)")),
        "log": (lg, dict(fmt=fmt, domain=f"mantissa codes [{LOG_M_LO},{LOG_M_HI})",
                         pipeline="vector_q(m+1, m-1, HYP_VECTORING)")),
    }


def _profile_domain(fb: int) -> np.ndarray:
    """Input codes for a wider-format sweep: the Q2.14 lattice embedded at
    frac_bits ``fb`` (full range, 2^16 points) plus a dense window around 0
    (low-order-bit coverage). Sorted, unique, int64."""
    stride = np.arange(-(1 << 15), 1 << 15, dtype=np.int64) << (fb - 14)
    dense = np.arange(-DENSE_HALF, DENSE_HALF + 1, dtype=np.int64)
    return np.unique(np.concatenate([stride, dense]))


def generate_profile(name: str) -> dict:
    """Golden (x, y) maps for one FORMAT_PROFILES entry.

    Same four functions as the Q2.14 set, computed with the profile's
    format-sized schedules; inputs are stored explicitly (the sweep is a
    deterministic sample, not exhaustive)."""
    p = FORMAT_PROFILES[name]
    fb = p.cfg.fmt.frac_bits
    one = 1 << fb
    codes = _profile_domain(fb)
    xj = jnp.asarray(codes, jnp.int32)

    sig = np.asarray(C.sigmoid_mr_q(xj, p.pipeline, p.cfg), np.int32)
    tah = np.asarray(C.tanh_mr_q(xj, p.pipeline, p.cfg), np.int32)
    c, s, _ = eng.rotate_q(xj, p.rotation, p.cfg)
    ex = np.asarray(fp.add(c, s, p.cfg.fmt), np.int32)

    # log leg: mantissa codes in [0.5, 1) on the Q2.14 lattice + dense tail
    m_stride = (np.arange(1 << 13, 1 << 14, dtype=np.int64) << (fb - 14))
    m_dense = (1 << (fb - 1)) + np.arange(DENSE_HALF, dtype=np.int64)
    mq = np.unique(np.concatenate([m_stride, m_dense]))
    mj = jnp.asarray(mq, jnp.int32)
    lg = np.asarray(eng.vector_q(mj + one, mj - one, p.vectoring, p.cfg),
                    np.int32)

    fmt = str(p.cfg.fmt)
    dom = f"Q2.14 lattice << {fb - 14} + dense |x| <= {DENSE_HALF}"
    return {
        f"sigmoid_{name}": (codes, sig, dict(
            fmt=fmt, profile=name, domain=dom,
            pipeline="sigmoid_mr_q(profile.pipeline)")),
        f"tanh_{name}": (codes, tah, dict(
            fmt=fmt, profile=name, domain=dom,
            pipeline="tanh_mr_q(profile.pipeline)")),
        f"exp_{name}": (codes, ex, dict(
            fmt=fmt, profile=name, domain=dom,
            pipeline="cosh+sinh of rotate_q(profile.rotation)")),
        f"log_{name}": (mq, lg, dict(
            fmt=fmt, profile=name,
            domain=f"mantissa codes [{1 << (fb - 1)}, {1 << fb}) sampled",
            pipeline="vector_q(m+1, m-1, profile.vectoring)")),
    }


def write(out_dir: str, profiles=()) -> None:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for name, (codes, meta) in generate().items():
        path = out / f"{name}_q2_14.npz"
        np.savez_compressed(path, y=codes,
                            meta=np.bytes_(json.dumps(meta, sort_keys=True)))
        print(f"wrote {path} ({codes.size} codes, "
              f"{path.stat().st_size / 1024:.0f} KiB)")
    for prof in profiles:
        for name, (x, y, meta) in generate_profile(prof).items():
            path = out / f"{name}.npz"
            np.savez_compressed(
                path, x=x.astype(np.int32), y=y,
                meta=np.bytes_(json.dumps(meta, sort_keys=True)))
            print(f"wrote {path} ({y.size} codes, "
                  f"{path.stat().st_size / 1024:.0f} KiB)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent.parent
                                         / "tests" / "golden"))
    ap.add_argument("--profile", default=None,
                    choices=[*sorted(set(FORMAT_PROFILES) - {"q2_14"}), "all"],
                    help="also freeze the wider-format profile vectors "
                         "(q2_20 / q2_29; 'all' for both)")
    args = ap.parse_args()
    profs = (sorted(set(FORMAT_PROFILES) - {"q2_14"})
             if args.profile == "all" else
             [args.profile] if args.profile else [])
    write(args.out, profs)
