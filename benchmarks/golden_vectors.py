"""Generate the golden-vector conformance set for the Q2.14 integer datapath.

Writes one ``.npz`` per function into ``tests/golden/``, each holding the
*exhaustive* input-code -> output-code map of the bit-accurate pipeline:

    sigmoid  all 2^16 Q2.14 codes -> sigmoid_mr_q codes (paper pipeline)
    tanh     all 2^16 Q2.14 codes -> tanh_mr_q codes
    exp      all 2^16 angle codes -> cosh+sinh codes of the MR-HRC rotation
             (the e^r core of exp/softmax; deterministic out-of-domain too)
    log      mantissa codes m in [0.5, 1) -> hyperbolic-vectoring
             2*atanh((m-1)/(m+1)) accumulator codes (the log leg)

The files are checked in; tests/test_golden_vectors.py asserts that both
the jnp engine path and the Pallas kernel path reproduce them bit-exactly,
so a refactor of the iteration core cannot silently drift from the paper's
published 4.23e-4 MAE behavior. Regenerate (only after an *intentional*
datapath change) with:

    PYTHONPATH=src python benchmarks/golden_vectors.py
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np
import jax.numpy as jnp

from repro.core import cordic as C
from repro.core import fixed_point as fp
from repro.cordic_engine import core as eng
from repro.cordic_engine.schedule import HYP_ROTATION, HYP_VECTORING

#: mantissa code range for the log leg: m = code * 2^-14 in [0.5, 1).
LOG_M_LO, LOG_M_HI = 1 << 13, 1 << 14
ONE_Q = 1 << 14


def generate() -> dict:
    """Returns {name: (out_codes int16 array, meta dict)}."""
    all_codes = jnp.arange(-(1 << 15), 1 << 15, dtype=jnp.int32)
    cfg = C.PAPER_FIXED

    sig = np.asarray(C.sigmoid_mr_q(all_codes, C.PAPER_SCHEDULE, cfg), np.int16)
    tah = np.asarray(C.tanh_mr_q(all_codes, C.PAPER_SCHEDULE, cfg), np.int16)

    c, s, _ = eng.rotate_q(all_codes, HYP_ROTATION, cfg)
    ex = np.asarray(fp.add(c, s, cfg.fmt), np.int16)    # e^r codes

    mq = jnp.arange(LOG_M_LO, LOG_M_HI, dtype=jnp.int32)
    # (x0, y0) = (m+1, m-1): exact dyadic offsets, both inside Q2.14
    lg = np.asarray(eng.vector_q(mq + ONE_Q, mq - ONE_Q, HYP_VECTORING, cfg),
                    np.int16)

    fmt = str(cfg.fmt)
    return {
        "sigmoid": (sig, dict(fmt=fmt, domain="all 2^16 codes",
                              pipeline="sigmoid_mr_q(PAPER_SCHEDULE)")),
        "tanh": (tah, dict(fmt=fmt, domain="all 2^16 codes",
                           pipeline="tanh_mr_q(PAPER_SCHEDULE)")),
        "exp": (ex, dict(fmt=fmt, domain="all 2^16 angle codes",
                         pipeline="cosh+sinh of rotate_q(HYP_ROTATION)")),
        "log": (lg, dict(fmt=fmt, domain=f"mantissa codes [{LOG_M_LO},{LOG_M_HI})",
                         pipeline="vector_q(m+1, m-1, HYP_VECTORING)")),
    }


def write(out_dir: str) -> None:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for name, (codes, meta) in generate().items():
        path = out / f"{name}_q2_14.npz"
        np.savez_compressed(path, y=codes,
                            meta=np.bytes_(json.dumps(meta, sort_keys=True)))
        print(f"wrote {path} ({codes.size} codes, "
              f"{path.stat().st_size / 1024:.0f} KiB)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent.parent
                                         / "tests" / "golden"))
    args = ap.parse_args()
    write(args.out)
